//! End-to-end serving driver — the full three-layer stack on a real
//! (small) model:
//!
//! * L1: Pallas kernels (`moe_ffn`, `paged_attention`) inside …
//! * L2: … the JAX decode graph, AOT-lowered to `artifacts/*.hlo.txt`, …
//! * L3: … executed from the Rust coordinator through the PJRT CPU
//!   client with continuous batching and a paged KV pool. Python never
//!   runs here.
//!
//! Serves a batch of requests end to end and reports wall-clock
//! latency/throughput plus the expert-routing histogram observed from
//! the real gating network. Recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`

use harvest::runtime::ModelRuntime;
use harvest::server::{RealEngine, WorkloadGen, WorkloadSpec};
use harvest::util::fmt_ns;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("HARVEST_ARTIFACTS").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
    });
    let dir = PathBuf::from(dir);
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("no artifacts at {} — run `make artifacts` first", dir.display());
    }

    println!("loading AOT artifacts from {} ...", dir.display());
    let rt = ModelRuntime::load(&dir)?;
    let cfg = rt.config().clone();
    println!(
        "model: {} layers, d={}, {} experts (top-{}), vocab {}, page {} tok x {} pages",
        cfg.n_layers, cfg.d_model, cfg.n_experts, cfg.top_k, cfg.vocab, cfg.page_size,
        cfg.num_pages
    );
    println!(
        "weights {:.2} MiB, KV state {:.2} MiB, batch variants {:?}\n",
        rt.weights_bytes() as f64 / (1 << 20) as f64,
        rt.kv_state_bytes() as f64 / (1 << 20) as f64,
        rt.batch_variants()
    );

    // A small but real workload: 24 requests, lognormal prompts, 16 new
    // tokens each, sized to the tiny model's context window.
    let spec = WorkloadSpec {
        n_requests: 24,
        mean_prompt_tokens: 24.0,
        prompt_sigma: 0.4,
        max_new_tokens: 16,
        seed: 42,
        ..Default::default()
    };
    let requests = WorkloadGen::new(spec).generate();
    let total_new: u64 = requests.iter().map(|r| r.max_new_tokens as u64).sum();

    let mut engine = RealEngine::new(rt)?;
    println!("serving {} requests ({total_new} new tokens) ...", requests.len());
    let report = engine.serve(requests)?;

    let m = &report.metrics;
    println!("\n== results (wall clock, PJRT CPU) ==");
    println!("requests finished : {}", m.requests_finished);
    println!("tokens generated  : {}", m.tokens_generated);
    println!("decode steps      : {}", report.decode_steps);
    println!("wall time         : {:.2} s", report.wall_seconds);
    println!(
        "throughput        : {:.1} tok/s",
        m.tokens_generated as f64 / report.wall_seconds
    );
    println!(
        "TTFT              : mean {}  p99 {}",
        fmt_ns(m.ttft.mean() as u64),
        fmt_ns(m.ttft.percentile(99.0) as u64)
    );
    println!(
        "per-token latency : mean {}  p99 {}",
        fmt_ns(m.per_token.mean() as u64),
        fmt_ns(m.per_token.percentile(99.0) as u64)
    );

    // Expert routing skew measured from the REAL gating network (§4.2's
    // premise, observed rather than simulated).
    let totals = report.expert_usage.totals();
    let sum: u64 = totals.iter().sum();
    let mut sorted = totals.clone();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    println!("\nexpert activation histogram (from the real router):");
    for (e, t) in totals.iter().enumerate() {
        let bar = "#".repeat((t * 40 / sum.max(1).max(*t)) as usize);
        println!("  expert {e}: {t:>6} {bar}");
    }
    let top2: u64 = sorted.iter().take(2).sum();
    println!(
        "top-2 experts carry {:.0}% of activations (skew -> §4.2 caching opportunity)",
        top2 as f64 / sum as f64 * 100.0
    );

    // Determinism check: same seed, same outputs.
    let sample: Vec<_> = report.outputs.iter().take(2).collect();
    println!("\nsample outputs (greedy): {sample:?}");
    Ok(())
}
