//! Long-context KV offload (paper §5) — decode a handful of very long
//! sequences whose KV cache exceeds the local pool, comparing vanilla
//! vLLM behaviour (evict to host DRAM over PCIe) against Harvest (evict
//! to peer HBM over NVLink), then inject a revocation storm and watch
//! the lossy tier recompute.
//!
//! Run: `cargo run --release --example kv_longcontext`

use harvest::harvest::{HarvestConfig, HarvestRuntime, RevocationReason};
use harvest::kv::{KvConfig, KvOffloadManager, SeqId};
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::util::{fmt_bytes, fmt_ns};

fn run(use_harvest: bool) -> (u64, harvest::kv::KvStats) {
    let model = find_kv_model("kimi").unwrap();
    let cfg = KvConfig {
        model,
        block_tokens: 16,
        local_capacity_blocks: 256, // 4096 tokens of local KV
        use_harvest,
        host_backed_peer: false,
    };
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let mut kv = KvOffloadManager::new(cfg, 0);

    // 4 sequences × 4096-token contexts = 4x the local pool.
    let seqs: Vec<SeqId> = (0..4).map(SeqId).collect();
    for &s in &seqs {
        for _ in 0..4096 {
            kv.append_token(&mut hr, s);
        }
    }
    // Decode phase: each step touches every sequence's full KV (attention
    // reads all blocks), round-robin — the reuse pattern §6.2 highlights.
    let t0 = hr.node.clock.now();
    for _step in 0..32 {
        for &s in &seqs {
            kv.access_seq(&mut hr, s);
            kv.append_token(&mut hr, s);
        }
    }
    (hr.node.clock.now() - t0, kv.stats.clone())
}

fn main() {
    let model = find_kv_model("kimi").unwrap();
    println!(
        "long-context decode: Kimi-K2 geometry, {} per token, 4 x 4096-token sequences,\n\
         local pool 256 blocks (4096 tokens) -> 75% of KV must live off-GPU\n",
        fmt_bytes(model.kv_bytes_per_token())
    );

    let (host_ns, host_stats) = run(false);
    let (peer_ns, peer_stats) = run(true);

    println!("vanilla vLLM (host offload):");
    println!(
        "  decode time {}   reloads {} (host {}, peer {})   hit rate {:.1}%",
        fmt_ns(host_ns),
        host_stats.reloads(),
        host_stats.host_reloads,
        host_stats.peer_reloads,
        host_stats.hit_rate() * 100.0
    );
    println!("harvest (peer offload):");
    println!(
        "  decode time {}   reloads {} (host {}, peer {})   hit rate {:.1}%",
        fmt_ns(peer_ns),
        peer_stats.reloads(),
        peer_stats.host_reloads,
        peer_stats.peer_reloads,
        peer_stats.hit_rate() * 100.0
    );
    println!("  speedup: {:.2}x\n", host_ns as f64 / peer_ns as f64);

    // Revocation storm mid-decode: the lossy peer tier disappears.
    println!("injecting peer revocation mid-decode (lossy tier) ...");
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let cfg = KvConfig {
        model,
        block_tokens: 16,
        local_capacity_blocks: 256,
        use_harvest: true,
        host_backed_peer: false,
    };
    let mut kv = KvOffloadManager::new(cfg, 0);
    let s = SeqId(0);
    // 12288 tokens = 768 blocks vs a 256-block pool: 512 blocks spill to peer
    for _ in 0..12288 {
        kv.append_token(&mut hr, s);
    }
    let revs = hr.revoke_peer(1, RevocationReason::ExternalReclaim);
    println!("  {} peer blocks revoked; correctness preserved by recomputation:", revs.len());
    kv.access_seq(&mut hr, s);
    let inv = match kv.check_invariants() {
        Ok(()) => "ok".to_string(),
        Err(e) => e,
    };
    println!(
        "  after reaccess: recomputes {}, drops observed {}, invariants {inv}",
        kv.stats.recomputes,
        kv.drops_observed(),
    );
}
