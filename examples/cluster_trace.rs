//! Cluster-trace study (paper §2.1 / Fig. 2) — synthesize the Alibaba
//! gpu-v2020-like utilisation distribution, then replay a machine's
//! tenant timeline against the Harvest controller to measure how much
//! peer memory is harvestable over a day and how often it gets revoked.
//!
//! Run: `cargo run --release --example cluster_trace`

use harvest::harvest::{AllocHints, HarvestConfig, HarvestRuntime, Lease, PayloadKind};
use harvest::memsim::{NodeSpec, SimNode, TenantLoad, UtilizationModel};
use harvest::trace::{ClusterTrace, TraceSpec};
use harvest::util::fmt_bytes;
use harvest::util::rng::Rng;

const GIB: u64 = 1 << 30;
const HOUR: u64 = 3_600_000_000_000;

fn main() {
    // Part 1: the Fig. 2 distribution.
    let trace = ClusterTrace::synthesize(TraceSpec::default());
    println!("Fig. 2 replica — {} machine snapshots:", trace.len());
    for u in [0.2, 0.5] {
        println!("  {:.0}% of machines use <= {:.0}% of GPU memory", trace.cdf_at(u) * 100.0, u * 100.0);
    }
    println!("  (paper: ~68% <= 20%, ~87% <= 50%)\n");

    // Part 2: replay a 24h tenant timeline on the peer GPU and keep a
    // standing harvest of as much memory as the controller will give us.
    println!("24h replay: opportunistic harvesting against a gpu-v2020-like tenant");
    let mut rng = Rng::new(7);
    // stationary target drawn from the Fig. 2 distribution
    let model = UtilizationModel::gpu_v2020();
    let target = model.sample(&mut rng);
    println!("  tenant stationary utilisation target: {:.0}%", target * 100.0);
    let timeline =
        TenantLoad::generate(&mut rng, 80 * GIB, target, Default::default(), 24 * HOUR);
    let mut node = SimNode::new(NodeSpec::h100x2());
    node.set_tenant_load(1, timeline);
    let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(2));
    let session = hr.open_session(PayloadKind::Generic);
    let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };

    let chunk = GIB;
    let mut held: Vec<Lease> = Vec::new();
    let mut samples = Vec::new();
    for hour5 in 0..(24 * 12) {
        let t = hour5 * (HOUR / 12);
        hr.advance_to(t);
        // pull-model: drop our RAII owners for whatever got revoked
        for ev in session.drain_revocations(&mut hr) {
            held.retain(|l| l.id() != ev.lease);
        }
        // greedily top up
        while let Ok(lease) =
            session.alloc(&mut hr, chunk, harvest::harvest::TierPreference::PEER_ONLY, hints)
        {
            held.push(lease);
        }
        samples.push(hr.live_bytes_on(1));
    }
    let mean = samples.iter().sum::<u64>() / samples.len() as u64;
    let min = *samples.iter().min().unwrap();
    let max = *samples.iter().max().unwrap();
    println!(
        "  harvested on peer: mean {} (min {}, max {}) of 80 GiB",
        fmt_bytes(mean),
        fmt_bytes(min),
        fmt_bytes(max)
    );
    println!(
        "  allocation attempts {} (failures {}), revocations {}",
        hr.alloc_attempts,
        hr.alloc_failures,
        hr.revocations.len()
    );
    println!(
        "\ntakeaway: production-trace-shaped tenants leave large, mostly-stable\n\
         headroom — the §2.1 premise — but the controller must absorb {} \n\
         revocation events/day to use it safely.",
        hr.revocations.len()
    );
}
