//! Completely Fair Decoding study (paper §6.3) — token-level preemption
//! amplifies KV working-set churn; Harvest lowers the marginal cost of
//! each preemption-induced reload, so finer-grained fairness becomes
//! affordable.
//!
//! Run: `cargo run --release --example fair_decode`

use harvest::harvest::{HarvestConfig, HarvestRuntime};
use harvest::kv::KvConfig;
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::server::{
    CompletelyFair, Fcfs, Scheduler, SimEngine, SimEngineConfig, SimEngineReport, WorkloadGen,
    WorkloadSpec,
};

fn run(use_harvest: bool, quantum: Option<u32>) -> SimEngineReport {
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let cfg = KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 48, // tight budget -> eviction pressure
        use_harvest,
        host_backed_peer: false,
    };
    let sched: Box<dyn Scheduler> = match quantum {
        None => Box::new(Fcfs::new()),
        Some(q) => Box::new(CompletelyFair::new(q)),
    };
    // Multi-tenant-style workload with shared prompt prefixes (§6.2:
    // reuse of evicted state is what makes the cache tier pay off).
    let spec = WorkloadSpec {
        n_requests: 24,
        mean_prompt_tokens: 96.0,
        max_new_tokens: 16,
        shared_prefix_fraction: 0.5,
        shared_prefix_tokens: 32,
        ..Default::default()
    };
    let mut eng = SimEngine::new(SimEngineConfig::new(cfg, 8, 32), sched, 0);
    eng.run(&mut hr, WorkloadGen::new(spec).generate())
}

fn main() {
    println!("§6.3 — fair decoding: FCFS vs token-level-preemptive CF, host vs peer tier\n");
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>12}",
        "CONFIG", "TOK/S", "RELOADS", "P99 TTFT", "CF PENALTY"
    );
    for tier in [false, true] {
        let name = if tier { "peer (harvest)" } else { "host (vanilla)" };
        let fcfs = run(tier, None);
        let base = fcfs.metrics.tokens_per_sec();
        for (label, q) in [("fcfs", None), ("cf q=4", Some(4)), ("cf q=1", Some(1))] {
            let r = if q.is_none() { run(tier, None) } else { run(tier, q) };
            let tps = r.metrics.tokens_per_sec();
            let penalty = if q.is_none() {
                "-".to_string()
            } else {
                format!("{:.1}%", (1.0 - tps / base) * 100.0)
            };
            println!(
                "{:<22} {:>10.0} {:>10} {:>9.1}ms {:>12}",
                format!("{name} / {label}"),
                tps,
                r.kv_stats.reloads(),
                r.metrics.ttft.percentile(99.0) / 1e6,
                penalty
            );
        }
    }
    println!(
        "\ntakeaway: the CF throughput penalty is smaller on the peer tier — \n\
         peer-HBM offload is a scheduler robustness mechanism (§6.3), letting\n\
         systems run finer-grained fairness without the full paging penalty."
    );
}
