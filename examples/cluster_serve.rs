//! Multi-node cluster serving quickstart: run the `cluster-4` preset —
//! 4 simulated 2×H100 nodes behind prefix-affinity routing on an RDMA
//! node fabric — and compare routing policies on the same session
//! workload.
//!
//! Run: `cargo run --release --example cluster_serve`

use harvest::cluster::{Cluster, RouterPolicy};
use harvest::config::find_preset;
use harvest::server::{SimEngineConfig, WorkloadGen};
use harvest::util::{fmt_bytes, fmt_ns};

fn main() {
    let cfg = find_preset("cluster-4").expect("preset registered");
    let kv = cfg.kv_config().expect("kv model known");
    println!(
        "preset `{}`: {} nodes ({} GPUs x {} GiB each), {} fabric, {} requests\n",
        cfg.name, cfg.nodes, cfg.n_gpus, cfg.hbm_gib, cfg.node_fabric.name(), cfg.n_requests
    );

    for policy in
        [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::PrefixAffinity]
    {
        let mut spec = cfg.cluster_spec();
        spec.router = policy;
        let engine = SimEngineConfig::new(kv, cfg.decode_slots, cfg.max_running);
        let mut cluster =
            Cluster::new(&spec, engine, cfg.scheduler_spec().expect("scheduler known"));
        let report = cluster.run(WorkloadGen::new(cfg.workload_spec()).generate());
        let m = &report.aggregate;
        let hits: u64 = report.per_node.iter().map(|n| n.prefix_hits).sum();
        println!(
            "{:<14} {:.0} tok/s | ttft p50 {} p99 {} | {} prefix hits | {} migrations ({})",
            policy.name(),
            m.tokens_per_sec(),
            fmt_ns(m.ttft.percentile(50.0) as u64),
            fmt_ns(m.ttft.percentile(99.0) as u64),
            hits,
            report.stats.prefix_migrations,
            fmt_bytes(report.stats.migrated_bytes),
        );
        for n in &report.per_node {
            println!(
                "    node {}: {:>3} served, {:>4} kv reloads, ledger {} harvested",
                n.node,
                n.finished,
                n.kv_stats.reloads(),
                fmt_bytes(n.ledger.total())
            );
        }
    }
    println!(
        "\ntakeaway: affinity routing pins each shared-prefix session to the node\n\
         already holding its KV blocks — prefill shrinks to the unshared suffix\n\
         and tail TTFT drops relative to round-robin, while spillover migrations\n\
         keep the holder from becoming a hotspot."
    );
}
