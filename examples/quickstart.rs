//! Quickstart — the Harvest API in 60 lines (paper §3.2).
//!
//! Simulates a 2× H100 node, harvests peer HBM, populates it, serves a
//! fast peer fetch, then watches a co-tenant pressure spike revoke the
//! allocation (drain → invalidate → callback) and falls back to host.
//!
//! Run: `cargo run --release --example quickstart`

use harvest::harvest::{AllocHints, Durability, HarvestConfig, HarvestRuntime};
use harvest::memsim::{DeviceId, NodeSpec, SimNode, TenantLoad};
use harvest::util::{fmt_bytes, fmt_ns};
use std::cell::RefCell;
use std::rc::Rc;

const GIB: u64 = 1 << 30;
const MIB: u64 = 1 << 20;

fn main() {
    // A 2-GPU NVLink node (the paper's testbed shape). GPU 0 is our
    // memory-pressured compute GPU; GPU 1 has headroom.
    let node = SimNode::new(NodeSpec::h100x2());
    let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(2));

    // 1. harvest_alloc: ask for 256 MiB of peer HBM for compute GPU 0.
    let hints = AllocHints {
        compute_gpu: Some(0),
        durability: Durability::HostBacked, // authoritative copy in DRAM
        ..Default::default()
    };
    let handle = hr.alloc(256 * MIB, hints).expect("peer capacity available");
    println!(
        "harvest_alloc -> handle {:?}: {} on peer GPU {} (offset {:#x})",
        handle.id,
        fmt_bytes(handle.size),
        handle.peer,
        handle.offset
    );

    // 2. harvest_register_cb: get told when the allocation is revoked.
    let revoked = Rc::new(RefCell::new(None));
    let seen = revoked.clone();
    hr.register_cb(handle.id, move |rev| {
        *seen.borrow_mut() = Some((rev.reason, rev.at));
    })
    .unwrap();

    // 3. Populate the cache (host -> peer over PCIe, off the hot path)...
    let fill = hr.copy_in(handle.id, DeviceId::Host).unwrap();
    println!("populate: host->peer copy finishes at t={}", fmt_ns(fill.end));

    // ...then serve a hit (peer -> compute over NVLink, the fast path).
    let hit = hr.fetch_to(handle.id, 0).unwrap();
    let host_equivalent =
        hr.node.topo.estimate(DeviceId::Host, DeviceId::Gpu(0), handle.size).unwrap();
    println!(
        "cache hit:  peer->gpu0 in {} (host DRAM would take {}; {:.1}x slower)",
        fmt_ns(hit.duration()),
        fmt_ns(host_equivalent),
        host_equivalent as f64 / hit.duration() as f64
    );

    // 4. A co-tenant on GPU 1 suddenly wants (almost) all of its memory.
    let now = hr.node.clock.now();
    hr.node.set_tenant_load(
        1,
        TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1_000_000, 80 * GIB)]),
    );
    let revs = hr.advance_to(now + 2_000_000);
    println!("tenant pressure spike -> {} revocation(s)", revs.len());
    let (reason, at) = revoked.borrow().expect("callback fired");
    println!("callback observed: reason {reason:?} at t={}", fmt_ns(at));
    assert!(!hr.is_live(handle.id), "handle is gone");

    // 5. Correctness never depended on the peer tier: the object still
    //    has its authoritative host copy; we just fetch from there now.
    let fallback = hr.node.copy(DeviceId::Host, DeviceId::Gpu(0), 256 * MIB, None);
    println!("fallback:   host->gpu0 in {} (correct, just slower)", fmt_ns(fallback.duration()));
}
