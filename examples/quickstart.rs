//! Quickstart — the lease-based Harvest API in ~70 lines (paper §3.2,
//! redesigned).
//!
//! Simulates a 2× H100 node, opens a session, leases peer HBM, populates
//! and serves it through the unified `Transfer` builder, then watches a
//! co-tenant pressure spike revoke the lease (drain → invalidate →
//! event) and falls back to host — all without callbacks or shared
//! state: revocations are *pulled* with `drain_revocations`.
//!
//! Run: `cargo run --release --example quickstart`

use harvest::harvest::{
    AllocHints, Durability, HarvestConfig, HarvestRuntime, PayloadKind, TierPreference, Transfer,
};
use harvest::memsim::{DeviceId, NodeSpec, SimNode, TenantLoad};
use harvest::util::{fmt_bytes, fmt_ns};

const GIB: u64 = 1 << 30;
const MIB: u64 = 1 << 20;

fn main() {
    // A 2-GPU NVLink node (the paper's testbed shape). GPU 0 is our
    // memory-pressured compute GPU; GPU 1 has headroom. The controller
    // config is TOML-loadable for sweeps; defaults would do here too.
    let node = SimNode::new(NodeSpec::h100x2());
    let cfg = HarvestConfig::from_toml_str("gpus = 2\nvictim_policy = \"lifo\"").unwrap();
    let mut hr = HarvestRuntime::new(node, cfg);

    // 1. Open a session and lease 256 MiB of peer HBM for compute GPU 0.
    //    The payload kind, durability and client identity ride on the
    //    lease; dropping it without release would be swept, releasing it
    //    twice does not compile.
    let session = hr.open_session(PayloadKind::Generic);
    let hints = AllocHints {
        compute_gpu: Some(0),
        durability: Durability::HostBacked, // authoritative copy in DRAM
        ..Default::default()
    };
    let lease = session
        .alloc(&mut hr, 256 * MIB, TierPreference::FastestAvailable, hints)
        .expect("peer capacity available");
    println!(
        "alloc -> lease {:?}: {} on tier {} ({:?})",
        lease.id(),
        fmt_bytes(lease.size()),
        lease.tier(),
        lease.kind(),
    );

    // 2. One transfer batch: populate the cache (host -> peer over PCIe,
    //    off the hot path), then serve a hit (peer -> compute over
    //    NVLink, the fast path). Both ops are tagged with the lease id,
    //    so the revocation pipeline's DMA drain covers them.
    let report = Transfer::new()
        .populate(&lease, DeviceId::Host)
        .fetch(&lease, 0)
        .submit(&mut hr)
        .unwrap();
    let hit = report.events[1];
    let host_equivalent =
        hr.node.topo.estimate(DeviceId::Host, DeviceId::Gpu(0), lease.size()).unwrap();
    println!("populate: host->peer copy finishes at t={}", fmt_ns(report.events[0].end));
    println!(
        "cache hit:  peer->gpu0 in {} (host DRAM would take {}; {:.1}x slower)",
        fmt_ns(hit.duration()),
        fmt_ns(host_equivalent),
        host_equivalent as f64 / hit.duration() as f64
    );

    // 3. A co-tenant on GPU 1 suddenly wants (almost) all of its memory.
    //    The controller drains in-flight DMA, invalidates the placement,
    //    frees the bytes — and only then is the event observable.
    let now = hr.node.clock.now();
    hr.node.set_tenant_load(
        1,
        TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1_000_000, 80 * GIB)]),
    );
    let revs = hr.advance_to(now + 2_000_000);
    println!("tenant pressure spike -> {} revocation(s)", revs.len());

    // 4. Pull the event at our own tick boundary. No callback, no shared
    //    state: we repair our index here, synchronously.
    let events = session.drain_revocations(&mut hr);
    let ev = events.first().expect("event pending");
    assert_eq!(ev.lease, lease.id());
    assert!(!hr.is_live(lease.id()), "lease is gone before the event is visible");
    println!("event drained: reason {:?} at t={}", ev.reason, fmt_ns(ev.at));

    // 5. Correctness never depended on the peer tier: the object still
    //    has its authoritative host copy; we just fetch from there now.
    let fallback = hr.node.copy(DeviceId::Host, DeviceId::Gpu(0), 256 * MIB, None);
    println!("fallback:   host->gpu0 in {} (correct, just slower)", fmt_ns(fallback.duration()));
    drop(lease); // stale RAII owner; the runtime's sweep ignores it
}
