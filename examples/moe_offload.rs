//! MoE expert offload (paper §4) — the full Expert-Rebalancer + CGOPipe
//! path on the paper's §4.4 configuration, reproducing the Fig. 5
//! comparison for one model and showing what happens when peer capacity
//! appears and disappears mid-serve.
//!
//! Run: `cargo run --release --example moe_offload [model-name]`

use harvest::harvest::{HarvestConfig, HarvestRuntime};
use harvest::memsim::{NodeSpec, SimNode, TenantLoad};
use harvest::moe::pipeline::OffloadTier;
use harvest::moe::{find_moe_model, CgoPipe, ExpertRebalancer, RouterSim};
use harvest::util::{fmt_bytes, fmt_ns};

const GIB: u64 = 1 << 30;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "Phi-3.5-MoE".into());
    let model = find_moe_model(&name).unwrap_or_else(|| {
        eprintln!("unknown model `{name}`; try Mixtral-8x7B / Phi-3.5-MoE / Phi-tiny-MoE / Qwen2-MoE");
        std::process::exit(1);
    });
    println!(
        "{}: {} layers x {} experts (top-{}), expert = {} ({} total)\n",
        model.name,
        model.n_layers,
        model.n_experts,
        model.top_k,
        fmt_bytes(model.expert_bytes()),
        fmt_bytes(model.total_expert_bytes())
    );

    // §4.4 setup: µ=324, b=14, 32 new tokens, 50% experts offloaded.
    let pipe = CgoPipe::paper_setup(model);
    let offload = 0.5;

    // Baseline: CGOPipe with host-DRAM offload (PCIe).
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let mut router = RouterSim::new(model, model.n_layers as usize, 1);
    let mut reb = ExpertRebalancer::new(model, 0, offload);
    let cpu = pipe.decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Cpu, 32);

    // Harvest: same pipeline, peer-HBM expert cache.
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let mut router = RouterSim::new(model, model.n_layers as usize, 1);
    let mut reb = ExpertRebalancer::new(model, 0, offload);
    let migrated = reb.rebalance(&mut hr, usize::MAX);
    println!(
        "rebalancer: {} experts migrated to peer HBM ({})",
        migrated,
        fmt_bytes(migrated as u64 * model.expert_bytes())
    );
    let peer = pipe.decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Harvest, 32);

    println!("\n{:<22} {:>12} {:>12}", "", "CPU offload", "Harvest");
    println!("{:<22} {:>12.0} {:>12.0}", "decode tok/s", cpu.tokens_per_sec(), peer.tokens_per_sec());
    println!("{:<22} {:>12} {:>12}", "stall time", fmt_ns(cpu.stall_ns), fmt_ns(peer.stall_ns));
    println!("{:<22} {:>12} {:>12}", "host fetches", cpu.fetches_host, peer.fetches_host);
    println!("{:<22} {:>12} {:>12}", "peer fetches", cpu.fetches_peer, peer.fetches_peer);
    println!(
        "\nimprovement: +{:.0}% (paper Fig. 5 band: +48%..+110%)\n",
        (peer.tokens_per_sec() / cpu.tokens_per_sec() - 1.0) * 100.0
    );

    // Dynamics: a co-tenant claims the peer mid-serve, then leaves.
    println!("dynamic availability: tenant claims peer at t+1ms, releases at t+100ms");
    let now = hr.node.clock.now();
    hr.node.set_tenant_load(
        1,
        TenantLoad::from_steps(
            80 * GIB,
            vec![(0, 0), (now + 1_000_000, 80 * GIB), (now + 100_000_000, 0)],
        ),
    );
    hr.advance_to(now + 2_000_000);
    let during = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
    println!(
        "  during pressure: {:.0} tok/s ({} peer / {} host fetches) — degraded but correct",
        during.tokens_per_sec(),
        during.fetches_peer,
        during.fetches_host
    );
    hr.advance_to(now + 101_000_000);
    let re_migrated = reb.rebalance(&mut hr, usize::MAX);
    let after = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
    println!(
        "  after recovery (+{} experts re-promoted): {:.0} tok/s",
        re_migrated,
        after.tokens_per_sec()
    );
}
