"""Pallas kernel: top-k routed mixture-of-experts feed-forward (SiLU MLP).

TPU-minded structure (see DESIGN.md §Hardware-Adaptation): the grid
iterates over *expert blocks*, and `BlockSpec`s stage one block of expert
weight tiles (`[eb, d, f]` / `[eb, f, d]`) from HBM into VMEM per grid
step — the HBM↔VMEM schedule the CUDA original expressed with
threadblocks. Each step runs MXU-shaped matmuls for its experts over the
whole micro-batch and accumulates the routed contribution into a
revisited output block (constant index map ⇒ the output tile stays
resident in VMEM across the expert loop; classic accumulator pattern).

`expert_block` picks the VMEM working-set/grid-length trade-off: for the
tiny AOT serving model every expert tile fits VMEM at once (2·E·d·f·4 B ≈
2 MiB « 16 MiB/core), so the default stages all experts in a single grid
step — measured 11× faster under the CPU interpreter than one-expert
blocks, and on a real TPU it cuts DMA issue count (EXPERIMENTS.md §Perf).
For paper-scale experts (d=4096, f=14336 ⇒ 448 MiB/expert at f32) a
deployment would set `expert_block=1` and rely on the revisited-output
accumulator, which this kernel keeps.

Tokens not routed to an expert contribute with weight zero — dense
per-expert compute with routing masks keeps every shape static (no
gather/scatter) and the MXU busy. For the tiny-batch serving shapes used
here the redundant FLOPs are cheaper than dynamic shapes.

`interpret=True` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute. Correctness is
asserted against `ref.moe_ffn_ref` by pytest + hypothesis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_ffn_kernel(x_ref, w1_ref, w2_ref, idx_ref, wgt_ref, o_ref, *, eb: int):
    c = pl.program_id(0)                 # expert-block index
    x = x_ref[...]                       # [B, d]   (VMEM-resident)
    w1 = w1_ref[...]                     # [eb, d, f] this block's tiles
    w2 = w2_ref[...]                     # [eb, f, d]
    h = jnp.einsum("bd,edf->ebf", x, w1)            # MXU matmuls (per tile)
    h = h * (1.0 / (1.0 + jnp.exp(-h)))             # SiLU on the VPU
    y = jnp.einsum("ebf,efd->ebd", h, w2)           # [eb, B, d]
    # Routing mask for this block's experts: ids are c*eb + [0, eb).
    e_ids = c * eb + jax.lax.broadcasted_iota(jnp.int32, (eb, 1, 1), 0)
    sel = idx_ref[...][None, :, :] == e_ids          # [eb, B, k]
    wt = jnp.sum(jnp.where(sel, wgt_ref[...][None, :, :], 0.0), axis=2)  # [eb, B]

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.einsum("eb,ebd->bd", wt, y)


@functools.partial(jax.jit, static_argnames=("interpret", "expert_block"))
def moe_ffn(x, w1, w2, topk_idx, topk_w, *, interpret: bool = True,
            expert_block: int | None = None):
    """Top-k routed MoE FFN: y = sum_k topk_w[:,k] * FFN_{topk_idx[:,k]}(x).

    Shapes: x [B,d], w1 [E,d,f], w2 [E,f,d], topk_idx/topk_w [B,k].
    Returns [B,d] with x.dtype. `expert_block` (default: all experts)
    must divide E and sizes the per-grid-step VMEM weight tile.
    """
    B, d = x.shape
    E, _, f = w1.shape
    k = topk_idx.shape[1]
    eb = E if expert_block is None else expert_block
    if E % eb != 0:
        raise ValueError(f"expert_block {eb} must divide n_experts {E}")
    kernel = functools.partial(_moe_ffn_kernel, eb=eb)
    return pl.pallas_call(
        kernel,
        grid=(E // eb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda c: (0, 0)),
            pl.BlockSpec((eb, d, f), lambda c: (c, 0, 0)),
            pl.BlockSpec((eb, f, d), lambda c: (c, 0, 0)),
            pl.BlockSpec((B, k), lambda c: (0, 0)),
            pl.BlockSpec((B, k), lambda c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, d), lambda c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), x.dtype),
        interpret=interpret,
    )(x, w1, w2, topk_idx, topk_w)
