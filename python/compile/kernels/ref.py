"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its reference here to float tolerance. pytest (and hypothesis
shape sweeps) assert kernel-vs-ref allclose at build time; nothing in the
Rust request path ever runs without the oracle having passed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def moe_ffn_ref(x, w1, w2, topk_idx, topk_w):
    """Reference top-k routed mixture-of-experts feed-forward.

    Args:
      x:        [B, d]   token activations.
      w1:       [E, d, f] expert up-projection weights.
      w2:       [E, f, d] expert down-projection weights.
      topk_idx: [B, k]   int32 expert ids selected per token.
      topk_w:   [B, k]   routing weights (already normalised).

    Returns:
      [B, d] combined expert outputs: sum_k w_k * FFN_{e_k}(x).
    """
    E = w1.shape[0]
    out = jnp.zeros_like(x)
    for e in range(E):
        h = silu(x @ w1[e])          # [B, f]
        y = h @ w2[e]                # [B, d]
        sel = topk_idx == e          # [B, k]
        wt = jnp.sum(jnp.where(sel, topk_w, 0.0), axis=1)  # [B]
        out = out + y * wt[:, None]
    return out


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens):
    """Reference single-token decode attention over a paged KV cache.

    Args:
      q:          [B, H, hd]      query for the current decode position.
      k_pages:    [P, bs, H, hd]  paged key cache (physical pages).
      v_pages:    [P, bs, H, hd]  paged value cache.
      page_table: [B, mp]  int32  logical->physical page map per sequence.
      seq_lens:   [B]      int32  valid KV length per sequence (incl. current).

    Returns:
      [B, H, hd] attention outputs.
    """
    B, H, hd = q.shape
    _, bs, _, _ = k_pages.shape
    mp = page_table.shape[1]
    T = mp * bs
    outs = []
    for b in range(B):
        pages = page_table[b]                       # [mp]
        k_all = k_pages[pages].reshape(T, H, hd)    # logical order
        v_all = v_pages[pages].reshape(T, H, hd)
        scores = jnp.einsum("hd,thd->ht", q[b], k_all) / jnp.sqrt(
            jnp.asarray(hd, q.dtype)
        )
        mask = jnp.arange(T) < seq_lens[b]
        scores = jnp.where(mask[None, :], scores, jnp.asarray(-1e30, q.dtype))
        p = jax.nn.softmax(scores, axis=-1)
        outs.append(jnp.einsum("ht,thd->hd", p, v_all))
    return jnp.stack(outs)
