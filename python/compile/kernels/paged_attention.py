"""Pallas kernel: single-token decode attention over a paged KV cache.

This is the L1 hot-spot of the KV-offload workload (paper §5): decode
attention where the KV cache lives in fixed-size pages (vLLM-style) and a
per-sequence page table maps logical block ids to physical pages. The Rust
coordinator decides *which tier* each page lives on (local HBM / peer HBM /
host DRAM — the Harvest contribution); by the time the kernel runs, pages
referenced by the table are resident and the kernel only sees physical page
indices.

TPU-minded structure: grid over sequences; the query tile (`[1, H, hd]`)
and the sequence's page-table row are staged into VMEM via BlockSpecs,
while the page pool stays in HBM and is gathered per-sequence. Scores are
computed against the full (static) `mp*bs` window with a length mask —
static shapes keep the lowering scatter/loop-free, and the softmax is
numerically stabilised with a running max exactly like a single-block
flash step.

`interpret=True` is mandatory on this image (Mosaic custom-calls cannot run
on the CPU PJRT plugin). Oracle: `ref.paged_attention_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paged_attention_kernel(q_ref, kp_ref, vp_ref, pt_ref, len_ref, o_ref):
    b = pl.program_id(0)
    H, hd = q_ref.shape[1], q_ref.shape[2]
    bs = kp_ref.shape[1]
    mp = pt_ref.shape[1]
    T = mp * bs

    q = q_ref[0]                                  # [H, hd]
    pages = pt_ref[0]                             # [mp] int32
    k_pool = kp_ref[...]                          # [P, bs, H, hd]
    v_pool = vp_ref[...]
    k_all = k_pool[pages].reshape(T, H, hd)       # gather logical window
    v_all = v_pool[pages].reshape(T, H, hd)

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    scores = jnp.einsum("hd,thd->ht", q, k_all) * scale  # [H, T]
    mask = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1) < len_ref[b]
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, q.dtype))
    # Stabilised softmax (single-block flash step).
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.einsum("ht,thd->hd", p / denom, v_all)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                    interpret: bool = True):
    """Decode attention over paged KV.

    Shapes: q [B,H,hd], k_pages/v_pages [P,bs,H,hd], page_table [B,mp] i32,
    seq_lens [B] i32. Returns [B,H,hd].
    """
    B, H, hd = q.shape
    P, bs, _, _ = k_pages.shape
    mp = page_table.shape[1]
    return pl.pallas_call(
        _paged_attention_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b: (b, 0, 0)),
            pl.BlockSpec((P, bs, H, hd), lambda b: (0, 0, 0, 0)),
            pl.BlockSpec((P, bs, H, hd), lambda b: (0, 0, 0, 0)),
            pl.BlockSpec((1, mp), lambda b: (b, 0)),
            pl.BlockSpec((B,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(q, k_pages, v_pages, page_table, seq_lens)
