"""L2: tiny MoE transformer decode step over a paged KV cache (JAX).

This is the compute graph the Rust coordinator executes through PJRT: a
pre-norm transformer block stack where the attention reads/writes a
vLLM-style paged KV cache (physical page pool + per-sequence page table)
and the FFN is a top-k routed mixture of experts. Both hot-spots call the
L1 Pallas kernels (`kernels.paged_attention`, `kernels.moe_ffn`); top-k
gating stays in plain jnp (it is tiny and XLA fuses it).

Everything is shape-static so the whole step lowers to a single HLO module:
  decode_step(params..., ids, pos, page_table, seq_lens, kv_k, kv_v)
      -> (logits, kv_k', kv_v')
The KV cache is passed in and returned functionally; the Rust side keeps it
as a device-resident buffer and feeds it back each step. Prefill is done by
calling the same step once per prompt token (chunked prefill of one), so a
single artifact serves both phases.

Build-time only: this module is never imported on the request path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.moe_ffn import moe_ffn
from .kernels.paged_attention import paged_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static geometry of the tiny serving model (and its KV layout)."""

    vocab: int = 1024
    d_model: int = 256
    n_heads: int = 4
    head_dim: int = 64
    n_layers: int = 4
    n_experts: int = 8
    top_k: int = 2
    d_ff: int = 512
    page_size: int = 16          # KV entries per physical page
    num_pages: int = 64          # physical page pool size (per layer)
    max_pages_per_seq: int = 16  # logical pages per sequence (max ctx 256)

    @property
    def max_context(self) -> int:
        return self.page_size * self.max_pages_per_seq

    def validate(self) -> None:
        assert self.n_heads * self.head_dim == self.d_model
        assert self.top_k <= self.n_experts


# Parameter registry: (name, shape-fn) in the exact order Rust's weight
# loader consumes them from weights.bin (see aot.py manifest).
def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, d)),
    ]
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.ln1", (d,)),
            (f"l{l}.wqkv", (d, 3 * d)),
            (f"l{l}.wo", (d, d)),
            (f"l{l}.ln2", (d,)),
            (f"l{l}.gate", (d, E)),
            (f"l{l}.w1", (E, d, f)),
            (f"l{l}.w2", (E, f, d)),
        ]
    specs += [("ln_f", (d,)), ("unembed", (d, cfg.vocab))]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic scaled-normal init (numpy RNG so Rust tests can rely on
    byte-identical weights.bin for a given seed)."""
    rng = np.random.default_rng(seed)
    params: Dict[str, jax.Array] = {}
    for name, shape in param_specs(cfg):
        if name.endswith(("ln1", "ln2", "ln_f")):
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            arr = rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def _rmsnorm(x: jax.Array, scale: jax.Array) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _rope(x: jax.Array, pos: jax.Array) -> jax.Array:
    """Rotary embedding: x [B,H,hd], pos [B] int32."""
    B, H, hd = x.shape
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]       # [B, half]
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def top_k_gating(x: jax.Array, gate_w: jax.Array, k: int):
    """Softmax-renormalised top-k gating. Returns ([B,k] i32, [B,k] f32).

    Implemented as k unrolled argmax+mask rounds rather than
    `jax.lax.top_k`: jax >= 0.5 lowers top_k to an HLO `topk(...,
    largest=true)` custom attribute that the xla_extension 0.5.1 text
    parser (the Rust loader's XLA) rejects. Argmax lowers to plain
    reduce/select ops that round-trip cleanly, and k is tiny (<= 4).
    """
    B = x.shape[0]
    logits = x @ gate_w                                   # [B, E]
    cur = logits
    idxs, vals = [], []
    for _ in range(k):
        i = jnp.argmax(cur, axis=-1)                      # [B]
        v = jnp.take_along_axis(cur, i[:, None], axis=-1)[:, 0]
        idxs.append(i)
        vals.append(v)
        cur = cur.at[jnp.arange(B), i].set(-jnp.inf)
    idx = jnp.stack(idxs, axis=1).astype(jnp.int32)       # [B, k]
    w = jax.nn.softmax(jnp.stack(vals, axis=1), axis=-1)
    return idx, w.astype(x.dtype)


def decode_step(
    params: Dict[str, jax.Array],
    cfg: ModelConfig,
    ids: jax.Array,          # [B] i32 current token ids
    pos: jax.Array,          # [B] i32 decode positions (0-based)
    page_table: jax.Array,   # [B, mp] i32
    seq_lens: jax.Array,     # [B] i32 valid KV length AFTER this token
    kv_k: jax.Array,         # [L, P, bs, H, hd] f32
    kv_v: jax.Array,         # [L, P, bs, H, hd] f32
):
    """One decode step for a batch of B sequences; returns
    (logits [B,V], routed_experts [L,B,k] i32, kv_k', kv_v')."""
    B = ids.shape[0]
    H, hd, bs = cfg.n_heads, cfg.head_dim, cfg.page_size
    x = params["embed"][ids]                              # [B, d]
    batch_ix = jnp.arange(B)
    page = page_table[batch_ix, pos // bs]                # [B] physical page
    off = pos % bs                                        # [B]
    routed = []
    for l in range(cfg.n_layers):
        h = _rmsnorm(x, params[f"l{l}.ln1"])
        qkv = h @ params[f"l{l}.wqkv"]                    # [B, 3d]
        q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
        q = _rope(q.reshape(B, H, hd), pos)
        k_new = _rope(k_new.reshape(B, H, hd), pos)
        v_new = v_new.reshape(B, H, hd)
        kv_k = kv_k.at[l, page, off].set(k_new)           # scatter into pages
        kv_v = kv_v.at[l, page, off].set(v_new)
        attn = paged_attention(q, kv_k[l], kv_v[l], page_table, seq_lens)
        x = x + attn.reshape(B, cfg.d_model) @ params[f"l{l}.wo"]
        h = _rmsnorm(x, params[f"l{l}.ln2"])
        topk_idx, topk_w = top_k_gating(h, params[f"l{l}.gate"], cfg.top_k)
        routed.append(topk_idx)
        x = x + moe_ffn(h, params[f"l{l}.w1"], params[f"l{l}.w2"],
                        topk_idx, topk_w)
    x = _rmsnorm(x, params["ln_f"])
    logits = x @ params["unembed"]
    return logits, jnp.stack(routed), kv_k, kv_v


def decode_step_flat(cfg: ModelConfig):
    """Returns a function taking (flat params..., ids, pos, page_table,
    seq_lens, kv_k, kv_v) in `param_specs` order — the exact calling
    convention of the AOT artifact consumed by the Rust runtime."""
    names = [n for n, _ in param_specs(cfg)]

    def fn(*args):
        n = len(names)
        params = dict(zip(names, args[:n]))
        ids, pos, page_table, seq_lens, kv_k, kv_v = args[n:]
        return decode_step(params, cfg, ids, pos, page_table, seq_lens,
                           kv_k, kv_v)

    return fn


def example_inputs(cfg: ModelConfig, batch: int):
    """ShapeDtypeStructs for the non-parameter decode_step arguments."""
    L, P, bs = cfg.n_layers, cfg.num_pages, cfg.page_size
    H, hd, mp = cfg.n_heads, cfg.head_dim, cfg.max_pages_per_seq
    i32, f32 = jnp.int32, jnp.float32
    return (
        jax.ShapeDtypeStruct((batch,), i32),            # ids
        jax.ShapeDtypeStruct((batch,), i32),            # pos
        jax.ShapeDtypeStruct((batch, mp), i32),         # page_table
        jax.ShapeDtypeStruct((batch,), i32),            # seq_lens
        jax.ShapeDtypeStruct((L, P, bs, H, hd), f32),   # kv_k
        jax.ShapeDtypeStruct((L, P, bs, H, hd), f32),   # kv_v
    )
