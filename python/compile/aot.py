"""AOT pipeline: lower the L2 graph to HLO *text* + weights + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the `xla` crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  decode_step_b{B}.hlo.txt   full transformer decode step per batch variant
  moe_ffn.hlo.txt            standalone L1 MoE FFN kernel (micro-bench)
  paged_attention.hlo.txt    standalone L1 paged attention kernel
  weights.bin                all parameters, f32 LE, param_specs order
  manifest.json              shapes/dtypes/arg order + model config + seed

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.moe_ffn import moe_ffn
from .kernels.paged_attention import paged_attention
from .model import (ModelConfig, decode_step_flat, example_inputs,
                    init_params, param_specs)

BATCH_VARIANTS = (1, 4)
SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=False: PJRT
    untuples the root, so the Rust side reads one buffer per result —
    half the output copy of the tuple path, see EXPERIMENTS.md §Perf)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}


def build(out_dir: pathlib.Path) -> dict:
    cfg = ModelConfig()
    cfg.validate()
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {
        "seed": SEED,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "head_dim": cfg.head_dim,
            "n_layers": cfg.n_layers, "n_experts": cfg.n_experts,
            "top_k": cfg.top_k, "d_ff": cfg.d_ff,
            "page_size": cfg.page_size, "num_pages": cfg.num_pages,
            "max_pages_per_seq": cfg.max_pages_per_seq,
        },
        "executables": {},
        "params": [],
    }

    # ---- weights.bin -------------------------------------------------
    params = init_params(cfg, SEED)
    blob = bytearray()
    for name, shape in param_specs(cfg):
        arr = np.asarray(params[name], np.float32)
        manifest["params"].append(
            {"name": name, "shape": list(shape), "offset": len(blob),
             "nbytes": arr.nbytes})
        blob += arr.tobytes()
    (out_dir / "weights.bin").write_bytes(bytes(blob))
    manifest["weights_sha256"] = hashlib.sha256(bytes(blob)).hexdigest()
    manifest["weights_nbytes"] = len(blob)

    # ---- decode_step variants ----------------------------------------
    flat_param_specs = [
        jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
        for _, shape in param_specs(cfg)
    ]
    for b in BATCH_VARIANTS:
        fn = decode_step_flat(cfg)
        lowered = jax.jit(fn).lower(*flat_param_specs, *example_inputs(cfg, b))
        text = to_hlo_text(lowered)
        name = f"decode_step_b{b}.hlo.txt"
        (out_dir / name).write_text(text)
        manifest["executables"][f"decode_step_b{b}"] = {
            "path": name,
            "args": (
                [{"name": n, **_spec_json(s)}
                 for (n, _), s in zip(param_specs(cfg), flat_param_specs)]
                + [{"name": n, **_spec_json(s)}
                   for n, s in zip(
                       ["ids", "pos", "page_table", "seq_lens", "kv_k",
                        "kv_v"], example_inputs(cfg, b))]
            ),
            "outputs": ["logits", "routed_experts", "kv_k", "kv_v"],
        }

    # ---- standalone kernels (micro-bench / cross-checking) -----------
    B, d, f, E, k = 4, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k
    f32, i32 = jnp.float32, jnp.int32
    moe_args = (
        jax.ShapeDtypeStruct((B, d), f32),
        jax.ShapeDtypeStruct((E, d, f), f32),
        jax.ShapeDtypeStruct((E, f, d), f32),
        jax.ShapeDtypeStruct((B, k), i32),
        jax.ShapeDtypeStruct((B, k), f32),
    )
    text = to_hlo_text(jax.jit(lambda *a: (moe_ffn(*a),)).lower(*moe_args))
    (out_dir / "moe_ffn.hlo.txt").write_text(text)
    manifest["executables"]["moe_ffn"] = {
        "path": "moe_ffn.hlo.txt",
        "args": [{"name": n, **_spec_json(s)} for n, s in zip(
            ["x", "w1", "w2", "topk_idx", "topk_w"], moe_args)],
        "outputs": ["y"],
    }

    H, hd, P, bs, mp = cfg.n_heads, cfg.head_dim, cfg.num_pages, \
        cfg.page_size, cfg.max_pages_per_seq
    pa_args = (
        jax.ShapeDtypeStruct((B, H, hd), f32),
        jax.ShapeDtypeStruct((P, bs, H, hd), f32),
        jax.ShapeDtypeStruct((P, bs, H, hd), f32),
        jax.ShapeDtypeStruct((B, mp), i32),
        jax.ShapeDtypeStruct((B,), i32),
    )
    text = to_hlo_text(
        jax.jit(lambda *a: (paged_attention(*a),)).lower(*pa_args))
    (out_dir / "paged_attention.hlo.txt").write_text(text)
    manifest["executables"]["paged_attention"] = {
        "path": "paged_attention.hlo.txt",
        "args": [{"name": n, **_spec_json(s)} for n, s in zip(
            ["q", "k_pages", "v_pages", "page_table", "seq_lens"], pa_args)],
        "outputs": ["out"],
    }

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    m = build(out)
    total = sum(p.stat().st_size for p in out.iterdir())
    print(f"wrote {len(m['executables'])} executables + "
          f"{m['weights_nbytes']} weight bytes to {out} "
          f"({total / 1e6:.1f} MB total)")


if __name__ == "__main__":
    main()
