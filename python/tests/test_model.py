"""L2 model tests: shapes, KV-cache semantics, gating, determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (ModelConfig, decode_step, decode_step_flat,
                           example_inputs, init_params, param_specs,
                           top_k_gating)

CFG = ModelConfig(vocab=64, d_model=32, n_heads=2, head_dim=16, n_layers=2,
                  n_experts=4, top_k=2, d_ff=48, page_size=4, num_pages=16,
                  max_pages_per_seq=4)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def _fresh_state(B):
    L, P, bs = CFG.n_layers, CFG.num_pages, CFG.page_size
    H, hd, mp = CFG.n_heads, CFG.head_dim, CFG.max_pages_per_seq
    kv_k = jnp.zeros((L, P, bs, H, hd), jnp.float32)
    kv_v = jnp.zeros((L, P, bs, H, hd), jnp.float32)
    # Sequence b owns pages [b*mp, (b+1)*mp).
    pt = jnp.asarray(
        np.arange(B * mp).reshape(B, mp), jnp.int32)
    return kv_k, kv_v, pt


def _run_greedy(params, prompt, steps):
    """Greedy-decode a single sequence; returns token list + final state."""
    kv_k, kv_v, pt = _fresh_state(1)
    toks = list(prompt)
    logits = None
    for t in range(len(prompt) + steps):
        cur = toks[t]
        ids = jnp.asarray([cur], jnp.int32)
        pos = jnp.asarray([t], jnp.int32)
        sl = jnp.asarray([t + 1], jnp.int32)
        logits, _, kv_k, kv_v = decode_step(
            params, CFG, ids, pos, pt, sl, kv_k, kv_v)
        if t >= len(prompt) - 1 and len(toks) < len(prompt) + steps:
            toks.append(int(jnp.argmax(logits[0])))
    return toks, kv_k, kv_v


class TestShapes:
    def test_decode_step_shapes(self, params):
        B = 3
        kv_k, kv_v, pt = _fresh_state(B)
        ids = jnp.zeros((B,), jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        sl = jnp.ones((B,), jnp.int32)
        logits, routed, k2, v2 = decode_step(
            params, CFG, ids, pos, pt, sl, kv_k, kv_v)
        assert logits.shape == (B, CFG.vocab)
        assert routed.shape == (CFG.n_layers, B, CFG.top_k)
        assert k2.shape == kv_k.shape and v2.shape == kv_v.shape

    def test_param_specs_cover_init(self):
        names = {n for n, _ in param_specs(CFG)}
        assert names == set(init_params(CFG).keys())

    def test_flat_calling_convention(self, params):
        fn = decode_step_flat(CFG)
        flat = [params[n] for n, _ in param_specs(CFG)]
        B = 2
        kv_k, kv_v, pt = _fresh_state(B)
        ids = jnp.zeros((B,), jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        sl = jnp.ones((B,), jnp.int32)
        l1, r1, _, _ = fn(*flat, ids, pos, pt, sl, kv_k, kv_v)
        l2, r2, _, _ = decode_step(params, CFG, ids, pos, pt, sl, kv_k, kv_v)
        np.testing.assert_allclose(l1, l2, rtol=1e-6)
        np.testing.assert_array_equal(r1, r2)

    def test_example_inputs_match_flat_fn(self):
        specs = example_inputs(CFG, 2)
        assert specs[0].shape == (2,)
        assert specs[4].shape == (CFG.n_layers, CFG.num_pages, CFG.page_size,
                                  CFG.n_heads, CFG.head_dim)


class TestKvSemantics:
    def test_kv_write_touches_only_own_page_slot(self, params):
        B = 2
        kv_k, kv_v, pt = _fresh_state(B)
        ids = jnp.asarray([1, 2], jnp.int32)
        pos = jnp.asarray([0, 5], jnp.int32)  # page 0 off 0; page 1 off 1
        sl = pos + 1
        _, _, k2, _ = decode_step(params, CFG, ids, pos, pt, sl, kv_k, kv_v)
        diff = np.asarray(k2 != kv_k)
        # Changed (page, offset) pairs per layer must be exactly the two
        # written slots.
        changed = {(p, o) for _, p, o in
                   zip(*np.nonzero(diff.any(axis=(3, 4))))}
        mp = CFG.max_pages_per_seq
        assert changed == {(0 * mp + 0, 0), (1 * mp + 1, 1)}

    def test_causality_future_cache_contents_ignored(self, params):
        """Poisoning pages beyond seq_len must not change logits."""
        B = 1
        kv_k, kv_v, pt = _fresh_state(B)
        ids = jnp.asarray([3], jnp.int32)
        pos = jnp.asarray([2], jnp.int32)
        sl = jnp.asarray([3], jnp.int32)
        base, _, _, _ = decode_step(params, CFG, ids, pos, pt, sl, kv_k, kv_v)
        poisoned_k = kv_k.at[:, :, :, :, :].set(0.0)
        # poison strictly-beyond-seq_len slots of owned pages
        poisoned_k = kv_k.at[:, 0, 3].set(100.0)   # logical pos 3 >= sl
        poisoned_v = kv_v.at[:, 1, 0].set(-100.0)  # logical pos 4 >= sl
        got, _, _, _ = decode_step(
            params, CFG, ids, pos, pt, sl, poisoned_k, poisoned_v)
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)

    def test_incremental_decode_matches_recomputed_cache(self, params):
        """Decoding t tokens one-by-one fills the cache so that step t+1
        gives identical logits regardless of write history order."""
        toks = [5, 9, 2, 7]
        _, kv_k, kv_v = _run_greedy(params, toks, 0)
        # Recompute same prompt in a fresh state; caches must agree on the
        # owned slots.
        _, kv_k2, kv_v2 = _run_greedy(params, toks, 0)
        np.testing.assert_allclose(kv_k, kv_k2, atol=0)
        np.testing.assert_allclose(kv_v, kv_v2, atol=0)

    def test_page_table_indirection(self, params):
        """Relocating physical pages (with contents) leaves logits fixed —
        this is the property Harvest migration relies on."""
        B = 1
        kv_k, kv_v, pt = _fresh_state(B)
        # Write 3 tokens first.
        for t, tok in enumerate([4, 8, 15]):
            ids = jnp.asarray([tok], jnp.int32)
            pos = jnp.asarray([t], jnp.int32)
            sl = jnp.asarray([t + 1], jnp.int32)
            logits, _, kv_k, kv_v = decode_step(
                params, CFG, ids, pos, pt, sl, kv_k, kv_v)
        # Move logical page 0 from physical 0 to physical 9.
        kv_k2 = kv_k.at[:, 9].set(kv_k[:, 0])
        kv_v2 = kv_v.at[:, 9].set(kv_v[:, 0])
        pt2 = pt.at[0, 0].set(9)
        ids = jnp.asarray([16], jnp.int32)
        pos = jnp.asarray([3], jnp.int32)
        sl = jnp.asarray([4], jnp.int32)
        a, _, _, _ = decode_step(params, CFG, ids, pos, pt, sl, kv_k, kv_v)
        b, _, _, _ = decode_step(params, CFG, ids, pos, pt2, sl, kv_k2, kv_v2)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


class TestGating:
    def test_topk_indices_valid_and_weights_normalised(self, params):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, CFG.d_model)), jnp.float32)
        idx, w = top_k_gating(x, params["l0.gate"], CFG.top_k)
        assert idx.shape == (8, CFG.top_k)
        assert np.all((np.asarray(idx) >= 0)
                      & (np.asarray(idx) < CFG.n_experts))
        np.testing.assert_allclose(np.asarray(w).sum(axis=1), 1.0, rtol=1e-5)

    def test_topk_picks_argmax(self, params):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(4, CFG.d_model)), jnp.float32)
        logits = np.asarray(x @ params["l0.gate"])
        idx, _ = top_k_gating(x, params["l0.gate"], 1)
        np.testing.assert_array_equal(
            np.asarray(idx)[:, 0], logits.argmax(axis=1))

    def test_routed_experts_reported_match_gating(self, params):
        B = 4
        kv_k, kv_v, pt = _fresh_state(B)
        ids = jnp.asarray([1, 2, 3, 4], jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        sl = jnp.ones((B,), jnp.int32)
        _, routed, _, _ = decode_step(
            params, CFG, ids, pos, pt, sl, kv_k, kv_v)
        assert np.all((np.asarray(routed) >= 0)
                      & (np.asarray(routed) < CFG.n_experts))


class TestDeterminism:
    def test_init_params_deterministic(self):
        a = init_params(CFG, seed=42)
        b = init_params(CFG, seed=42)
        for n in a:
            np.testing.assert_array_equal(a[n], b[n])

    def test_init_params_seed_sensitivity(self):
        a = init_params(CFG, seed=1)
        b = init_params(CFG, seed=2)
        assert not np.allclose(a["embed"], b["embed"])

    def test_greedy_decode_deterministic(self, params):
        t1, _, _ = _run_greedy(params, [7, 3], 4)
        t2, _, _ = _run_greedy(params, [7, 3], 4)
        assert t1 == t2 and len(t1) == 6
