"""Kernel-vs-reference correctness: the CORE L1 signal.

Fixed-shape unit tests plus hypothesis sweeps over shapes/dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.moe_ffn import moe_ffn
from compile.kernels.paged_attention import paged_attention

RNG = np.random.default_rng(1234)


def _moe_inputs(B, d, E, f, k, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, d)), dtype)
    w1 = jnp.asarray(rng.normal(0, d ** -0.5, size=(E, d, f)), dtype)
    w2 = jnp.asarray(rng.normal(0, f ** -0.5, size=(E, f, d)), dtype)
    idx = jnp.asarray(rng.integers(0, E, size=(B, k)), jnp.int32)
    w = rng.random((B, k)).astype(np.float32)
    w = w / w.sum(axis=1, keepdims=True)
    return x, w1, w2, idx, jnp.asarray(w, dtype)


def _attn_inputs(B, H, hd, P, bs, mp, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(P, bs, H, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(P, bs, H, hd)), dtype)
    # Each sequence gets mp distinct physical pages (disjoint across seqs
    # requires P >= B*mp; allow sharing otherwise — both are legal).
    if P >= B * mp:
        pt = rng.permutation(P)[: B * mp].reshape(B, mp)
    else:
        pt = rng.integers(0, P, size=(B, mp))
    sl = rng.integers(1, mp * bs + 1, size=(B,))
    return q, kp, vp, jnp.asarray(pt, jnp.int32), jnp.asarray(sl, jnp.int32)


class TestMoeFfn:
    def test_matches_ref_basic(self):
        args = _moe_inputs(4, 32, 8, 64, 2)
        np.testing.assert_allclose(
            moe_ffn(*args), ref.moe_ffn_ref(*args), rtol=2e-5, atol=2e-5)

    def test_single_expert_all_weight(self):
        """k=1 with weight 1.0 must equal a plain dense FFN of that expert."""
        B, d, E, f = 4, 16, 4, 32
        x, w1, w2, _, _ = _moe_inputs(B, d, E, f, 1)
        idx = jnp.full((B, 1), 2, jnp.int32)
        w = jnp.ones((B, 1), jnp.float32)
        got = moe_ffn(x, w1, w2, idx, w)
        h = x @ w1[2]
        want = (h * jax.nn.sigmoid(h)) @ w2[2]
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_zero_weights_give_zero(self):
        B, d, E, f, k = 3, 16, 4, 32, 2
        x, w1, w2, idx, _ = _moe_inputs(B, d, E, f, k)
        w = jnp.zeros((B, k), jnp.float32)
        np.testing.assert_allclose(
            moe_ffn(x, w1, w2, idx, w), jnp.zeros((B, d)), atol=1e-7)

    def test_duplicate_expert_in_topk_sums_weights(self):
        """Routing the same expert twice must behave like summed weight."""
        B, d, E, f = 2, 16, 4, 32
        x, w1, w2, _, _ = _moe_inputs(B, d, E, f, 2)
        idx = jnp.full((B, 2), 1, jnp.int32)
        w = jnp.asarray([[0.3, 0.7], [0.5, 0.5]], jnp.float32)
        got = moe_ffn(x, w1, w2, idx, w)
        idx1 = jnp.full((B, 1), 1, jnp.int32)
        w1_ = jnp.ones((B, 1), jnp.float32)
        want = moe_ffn(x, w1, w2, idx1, w1_)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_linearity_in_routing_weights(self):
        B, d, E, f, k = 4, 16, 4, 32, 2
        x, w1, w2, idx, w = _moe_inputs(B, d, E, f, k)
        got2 = moe_ffn(x, w1, w2, idx, 2.0 * w)
        want2 = 2.0 * moe_ffn(x, w1, w2, idx, w)
        np.testing.assert_allclose(got2, want2, rtol=2e-5, atol=2e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        B=st.integers(1, 8),
        d=st.sampled_from([8, 16, 64, 128]),
        E=st.sampled_from([2, 4, 8, 16]),
        f=st.sampled_from([8, 32, 128]),
        k=st.integers(1, 4),
        seed=st.integers(0, 2 ** 16),
    )
    def test_hypothesis_shape_sweep(self, B, d, E, f, k, seed):
        k = min(k, E)
        args = _moe_inputs(B, d, E, f, k, seed=seed)
        np.testing.assert_allclose(
            moe_ffn(*args), ref.moe_ffn_ref(*args), rtol=5e-5, atol=5e-5)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_hypothesis_bf16(self, seed):
        args = _moe_inputs(4, 32, 4, 64, 2, dtype=jnp.bfloat16, seed=seed)
        got = np.asarray(moe_ffn(*args), np.float32)
        want = np.asarray(ref.moe_ffn_ref(*args), np.float32)
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


class TestPagedAttention:
    def test_matches_ref_basic(self):
        args = _attn_inputs(4, 4, 16, 16, 8, 4)
        np.testing.assert_allclose(
            paged_attention(*args), ref.paged_attention_ref(*args),
            rtol=2e-5, atol=2e-5)

    def test_single_kv_entry_returns_its_value(self):
        """seq_len=1 ⇒ softmax over one position ⇒ output == v[first]."""
        B, H, hd, P, bs, mp = 2, 2, 8, 8, 4, 2
        q, kp, vp, pt, _ = _attn_inputs(B, H, hd, P, bs, mp)
        sl = jnp.ones((B,), jnp.int32)
        got = paged_attention(q, kp, vp, pt, sl)
        for b in range(B):
            want = vp[pt[b, 0], 0]
            np.testing.assert_allclose(got[b], want, rtol=1e-5, atol=1e-5)

    def test_mask_excludes_stale_pages(self):
        """Garbage beyond seq_len (stale/revoked data) must not leak in."""
        B, H, hd, P, bs, mp = 2, 2, 8, 8, 4, 2
        q, kp, vp, pt, _ = _attn_inputs(B, H, hd, P, bs, mp)
        sl = jnp.asarray([3, 5], jnp.int32)
        base = paged_attention(q, kp, vp, pt, sl)
        # Poison everything at logical positions >= seq_len.
        kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
        for b in range(B):
            for t in range(int(sl[b]), mp * bs):
                kp2[pt[b, t // bs], t % bs] = 1e4
                vp2[pt[b, t // bs], t % bs] = -1e4
        got = paged_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), pt, sl)
        np.testing.assert_allclose(got, base, rtol=1e-4, atol=1e-4)

    def test_permutation_invariance_of_page_table(self):
        """Physical page ids are arbitrary: relabeling pages (and moving
        their contents) must not change the output."""
        B, H, hd, P, bs, mp = 2, 2, 8, 8, 4, 2
        q, kp, vp, pt, sl = _attn_inputs(B, H, hd, P, bs, mp)
        perm = np.random.default_rng(7).permutation(P)
        inv = np.empty(P, np.int64)
        inv[perm] = np.arange(P)
        kp2 = jnp.asarray(np.asarray(kp)[perm])
        vp2 = jnp.asarray(np.asarray(vp)[perm])
        pt2 = jnp.asarray(inv[np.asarray(pt)], jnp.int32)
        got = paged_attention(q, kp2, vp2, pt2, sl)
        want = paged_attention(q, kp, vp, pt, sl)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_softmax_weights_bound_output(self):
        """|out| <= max |v| elementwise-ish (convex combination)."""
        args = _attn_inputs(4, 4, 16, 16, 8, 4, seed=3)
        out = np.asarray(paged_attention(*args))
        assert np.all(np.abs(out) <= np.abs(np.asarray(args[2])).max() + 1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        B=st.integers(1, 6),
        H=st.sampled_from([1, 2, 4]),
        hd=st.sampled_from([4, 8, 32]),
        bs=st.sampled_from([2, 4, 16]),
        mp=st.integers(1, 6),
        seed=st.integers(0, 2 ** 16),
    )
    def test_hypothesis_shape_sweep(self, B, H, hd, bs, mp, seed):
        P = max(B * mp, 8)
        args = _attn_inputs(B, H, hd, P, bs, mp, seed=seed)
        np.testing.assert_allclose(
            paged_attention(*args), ref.paged_attention_ref(*args),
            rtol=5e-5, atol=5e-5)

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_hypothesis_bf16(self, seed):
        args = _attn_inputs(2, 2, 8, 8, 4, 2, dtype=jnp.bfloat16, seed=seed)
        got = np.asarray(paged_attention(*args), np.float32)
        want = np.asarray(ref.paged_attention_ref(*args), np.float32)
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


class TestMoeExpertBlock:
    """expert_block chunking must agree with the all-at-once default and
    the jnp oracle (the §Perf L1.1 knob)."""

    @pytest.mark.parametrize("eb", [1, 2, 4, 8])
    def test_expert_block_matches_ref(self, eb):
        x, w1, w2, idx, w = _moe_inputs(B=5, d=32, E=8, f=16, k=2, seed=11)
        got = moe_ffn(x, w1, w2, idx, w, expert_block=eb)
        want = ref.moe_ffn_ref(x, w1, w2, idx, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_expert_block_must_divide(self):
        x, w1, w2, idx, w = _moe_inputs(B=2, d=8, E=6, f=4, k=2)
        with pytest.raises(ValueError, match="must divide"):
            moe_ffn(x, w1, w2, idx, w, expert_block=4)
