"""AOT artifact pipeline tests: manifest/weights/HLO-text integrity.

Builds into a tmp dir (does not touch ../artifacts) so pytest stays
side-effect free.
"""
import hashlib
import json
import pathlib

import numpy as np
import pytest

from compile import aot
from compile.model import ModelConfig, init_params, param_specs


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(out)
    return out, manifest


class TestManifest:
    def test_all_executables_present(self, built):
        out, m = built
        expected = {"decode_step_b1", "decode_step_b4", "moe_ffn",
                    "paged_attention"}
        assert set(m["executables"]) == expected
        for exe in m["executables"].values():
            assert (out / exe["path"]).exists()

    def test_manifest_json_round_trips(self, built):
        out, m = built
        loaded = json.loads((out / "manifest.json").read_text())
        assert loaded == json.loads(json.dumps(m))

    def test_config_matches_model_default(self, built):
        _, m = built
        cfg = ModelConfig()
        assert m["config"]["d_model"] == cfg.d_model
        assert m["config"]["n_experts"] == cfg.n_experts
        assert m["config"]["page_size"] == cfg.page_size

    def test_decode_step_arg_order(self, built):
        """Rust feeds weights first (param_specs order) then runtime args —
        the manifest must pin exactly that order."""
        _, m = built
        cfg = ModelConfig()
        names = [a["name"] for a in m["executables"]["decode_step_b4"]["args"]]
        want = [n for n, _ in param_specs(cfg)] + [
            "ids", "pos", "page_table", "seq_lens", "kv_k", "kv_v"]
        assert names == want

    def test_batch_variants_differ_only_in_batch(self, built):
        _, m = built
        a1 = {a["name"]: a for a in m["executables"]["decode_step_b1"]["args"]}
        a4 = {a["name"]: a for a in m["executables"]["decode_step_b4"]["args"]}
        assert a1["ids"]["shape"] == [1] and a4["ids"]["shape"] == [4]
        assert a1["kv_k"]["shape"] == a4["kv_k"]["shape"]


class TestWeights:
    def test_weights_bin_layout(self, built):
        out, m = built
        blob = (out / "weights.bin").read_bytes()
        assert len(blob) == m["weights_nbytes"]
        assert hashlib.sha256(blob).hexdigest() == m["weights_sha256"]
        # offsets are contiguous and cover the blob
        end = 0
        for p in m["params"]:
            assert p["offset"] == end
            end += p["nbytes"]
        assert end == len(blob)

    def test_weights_match_init_params(self, built):
        out, m = built
        cfg = ModelConfig()
        params = init_params(cfg, m["seed"])
        blob = (out / "weights.bin").read_bytes()
        for p in m["params"]:
            arr = np.frombuffer(
                blob, np.float32, count=p["nbytes"] // 4,
                offset=p["offset"]).reshape(p["shape"])
            np.testing.assert_array_equal(arr, np.asarray(params[p["name"]]))


class TestHloText:
    def test_hlo_text_parses_as_module(self, built):
        out, m = built
        for exe in m["executables"].values():
            text = (out / exe["path"]).read_text()
            assert text.startswith("HloModule"), exe["path"]
            assert "ENTRY" in text

    def test_hlo_has_no_mosaic_custom_call(self, built):
        """interpret=True must have erased all Mosaic custom-calls — a
        tpu_custom_call in the text would be unloadable on CPU PJRT."""
        out, m = built
        for exe in m["executables"].values():
            text = (out / exe["path"]).read_text()
            assert "tpu_custom_call" not in text, exe["path"]
            assert "mosaic" not in text.lower(), exe["path"]

    def test_decode_step_parameter_count(self, built):
        out, m = built
        text = (out / "decode_step_b4.hlo.txt").read_text()
        n_args = len(m["executables"]["decode_step_b4"]["args"])
        # every arg appears as a parameter( in the entry computation
        assert text.count("parameter(") >= n_args
