//! Config system: a TOML-subset parser plus the typed deployment
//! configuration the launcher (`rust/src/main.rs`) consumes.
//!
//! The image's offline crate set has no `toml`/`serde`, so — like
//! [`crate::util::json`] — the parser is hand-rolled. It supports the
//! subset real deployments of this repo need:
//!
//! * `[section]` and `[section.sub]` headers
//! * `key = value` with string / integer / float / bool / array values
//! * `#` comments, blank lines
//!
//! A [`DeploymentConfig`] describes a full launch: node shape, harvest
//! controller settings, the serving workload, and which paper workload
//! (MoE expert offload or KV-cache offload) to run. `presets()` returns
//! the configurations used by the examples and benches, and every preset
//! round-trips through the parser (tested below).

use crate::cluster::{ClusterSpec, RouterPolicy, SchedulerSpec};
use crate::control::{AdmissionConfig, AdmissionPolicy, SloConfig};
use crate::harvest::{HarvestConfig, MigConfig, PlacementSpec, VictimPolicy};
use crate::kv::KvConfig;
use crate::memsim::{FabricKind, GpuSpec, NodeFabricKind, NodeSpec};
use crate::moe::{find_kv_model, find_moe_model};
use crate::server::WorkloadSpec;
use crate::tenantsim::{TenantFleet, TenantMix, TenantPriority};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};

const GIB: u64 = 1 << 30;

// ---------------------------------------------------------------------
// TOML-subset value + parser
// ---------------------------------------------------------------------

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let i = self.as_i64()?;
        u64::try_from(i).map_err(|_| anyhow!("expected non-negative integer, got {i}"))
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_array(&self) -> Result<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }
}

/// A parsed TOML-subset document: dotted-path key → value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse `text`. Errors carry the 1-based line number.
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section header", ln + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", ln + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", ln + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", ln + 1);
            }
            let value = parse_value(value.trim())
                .with_context(|| format!("line {}: bad value for `{key}`", ln + 1))?;
            let path =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            if doc.entries.insert(path.clone(), value).is_some() {
                bail!("line {}: duplicate key `{path}`", ln + 1);
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn require(&self, path: &str) -> Result<&TomlValue> {
        self.get(path).ok_or_else(|| anyhow!("missing config key `{path}`"))
    }

    /// All keys under `section.` (for validation / introspection).
    pub fn section_keys<'a>(&'a self, section: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let prefix = format!("{section}.");
        self.entries.keys().filter(move |k| k.starts_with(&prefix)).map(|k| k.as_str())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> + '_ {
        self.entries.keys().map(|k| k.as_str())
    }

    fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(|v| v.as_str().ok())
            .map(str::to_string)
            .unwrap_or_else(|| default.to_string())
    }

    fn u64_or(&self, path: &str, default: u64) -> Result<u64> {
        match self.get(path) {
            Some(v) => v.as_u64().with_context(|| format!("key `{path}`")),
            None => Ok(default),
        }
    }

    fn usize_or(&self, path: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(path, default as u64)? as usize)
    }

    fn f64_or(&self, path: &str, default: f64) -> Result<f64> {
        match self.get(path) {
            Some(v) => v.as_f64().with_context(|| format!("key `{path}`")),
            None => Ok(default),
        }
    }

    fn bool_or(&self, path: &str, default: bool) -> Result<bool> {
        match self.get(path) {
            Some(v) => v.as_bool().with_context(|| format!("key `{path}`")),
            None => Ok(default),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string literal.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        if inner.contains('"') {
            bail!("embedded quote in string");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = split_top_level(inner)?
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    // numbers: underscores allowed as digit separators, like real TOML
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    } else if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    bail!("cannot parse value `{s}`")
}

/// Split a comma-separated list, respecting nested `[...]` and strings.
fn split_top_level(s: &str) -> Result<Vec<&str>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or_else(|| anyhow!("unbalanced `]`"))?
            }
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str || depth != 0 {
        bail!("unbalanced array or string");
    }
    out.push(&s[start..]);
    Ok(out)
}

// ---------------------------------------------------------------------
// Typed deployment config
// ---------------------------------------------------------------------

/// Which paper workload a launch runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// §4: MoE expert offload through the CGOPipe-style pipeline.
    MoeOffload,
    /// §5: KV-cache offload through the SimEngine decode loop.
    KvOffload,
    /// End-to-end: real PJRT compute on the AOT tiny model.
    RealServe,
}

impl WorkloadKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "moe" | "moe-offload" => Ok(WorkloadKind::MoeOffload),
            "kv" | "kv-offload" => Ok(WorkloadKind::KvOffload),
            "real" | "serve" | "real-serve" => Ok(WorkloadKind::RealServe),
            other => bail!("unknown workload kind `{other}` (moe | kv | real)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::MoeOffload => "moe",
            WorkloadKind::KvOffload => "kv",
            WorkloadKind::RealServe => "real",
        }
    }
}

/// A full launch description.
#[derive(Debug, Clone)]
pub struct DeploymentConfig {
    pub name: String,
    pub workload: WorkloadKind,
    /// Node shape.
    pub n_gpus: usize,
    pub hbm_gib: u64,
    pub fabric: FabricKind,
    /// CXL memory-expander capacity per node (0 = tier absent).
    pub cxl_gib: u64,
    /// Cluster shape: how many nodes serve behind the router (1 = the
    /// single-node stack, no router in the path).
    pub nodes: usize,
    pub router_policy: RouterPolicy,
    /// Inter-node link class (`cluster.fabric`).
    pub node_fabric: NodeFabricKind,
    /// Affinity spill threshold (queue depth on the prefix holder).
    pub spill_queue_depth: usize,
    /// Shed threshold per node (0 = never shed).
    pub shed_queue_depth: usize,
    /// Harvest controller.
    pub harvest_enabled: bool,
    pub victim_policy: VictimPolicy,
    pub reserve_gib: u64,
    pub mig_cache_gib: Option<u64>,
    /// Pressure-revoked lossy leases demote to host instead of dropping.
    pub demote_to_host: bool,
    /// Harvest placement policy (`harvest.placement`): best-fit |
    /// first-available | locality | stability | interference.
    pub placement: String,
    /// Admission policy (`slo.admission`): `"static"` keeps the legacy
    /// `cluster.shed_queue_depth` gate; `"occupancy"` arms the SLO
    /// control plane ([`crate::control::AdmissionController`]).
    pub slo_admission: String,
    /// p99 TTFT target in milliseconds (`slo.ttft_p99_ms`).
    pub slo_ttft_p99_ms: u64,
    /// Goodput floor in completed tokens/sec (`slo.goodput_floor_tps`;
    /// 0 disables the floor).
    pub slo_goodput_floor_tps: f64,
    /// Sliding stability window in milliseconds (`slo.window_ms`).
    pub slo_window_ms: u64,
    /// Hysteresis watermarks in percent of pressure (occupancy or
    /// tenant-held), enter/exit the Pressured state.
    pub slo_high_watermark_pct: u32,
    pub slo_low_watermark_pct: u32,
    /// Virtual-time tracer ring capacity in events (`obs.ring_cap`);
    /// bounds the memory a `serve --trace` run retains.
    pub obs_ring_cap: usize,
    /// Wall-clock per-phase stepper profiling (`obs.profile`).
    pub obs_profile: bool,
    /// Arm the SLO flight recorder during traced runs (`obs.flight`).
    pub obs_flight: bool,
    /// Shed count within one SLO window that triggers a flight dump
    /// (`obs.shed_burst`).
    pub obs_shed_burst: usize,
    /// Arm per-request latency attribution ledgers (`obs.attribution`);
    /// observation-only — the served schedule is bit-for-bit identical.
    /// `serve --report` arms this implicitly.
    pub obs_attribution: bool,
    /// Cold-tier SSD arena capacity per node (`[coldtier]`; 0 = tier
    /// absent). When present the demotion ladder bottoms out on paged
    /// NVMe instead of dropping leases.
    pub ssd_gib: u64,
    /// Cold-tier pager page size in KiB (allocations are padded up).
    pub ssd_page_kib: u64,
    /// In-place compression target, percent of original size (1..=99).
    pub compress_ratio_pct: u32,
    /// Pressure ladder: try compressing a lease in place before
    /// demoting it, and demote before dropping.
    pub compress_before_demote: bool,
    /// Closed-loop co-tenant actors (`[tenants]`; disabled by default —
    /// pressure then comes only from replay timelines, as pre-fleet).
    pub tenants: TenantMix,
    /// Per-node overrides (`[tenants.node<k>]`) for multi-node runs.
    pub tenant_overrides: Vec<(usize, TenantMix)>,
    /// MoE workload parameters (§4.4 defaults).
    pub moe_model: String,
    pub offload_fraction: f64,
    pub micro_batch_tokens: usize,
    pub n_micro_batches: usize,
    pub max_new_tokens: u32,
    /// KV workload parameters (§5.3 defaults).
    pub kv_model: String,
    pub block_tokens: u32,
    pub local_capacity_blocks: usize,
    pub decode_slots: usize,
    pub max_running: usize,
    pub scheduler: String,
    pub quantum: u32,
    /// Request workload.
    pub n_requests: usize,
    pub mean_prompt_tokens: f64,
    pub shared_prefix_fraction: f64,
    /// Mean request inter-arrival gap in microseconds (0 = burst).
    pub mean_interarrival_us: u64,
    /// Distinct shared prefixes (sessions) in the workload.
    pub prefix_groups: usize,
    pub seed: u64,
}

impl Default for DeploymentConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            workload: WorkloadKind::MoeOffload,
            n_gpus: 2,
            hbm_gib: 80,
            fabric: FabricKind::FullMesh,
            cxl_gib: 0,
            nodes: 1,
            router_policy: RouterPolicy::LeastLoaded,
            node_fabric: NodeFabricKind::Rdma,
            spill_queue_depth: 16,
            shed_queue_depth: 0,
            harvest_enabled: true,
            victim_policy: VictimPolicy::Lifo,
            reserve_gib: 0,
            mig_cache_gib: None,
            demote_to_host: false,
            placement: "best-fit".into(),
            slo_admission: "static".into(),
            slo_ttft_p99_ms: 50,
            slo_goodput_floor_tps: 0.0,
            slo_window_ms: 20,
            slo_high_watermark_pct: 90,
            slo_low_watermark_pct: 70,
            obs_ring_cap: 65_536,
            obs_profile: false,
            obs_flight: true,
            obs_shed_burst: 4,
            obs_attribution: false,
            ssd_gib: 0,
            ssd_page_kib: 2048,
            compress_ratio_pct: 50,
            compress_before_demote: false,
            tenants: TenantMix::default(),
            tenant_overrides: Vec::new(),
            moe_model: "Qwen2-MoE".into(),
            offload_fraction: 0.5,
            micro_batch_tokens: 324,
            n_micro_batches: 14,
            max_new_tokens: 32,
            kv_model: "Kimi-K2".into(),
            block_tokens: 16,
            local_capacity_blocks: 2048,
            decode_slots: 32,
            max_running: 64,
            scheduler: "fcfs".into(),
            quantum: 4,
            n_requests: 64,
            mean_prompt_tokens: 180.0,
            shared_prefix_fraction: 0.0,
            mean_interarrival_us: 0,
            prefix_groups: 1,
            seed: 0,
        }
    }
}

fn fabric_from_str(s: &str) -> Result<FabricKind> {
    match s {
        "mesh" | "full-mesh" => Ok(FabricKind::FullMesh),
        "nvswitch" => Ok(FabricKind::NvSwitch),
        "ring" => Ok(FabricKind::Ring),
        other => bail!("unknown fabric `{other}` (mesh | nvswitch | ring)"),
    }
}

fn fabric_name(f: FabricKind) -> &'static str {
    match f {
        FabricKind::FullMesh => "mesh",
        FabricKind::NvSwitch => "nvswitch",
        FabricKind::Ring => "ring",
    }
}

/// Keys a `[tenants]` (or `[tenants.node<k>]`) section accepts.
const TENANT_KEYS: &[&str] = &[
    "enabled",
    "training",
    "inference",
    "batch",
    "training_gib",
    "activation_gib",
    "host_gib",
    "collective_mib",
    "step_period_us",
    "inference_target",
    "batch_gib",
    "batch_priority",
    "seed",
];

/// Parse one tenant-mix section; unset keys fall back to `base` (the
/// built-in defaults for `[tenants]`, the fleet-wide mix for per-node
/// override sections — an override only names what it changes).
fn tenant_mix(doc: &TomlDoc, section: &str, base: &TenantMix) -> Result<TenantMix> {
    let p = |k: &str| format!("{section}.{k}");
    Ok(TenantMix {
        enabled: doc.bool_or(&p("enabled"), base.enabled)?,
        training: doc.usize_or(&p("training"), base.training)?,
        inference: doc.usize_or(&p("inference"), base.inference)?,
        batch: doc.usize_or(&p("batch"), base.batch)?,
        training_gib: doc.u64_or(&p("training_gib"), base.training_gib)?,
        activation_gib: doc.u64_or(&p("activation_gib"), base.activation_gib)?,
        host_gib: doc.u64_or(&p("host_gib"), base.host_gib)?,
        collective_mib: doc.u64_or(&p("collective_mib"), base.collective_mib)?,
        step_period_us: doc.u64_or(&p("step_period_us"), base.step_period_us)?,
        inference_target: doc.f64_or(&p("inference_target"), base.inference_target)?,
        batch_gib: doc.u64_or(&p("batch_gib"), base.batch_gib)?,
        batch_priority: TenantPriority::parse(
            &doc.str_or(&p("batch_priority"), base.batch_priority.name()),
        )?,
        seed: doc.u64_or(&p("seed"), base.seed)?,
    })
}

fn emit_tenant_mix(s: &mut String, header: &str, m: &TenantMix) {
    s.push_str(&format!("[{header}]\n"));
    s.push_str(&format!("enabled = {}\n", m.enabled));
    s.push_str(&format!("training = {}\n", m.training));
    s.push_str(&format!("inference = {}\n", m.inference));
    s.push_str(&format!("batch = {}\n", m.batch));
    s.push_str(&format!("training_gib = {}\n", m.training_gib));
    s.push_str(&format!("activation_gib = {}\n", m.activation_gib));
    s.push_str(&format!("host_gib = {}\n", m.host_gib));
    s.push_str(&format!("collective_mib = {}\n", m.collective_mib));
    s.push_str(&format!("step_period_us = {}\n", m.step_period_us));
    s.push_str(&format!("inference_target = {:?}\n", m.inference_target));
    s.push_str(&format!("batch_gib = {}\n", m.batch_gib));
    s.push_str(&format!("batch_priority = \"{}\"\n", m.batch_priority.name()));
    s.push_str(&format!("seed = {}\n", m.seed));
}

impl DeploymentConfig {
    /// Parse from TOML-subset text. Unknown keys are rejected so typos
    /// fail loudly rather than silently falling back to defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        const KNOWN: &[&str] = &[
            "name",
            "workload",
            "node.gpus",
            "node.hbm_gib",
            "node.fabric",
            "node.cxl_gib",
            "cluster.nodes",
            "cluster.router_policy",
            "cluster.fabric",
            "cluster.spill_queue_depth",
            "cluster.shed_queue_depth",
            "harvest.enabled",
            "harvest.victim_policy",
            "harvest.reserve_gib",
            "harvest.mig_cache_gib",
            "harvest.demote_to_host",
            "harvest.placement",
            "slo.admission",
            "slo.ttft_p99_ms",
            "slo.goodput_floor_tps",
            "slo.window_ms",
            "slo.high_watermark_pct",
            "slo.low_watermark_pct",
            "obs.ring_cap",
            "obs.profile",
            "obs.flight",
            "obs.shed_burst",
            "coldtier.ssd_gib",
            "coldtier.page_kib",
            "coldtier.compress_ratio_pct",
            "coldtier.compress_before_demote",
            "moe.model",
            "moe.offload_fraction",
            "moe.micro_batch_tokens",
            "moe.n_micro_batches",
            "moe.max_new_tokens",
            "kv.model",
            "kv.block_tokens",
            "kv.local_capacity_blocks",
            "server.decode_slots",
            "server.max_running",
            "server.scheduler",
            "server.quantum",
            "requests.n",
            "requests.mean_prompt_tokens",
            "requests.shared_prefix_fraction",
            "requests.mean_interarrival_us",
            "requests.prefix_groups",
            "requests.seed",
        ];
        for key in doc.keys() {
            // `[tenants]` / `[tenants.node<k>]` sections are validated
            // field-by-field (the node index is data, not grammar).
            if let Some(rest) = key.strip_prefix("tenants.") {
                let (scope, field) = match rest.split_once('.') {
                    Some((node, field)) => (Some(node), field),
                    None => (None, rest),
                };
                if let Some(node) = scope {
                    if node.strip_prefix("node").and_then(|n| n.parse::<usize>().ok()).is_none()
                    {
                        bail!(
                            "unknown config key `{key}` (per-node tenant overrides are \
                             `[tenants.node<k>]`)"
                        );
                    }
                }
                if !TENANT_KEYS.contains(&field) {
                    bail!("unknown config key `{key}`");
                }
                continue;
            }
            if !KNOWN.contains(&key) {
                bail!("unknown config key `{key}`");
            }
        }
        let d = DeploymentConfig::default();
        let mut cfg = DeploymentConfig {
            name: doc.str_or("name", &d.name),
            workload: WorkloadKind::parse(&doc.str_or("workload", d.workload.name()))?,
            n_gpus: doc.usize_or("node.gpus", d.n_gpus)?,
            hbm_gib: doc.u64_or("node.hbm_gib", d.hbm_gib)?,
            fabric: fabric_from_str(&doc.str_or("node.fabric", fabric_name(d.fabric)))?,
            cxl_gib: doc.u64_or("node.cxl_gib", d.cxl_gib)?,
            nodes: doc.usize_or("cluster.nodes", d.nodes)?,
            router_policy: RouterPolicy::parse(
                &doc.str_or("cluster.router_policy", d.router_policy.name()),
            )?,
            node_fabric: NodeFabricKind::parse(
                &doc.str_or("cluster.fabric", d.node_fabric.name()),
            )?,
            spill_queue_depth: doc.usize_or("cluster.spill_queue_depth", d.spill_queue_depth)?,
            shed_queue_depth: doc.usize_or("cluster.shed_queue_depth", d.shed_queue_depth)?,
            harvest_enabled: doc.bool_or("harvest.enabled", d.harvest_enabled)?,
            victim_policy: VictimPolicy::parse(
                &doc.str_or("harvest.victim_policy", d.victim_policy.name()),
            )?,
            reserve_gib: doc.u64_or("harvest.reserve_gib", d.reserve_gib)?,
            mig_cache_gib: match doc.get("harvest.mig_cache_gib") {
                Some(v) => Some(v.as_u64().context("key `harvest.mig_cache_gib`")?),
                None => None,
            },
            demote_to_host: doc.bool_or("harvest.demote_to_host", d.demote_to_host)?,
            placement: doc.str_or("harvest.placement", &d.placement),
            slo_admission: doc.str_or("slo.admission", &d.slo_admission),
            slo_ttft_p99_ms: doc.u64_or("slo.ttft_p99_ms", d.slo_ttft_p99_ms)?,
            slo_goodput_floor_tps: doc
                .f64_or("slo.goodput_floor_tps", d.slo_goodput_floor_tps)?,
            slo_window_ms: doc.u64_or("slo.window_ms", d.slo_window_ms)?,
            slo_high_watermark_pct: doc
                .u64_or("slo.high_watermark_pct", d.slo_high_watermark_pct as u64)?
                as u32,
            slo_low_watermark_pct: doc
                .u64_or("slo.low_watermark_pct", d.slo_low_watermark_pct as u64)?
                as u32,
            obs_ring_cap: doc.usize_or("obs.ring_cap", d.obs_ring_cap)?,
            obs_profile: doc.bool_or("obs.profile", d.obs_profile)?,
            obs_flight: doc.bool_or("obs.flight", d.obs_flight)?,
            obs_shed_burst: doc.usize_or("obs.shed_burst", d.obs_shed_burst)?,
            obs_attribution: doc.bool_or("obs.attribution", d.obs_attribution)?,
            ssd_gib: doc.u64_or("coldtier.ssd_gib", d.ssd_gib)?,
            ssd_page_kib: doc.u64_or("coldtier.page_kib", d.ssd_page_kib)?,
            compress_ratio_pct: doc
                .u64_or("coldtier.compress_ratio_pct", d.compress_ratio_pct as u64)?
                as u32,
            compress_before_demote: doc
                .bool_or("coldtier.compress_before_demote", d.compress_before_demote)?,
            tenants: tenant_mix(&doc, "tenants", &d.tenants)?,
            tenant_overrides: Vec::new(), // filled below (needs the base mix)
            moe_model: doc.str_or("moe.model", &d.moe_model),
            offload_fraction: doc.f64_or("moe.offload_fraction", d.offload_fraction)?,
            micro_batch_tokens: doc.usize_or("moe.micro_batch_tokens", d.micro_batch_tokens)?,
            n_micro_batches: doc.usize_or("moe.n_micro_batches", d.n_micro_batches)?,
            max_new_tokens: doc.u64_or("moe.max_new_tokens", d.max_new_tokens as u64)? as u32,
            kv_model: doc.str_or("kv.model", &d.kv_model),
            block_tokens: doc.u64_or("kv.block_tokens", d.block_tokens as u64)? as u32,
            local_capacity_blocks: doc
                .usize_or("kv.local_capacity_blocks", d.local_capacity_blocks)?,
            decode_slots: doc.usize_or("server.decode_slots", d.decode_slots)?,
            max_running: doc.usize_or("server.max_running", d.max_running)?,
            scheduler: doc.str_or("server.scheduler", &d.scheduler),
            quantum: doc.u64_or("server.quantum", d.quantum as u64)? as u32,
            n_requests: doc.usize_or("requests.n", d.n_requests)?,
            mean_prompt_tokens: doc.f64_or("requests.mean_prompt_tokens", d.mean_prompt_tokens)?,
            shared_prefix_fraction: doc
                .f64_or("requests.shared_prefix_fraction", d.shared_prefix_fraction)?,
            mean_interarrival_us: doc
                .u64_or("requests.mean_interarrival_us", d.mean_interarrival_us)?,
            prefix_groups: doc.usize_or("requests.prefix_groups", d.prefix_groups)?,
            seed: doc.u64_or("requests.seed", d.seed)?,
        };
        let node_ids: BTreeSet<usize> = doc
            .keys()
            .filter_map(|k| k.strip_prefix("tenants.node"))
            .filter_map(|rest| rest.split_once('.'))
            .filter_map(|(idx, _)| idx.parse::<usize>().ok())
            .collect();
        for i in node_ids {
            let mix = tenant_mix(&doc, &format!("tenants.node{i}"), &cfg.tenants)?;
            cfg.tenant_overrides.push((i, mix));
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    /// Sanity-check parameter ranges and model names.
    pub fn validate(&self) -> Result<()> {
        if self.n_gpus < 2 {
            bail!("node.gpus must be >= 2 (need at least one peer)");
        }
        if self.hbm_gib == 0 {
            bail!("node.hbm_gib must be > 0");
        }
        if !(0.0..=1.0).contains(&self.offload_fraction) {
            bail!("moe.offload_fraction must be in [0, 1]");
        }
        if !(0.0..=1.0).contains(&self.shared_prefix_fraction) {
            bail!("requests.shared_prefix_fraction must be in [0, 1]");
        }
        if self.workload == WorkloadKind::MoeOffload && find_moe_model(&self.moe_model).is_none() {
            bail!("unknown MoE model `{}` (see Table 1 registry)", self.moe_model);
        }
        if self.workload == WorkloadKind::KvOffload && find_kv_model(&self.kv_model).is_none() {
            bail!("unknown KV model `{}` (see §5.3 registry)", self.kv_model);
        }
        // One source of truth for scheduler / placement / admission
        // spellings.
        SchedulerSpec::parse(&self.scheduler, self.quantum)?;
        PlacementSpec::parse(&self.placement)?;
        self.admission_policy()?;
        if !(1..=100).contains(&self.slo_high_watermark_pct)
            || !(1..=100).contains(&self.slo_low_watermark_pct)
        {
            bail!("slo watermarks must be in 1..=100");
        }
        if self.slo_low_watermark_pct >= self.slo_high_watermark_pct {
            bail!(
                "slo.low_watermark_pct ({}) must be below slo.high_watermark_pct ({})",
                self.slo_low_watermark_pct,
                self.slo_high_watermark_pct
            );
        }
        if self.slo_ttft_p99_ms == 0 || self.slo_window_ms == 0 {
            bail!("slo.ttft_p99_ms and slo.window_ms must be > 0");
        }
        if self.slo_goodput_floor_tps < 0.0 {
            bail!("slo.goodput_floor_tps must be >= 0");
        }
        if self.obs_ring_cap == 0 {
            bail!("obs.ring_cap must be > 0");
        }
        if self.obs_shed_burst == 0 {
            bail!("obs.shed_burst must be > 0");
        }
        if self.decode_slots == 0 || self.max_running == 0 {
            bail!("server.decode_slots and server.max_running must be > 0");
        }
        if self.nodes == 0 {
            bail!("cluster.nodes must be >= 1");
        }
        if self.prefix_groups == 0 {
            bail!("requests.prefix_groups must be >= 1");
        }
        if self.compress_ratio_pct == 0 || self.compress_ratio_pct > 99 {
            bail!("coldtier.compress_ratio_pct must be in 1..=99");
        }
        if self.ssd_page_kib == 0 {
            bail!("coldtier.page_kib must be > 0");
        }
        for (label, mix) in std::iter::once((None, &self.tenants))
            .chain(self.tenant_overrides.iter().map(|(i, m)| (Some(*i), m)))
        {
            if !(0.0..=1.0).contains(&mix.inference_target) {
                match label {
                    None => bail!("tenants.inference_target must be in [0, 1]"),
                    Some(i) => bail!("tenants.node{i}.inference_target must be in [0, 1]"),
                }
            }
            if mix.enabled && mix.step_period_us == 0 {
                bail!("tenants.step_period_us must be > 0");
            }
        }
        for (i, _) in &self.tenant_overrides {
            if *i >= self.nodes {
                bail!(
                    "tenants.node{i} override names a node outside the cluster \
                     (cluster.nodes = {})",
                    self.nodes
                );
            }
        }
        Ok(())
    }

    /// Serialize back to TOML-subset text (round-trips through
    /// [`Self::from_toml`]).
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("name = \"{}\"\n", self.name));
        s.push_str(&format!("workload = \"{}\"\n\n", self.workload.name()));
        s.push_str("[node]\n");
        s.push_str(&format!("gpus = {}\n", self.n_gpus));
        s.push_str(&format!("hbm_gib = {}\n", self.hbm_gib));
        s.push_str(&format!("fabric = \"{}\"\n", fabric_name(self.fabric)));
        if self.cxl_gib > 0 {
            s.push_str(&format!("cxl_gib = {}\n", self.cxl_gib));
        }
        s.push('\n');
        s.push_str("[cluster]\n");
        s.push_str(&format!("nodes = {}\n", self.nodes));
        s.push_str(&format!("router_policy = \"{}\"\n", self.router_policy.name()));
        s.push_str(&format!("fabric = \"{}\"\n", self.node_fabric.name()));
        s.push_str(&format!("spill_queue_depth = {}\n", self.spill_queue_depth));
        s.push_str(&format!("shed_queue_depth = {}\n\n", self.shed_queue_depth));
        s.push_str("[harvest]\n");
        s.push_str(&format!("enabled = {}\n", self.harvest_enabled));
        s.push_str(&format!("victim_policy = \"{}\"\n", self.victim_policy.name()));
        s.push_str(&format!("reserve_gib = {}\n", self.reserve_gib));
        if let Some(gib) = self.mig_cache_gib {
            s.push_str(&format!("mig_cache_gib = {gib}\n"));
        }
        s.push_str(&format!("demote_to_host = {}\n", self.demote_to_host));
        s.push_str(&format!("placement = \"{}\"\n", self.placement));
        s.push('\n');
        s.push_str("[slo]\n");
        s.push_str(&format!("admission = \"{}\"\n", self.slo_admission));
        s.push_str(&format!("ttft_p99_ms = {}\n", self.slo_ttft_p99_ms));
        s.push_str(&format!("goodput_floor_tps = {:?}\n", self.slo_goodput_floor_tps));
        s.push_str(&format!("window_ms = {}\n", self.slo_window_ms));
        s.push_str(&format!("high_watermark_pct = {}\n", self.slo_high_watermark_pct));
        s.push_str(&format!("low_watermark_pct = {}\n", self.slo_low_watermark_pct));
        s.push('\n');
        s.push_str("[obs]\n");
        s.push_str(&format!("ring_cap = {}\n", self.obs_ring_cap));
        s.push_str(&format!("profile = {}\n", self.obs_profile));
        s.push_str(&format!("flight = {}\n", self.obs_flight));
        s.push_str(&format!("shed_burst = {}\n", self.obs_shed_burst));
        s.push_str(&format!("attribution = {}\n", self.obs_attribution));
        s.push('\n');
        s.push_str("[coldtier]\n");
        s.push_str(&format!("ssd_gib = {}\n", self.ssd_gib));
        s.push_str(&format!("page_kib = {}\n", self.ssd_page_kib));
        s.push_str(&format!("compress_ratio_pct = {}\n", self.compress_ratio_pct));
        s.push_str(&format!("compress_before_demote = {}\n", self.compress_before_demote));
        s.push('\n');
        emit_tenant_mix(&mut s, "tenants", &self.tenants);
        for (i, mix) in &self.tenant_overrides {
            s.push('\n');
            emit_tenant_mix(&mut s, &format!("tenants.node{i}"), mix);
        }
        s.push('\n');
        s.push_str("[moe]\n");
        s.push_str(&format!("model = \"{}\"\n", self.moe_model));
        s.push_str(&format!("offload_fraction = {:?}\n", self.offload_fraction));
        s.push_str(&format!("micro_batch_tokens = {}\n", self.micro_batch_tokens));
        s.push_str(&format!("n_micro_batches = {}\n", self.n_micro_batches));
        s.push_str(&format!("max_new_tokens = {}\n\n", self.max_new_tokens));
        s.push_str("[kv]\n");
        s.push_str(&format!("model = \"{}\"\n", self.kv_model));
        s.push_str(&format!("block_tokens = {}\n", self.block_tokens));
        s.push_str(&format!("local_capacity_blocks = {}\n\n", self.local_capacity_blocks));
        s.push_str("[server]\n");
        s.push_str(&format!("decode_slots = {}\n", self.decode_slots));
        s.push_str(&format!("max_running = {}\n", self.max_running));
        s.push_str(&format!("scheduler = \"{}\"\n", self.scheduler));
        s.push_str(&format!("quantum = {}\n\n", self.quantum));
        s.push_str("[requests]\n");
        s.push_str(&format!("n = {}\n", self.n_requests));
        s.push_str(&format!("mean_prompt_tokens = {:?}\n", self.mean_prompt_tokens));
        s.push_str(&format!("shared_prefix_fraction = {:?}\n", self.shared_prefix_fraction));
        s.push_str(&format!("mean_interarrival_us = {}\n", self.mean_interarrival_us));
        s.push_str(&format!("prefix_groups = {}\n", self.prefix_groups));
        s.push_str(&format!("seed = {}\n", self.seed));
        s
    }

    // -- Materialization into the runtime types --

    pub fn node_spec(&self) -> NodeSpec {
        let mut spec = NodeSpec::nvlink_domain(self.n_gpus);
        spec.fabric = self.fabric;
        for g in &mut spec.gpus {
            *g = GpuSpec { hbm_bytes: self.hbm_gib * GIB, ..GpuSpec::default() };
        }
        if self.cxl_gib > 0 {
            spec = spec.with_cxl(self.cxl_gib * GIB);
        }
        if self.ssd_gib > 0 {
            spec = spec.with_ssd(self.ssd_gib * GIB);
        }
        spec
    }

    /// Cluster shape for the multi-node serving path (meaningful for any
    /// `nodes >= 1`; the single-node stack is a 1-node cluster).
    pub fn cluster_spec(&self) -> ClusterSpec {
        ClusterSpec {
            nodes: self.nodes,
            node: self.node_spec(),
            harvest: self.harvest_config(),
            fabric: self.node_fabric,
            router: self.router_policy,
            spill_queue_depth: self.spill_queue_depth,
            shed_queue_depth: if self.shed_queue_depth == 0 {
                usize::MAX
            } else {
                self.shed_queue_depth
            },
            // Both spellings are range-checked by `validate`, so a
            // validated config cannot fail here.
            admission: self
                .admission_policy()
                .expect("slo.admission validated by DeploymentConfig::validate"),
            placement: self
                .placement_spec()
                .expect("harvest.placement validated by DeploymentConfig::validate"),
            tenants: Some(self.tenants.clone()),
            tenant_overrides: self.tenant_overrides.iter().cloned().collect(),
        }
    }

    /// The mix node 0 effectively runs: its `[tenants.node0]` override
    /// when present, else the fleet-wide `[tenants]` mix.
    pub fn node0_tenant_mix(&self) -> &TenantMix {
        self.tenant_overrides
            .iter()
            .find(|(i, _)| *i == 0)
            .map(|(_, m)| m)
            .unwrap_or(&self.tenants)
    }

    /// The co-tenant fleet a single-node launch runs (None when the mix
    /// is disabled). Multi-node launches build per-node fleets from
    /// [`DeploymentConfig::cluster_spec`] instead.
    pub fn tenant_fleet(&self) -> Option<TenantFleet> {
        let mix = self.node0_tenant_mix();
        let fleet = TenantFleet::from_mix(mix, self.n_gpus, self.hbm_gib * GIB, 0);
        (!fleet.is_empty()).then_some(fleet)
    }

    /// The per-node decode scheduler.
    pub fn scheduler_spec(&self) -> Result<SchedulerSpec> {
        SchedulerSpec::parse(&self.scheduler, self.quantum)
    }

    /// The harvest placement policy spec (`harvest.placement`).
    pub fn placement_spec(&self) -> Result<PlacementSpec> {
        PlacementSpec::parse(&self.placement)
    }

    /// The admission policy serving runs (`[slo]`). `"static"` maps
    /// `cluster.shed_queue_depth` onto the legacy router-side gate
    /// (0 = never shed); `"occupancy"` arms the node-side SLO
    /// controller with the section's targets and watermarks.
    pub fn admission_policy(&self) -> Result<AdmissionPolicy> {
        match self.slo_admission.as_str() {
            "static" => Ok(AdmissionPolicy::StaticDepth {
                shed_queue_depth: if self.shed_queue_depth == 0 {
                    usize::MAX
                } else {
                    self.shed_queue_depth
                },
            }),
            "occupancy" => Ok(AdmissionPolicy::SloOccupancy(AdmissionConfig {
                slo: SloConfig {
                    ttft_p99_ns: self.slo_ttft_p99_ms * 1_000_000,
                    goodput_floor_tps: self.slo_goodput_floor_tps,
                    window_ns: self.slo_window_ms * 1_000_000,
                },
                high_watermark_pct: self.slo_high_watermark_pct,
                low_watermark_pct: self.slo_low_watermark_pct,
            })),
            other => bail!("unknown slo.admission `{other}` (static | occupancy)"),
        }
    }

    /// The [`crate::control::AdmissionConfig`] when the SLO controller
    /// is armed (None under static admission).
    pub fn admission_config(&self) -> Result<Option<AdmissionConfig>> {
        Ok(self.admission_policy()?.admission_config())
    }

    pub fn harvest_config(&self) -> HarvestConfig {
        let mut cfg = HarvestConfig::for_node(self.n_gpus);
        cfg.victim_policy = self.victim_policy;
        cfg.reserve_bytes = self.reserve_gib * GIB;
        cfg.demote_to_host = self.demote_to_host;
        cfg.compress_before_demote = self.compress_before_demote;
        cfg.compress_ratio_pct = self.compress_ratio_pct;
        cfg.ssd_page_bytes = self.ssd_page_kib * 1024;
        if let Some(gib) = self.mig_cache_gib {
            // Partition every potential peer; the compute GPU's entry is
            // ignored by the controller (never selected as a peer).
            for m in &mut cfg.mig {
                *m = MigConfig::CachePartition { bytes: gib * GIB };
            }
        }
        cfg
    }

    pub fn kv_config(&self) -> Result<KvConfig> {
        let model = find_kv_model(&self.kv_model)
            .ok_or_else(|| anyhow!("unknown KV model `{}`", self.kv_model))?;
        Ok(KvConfig {
            model,
            block_tokens: self.block_tokens,
            local_capacity_blocks: self.local_capacity_blocks,
            use_harvest: self.harvest_enabled,
            host_backed_peer: false,
        })
    }

    pub fn workload_spec(&self) -> WorkloadSpec {
        WorkloadSpec {
            n_requests: self.n_requests,
            mean_prompt_tokens: self.mean_prompt_tokens,
            max_new_tokens: self.max_new_tokens,
            shared_prefix_fraction: self.shared_prefix_fraction,
            shared_prefix_tokens: if self.shared_prefix_fraction > 0.0 { 64 } else { 0 },
            mean_interarrival_ns: self.mean_interarrival_us * 1_000,
            n_prefix_groups: self.prefix_groups,
            seed: self.seed,
            ..WorkloadSpec::default()
        }
    }
}

/// Named presets used by examples, benches and the CLI (`--preset`).
pub fn presets() -> Vec<DeploymentConfig> {
    let base = DeploymentConfig::default();
    vec![
        // The paper's §4.4 MoE setup: 2× H100, half the experts offloaded.
        DeploymentConfig {
            name: "paper-moe".into(),
            workload: WorkloadKind::MoeOffload,
            moe_model: "Mixtral-8x7B".into(),
            ..base.clone()
        },
        // The paper's §5.3 KV setup.
        DeploymentConfig {
            name: "paper-kv".into(),
            workload: WorkloadKind::KvOffload,
            kv_model: "Kimi-K2".into(),
            ..base.clone()
        },
        // §6.3 fair decoding: CF scheduler, tight KV budget.
        DeploymentConfig {
            name: "fair-decode".into(),
            workload: WorkloadKind::KvOffload,
            scheduler: "cf".into(),
            quantum: 2,
            local_capacity_blocks: 512,
            shared_prefix_fraction: 0.5,
            ..base.clone()
        },
        // CPU-offload baseline (vanilla vLLM / CGOPipe-to-host).
        DeploymentConfig { name: "baseline-host".into(), harvest_enabled: false, ..base.clone() },
        // Future-deployment sweep: an 8-GPU NVSwitch domain.
        DeploymentConfig {
            name: "nvswitch-8".into(),
            n_gpus: 8,
            fabric: FabricKind::NvSwitch,
            moe_model: "Phi-3.5-MoE".into(),
            ..base.clone()
        },
        // §8 "potentially CXL-attached memory": a 256 GiB expander makes
        // CxlMem an allocatable tier between peer HBM and host DRAM; a
        // tight local pool forces the tier policy to actually use it.
        DeploymentConfig {
            name: "cxl-expander".into(),
            workload: WorkloadKind::KvOffload,
            cxl_gib: 256,
            local_capacity_blocks: 512,
            ..base.clone()
        },
        // Scale-out serving: 4 nodes behind prefix-affinity routing on a
        // shared-prefix session workload, RDMA node fabric.
        DeploymentConfig {
            name: "cluster-4".into(),
            workload: WorkloadKind::KvOffload,
            nodes: 4,
            router_policy: RouterPolicy::PrefixAffinity,
            n_requests: 128,
            shared_prefix_fraction: 0.75,
            mean_interarrival_us: 1_500,
            prefix_groups: 8,
            ..base.clone()
        },
        // Closed-loop co-tenants: a training job (ring all-reduce on the
        // serving GPUs' NVLinks), a second inference service and a
        // bursty batch job contend with the KV serve path; demotion
        // keeps revoked blocks alive on the host tier.
        DeploymentConfig {
            name: "multi-tenant".into(),
            workload: WorkloadKind::KvOffload,
            scheduler: "cf".into(),
            quantum: 2,
            local_capacity_blocks: 512,
            demote_to_host: true,
            tenants: TenantMix { enabled: true, host_gib: 4, ..TenantMix::default() },
            ..base.clone()
        },
        // Long-context sessions over the full cold-tier ladder: a tight
        // local pool plus a CXL expander and an SSD arena lets idle
        // sessions age peer -> host/CXL -> compressed -> SSD and come
        // back with zero recomputes instead of being dropped.
        DeploymentConfig {
            name: "long-context".into(),
            workload: WorkloadKind::KvOffload,
            cxl_gib: 256,
            ssd_gib: 1024,
            compress_before_demote: true,
            demote_to_host: true,
            local_capacity_blocks: 512,
            mean_prompt_tokens: 900.0,
            shared_prefix_fraction: 0.5,
            prefix_groups: 4,
            ..base.clone()
        },
        // SLO-governed serving: 4 nodes behind harvest-priced routing,
        // node-side occupancy admission (defer under the hysteresis
        // band, shed only past the stability boundary), heterogeneous
        // tenant pressure so pricing has something to avoid.
        DeploymentConfig {
            name: "slo-serve".into(),
            workload: WorkloadKind::KvOffload,
            nodes: 4,
            router_policy: RouterPolicy::HarvestPriced,
            slo_admission: "occupancy".into(),
            slo_ttft_p99_ms: 40,
            local_capacity_blocks: 512,
            demote_to_host: true,
            n_requests: 128,
            mean_interarrival_us: 800,
            tenants: TenantMix { enabled: true, host_gib: 4, ..TenantMix::default() },
            ..base.clone()
        },
        // End-to-end real-compute serve on the AOT tiny model.
        DeploymentConfig {
            name: "real-serve".into(),
            workload: WorkloadKind::RealServe,
            n_requests: 16,
            max_new_tokens: 16,
            ..base
        },
    ]
}

/// Look up a preset by name.
pub fn find_preset(name: &str) -> Option<DeploymentConfig> {
    presets().into_iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = TomlDoc::parse(
            r#"
            name = "x"            # comment
            n = 42
            ratio = 0.5
            big = 1_000_000
            on = true
            [sec]
            key = "v"
            [sec.sub]
            deep = -3
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str().unwrap(), "x");
        assert_eq!(doc.get("n").unwrap().as_i64().unwrap(), 42);
        assert_eq!(doc.get("ratio").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(doc.get("big").unwrap().as_i64().unwrap(), 1_000_000);
        assert!(doc.get("on").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("sec.key").unwrap().as_str().unwrap(), "v");
        assert_eq!(doc.get("sec.sub.deep").unwrap().as_i64().unwrap(), -3);
    }

    #[test]
    fn parses_arrays() {
        let doc = TomlDoc::parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nempty = []").unwrap();
        let xs = doc.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.iter().map(|v| v.as_i64().unwrap()).collect::<Vec<_>>(), vec![1, 2, 3]);
        let ys = doc.get("ys").unwrap().as_array().unwrap();
        assert_eq!(ys[1].as_str().unwrap(), "b");
        assert!(doc.get("empty").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn comment_inside_string_is_kept() {
        let doc = TomlDoc::parse("k = \"a # b\"").unwrap();
        assert_eq!(doc.get("k").unwrap().as_str().unwrap(), "a # b");
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbroken").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = TomlDoc::parse("k = ").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        let err = TomlDoc::parse("[sec\nk = 1").unwrap_err().to_string();
        assert!(err.contains("unterminated section"), "{err}");
    }

    #[test]
    fn duplicate_keys_rejected() {
        let err = TomlDoc::parse("a = 1\na = 2").unwrap_err().to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn section_keys_lists_section() {
        let doc = TomlDoc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<_> = doc.section_keys("a").collect();
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }

    #[test]
    fn require_reports_missing_key() {
        let doc = TomlDoc::parse("a = 1").unwrap();
        assert!(doc.require("a").is_ok());
        assert!(doc.require("b").unwrap_err().to_string().contains("missing config key"));
    }

    #[test]
    fn deployment_defaults_parse_from_empty() {
        let cfg = DeploymentConfig::from_toml("").unwrap();
        assert_eq!(cfg.n_gpus, 2);
        assert!(cfg.harvest_enabled);
        assert_eq!(cfg.workload, WorkloadKind::MoeOffload);
    }

    #[test]
    fn unknown_keys_fail_loudly() {
        let err = DeploymentConfig::from_toml("[moe]\nmodle = \"x\"").unwrap_err().to_string();
        assert!(err.contains("unknown config key `moe.modle`"), "{err}");
    }

    #[test]
    fn unknown_model_rejected() {
        let err =
            DeploymentConfig::from_toml("[moe]\nmodel = \"GPT-9\"").unwrap_err().to_string();
        assert!(err.contains("unknown MoE model"), "{err}");
    }

    #[test]
    fn bad_ranges_rejected() {
        assert!(DeploymentConfig::from_toml("[node]\ngpus = 1").is_err());
        assert!(DeploymentConfig::from_toml("[moe]\noffload_fraction = 1.5").is_err());
        assert!(DeploymentConfig::from_toml("[server]\nscheduler = \"sjf\"").is_err());
    }

    #[test]
    fn every_preset_validates_and_roundtrips() {
        for p in presets() {
            p.validate().unwrap_or_else(|e| panic!("preset {}: {e}", p.name));
            let text = p.to_toml();
            let back = DeploymentConfig::from_toml(&text)
                .unwrap_or_else(|e| panic!("preset {} roundtrip: {e}\n{text}", p.name));
            assert_eq!(back.name, p.name);
            assert_eq!(back.workload, p.workload);
            assert_eq!(back.n_gpus, p.n_gpus);
            assert_eq!(back.victim_policy, p.victim_policy);
            assert_eq!(back.offload_fraction, p.offload_fraction);
            assert_eq!(back.scheduler, p.scheduler);
            assert_eq!(back.mig_cache_gib, p.mig_cache_gib);
            assert_eq!(back.cxl_gib, p.cxl_gib);
            assert_eq!(back.nodes, p.nodes);
            assert_eq!(back.router_policy, p.router_policy);
            assert_eq!(back.node_fabric, p.node_fabric);
            assert_eq!(back.prefix_groups, p.prefix_groups);
            assert_eq!(back.mean_interarrival_us, p.mean_interarrival_us);
            assert_eq!(back.demote_to_host, p.demote_to_host);
            assert_eq!(back.ssd_gib, p.ssd_gib);
            assert_eq!(back.ssd_page_kib, p.ssd_page_kib);
            assert_eq!(back.compress_ratio_pct, p.compress_ratio_pct);
            assert_eq!(back.compress_before_demote, p.compress_before_demote);
            assert_eq!(back.tenants, p.tenants);
            assert_eq!(back.tenant_overrides, p.tenant_overrides);
            assert_eq!(back.obs_ring_cap, p.obs_ring_cap);
            assert_eq!(back.obs_profile, p.obs_profile);
            assert_eq!(back.obs_flight, p.obs_flight);
            assert_eq!(back.obs_shed_burst, p.obs_shed_burst);
            assert_eq!(back.obs_attribution, p.obs_attribution);
        }
    }

    #[test]
    fn obs_section_parses_and_validates() {
        let cfg = DeploymentConfig::from_toml(
            "[obs]\nring_cap = 1024\nprofile = true\nflight = false\nshed_burst = 2\n\
             attribution = true",
        )
        .unwrap();
        assert_eq!(cfg.obs_ring_cap, 1024);
        assert!(cfg.obs_profile);
        assert!(!cfg.obs_flight);
        assert_eq!(cfg.obs_shed_burst, 2);
        assert!(cfg.obs_attribution);
        assert!(!DeploymentConfig::default().obs_attribution);
        assert!(DeploymentConfig::from_toml("[obs]\nring_cap = 0").is_err());
        assert!(DeploymentConfig::from_toml("[obs]\nshed_burst = 0").is_err());
    }

    #[test]
    fn tenants_section_parses_and_overrides_per_node() {
        let cfg = DeploymentConfig::from_toml(
            "[cluster]\nnodes = 3\n[tenants]\nenabled = true\ntraining = 2\n\
             inference_target = 0.4\nbatch_priority = \"best-effort\"\n\
             [tenants.node1]\nenabled = false\n[tenants.node2]\nbatch = 5\nhost_gib = 8",
        )
        .unwrap();
        assert!(cfg.tenants.enabled);
        assert_eq!(cfg.tenants.training, 2);
        assert_eq!(cfg.tenants.inference_target, 0.4);
        assert_eq!(
            cfg.tenants.batch_priority,
            crate::tenantsim::TenantPriority::BestEffort
        );
        // overrides inherit the base mix, changing only named fields
        assert_eq!(cfg.tenant_overrides.len(), 2);
        let (i1, node1) = &cfg.tenant_overrides[0];
        assert_eq!(*i1, 1);
        assert!(!node1.enabled);
        assert_eq!(node1.training, 2, "inherited from [tenants]");
        let (i2, node2) = &cfg.tenant_overrides[1];
        assert_eq!(*i2, 2);
        assert!(node2.enabled);
        assert_eq!(node2.batch, 5);
        assert_eq!(node2.host_gib, 8);
        // round-trips
        let back = DeploymentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.tenants, cfg.tenants);
        assert_eq!(back.tenant_overrides, cfg.tenant_overrides);
        // rejections: typos, bad node scopes, bad ranges
        assert!(DeploymentConfig::from_toml("[tenants]\ntrainign = 1").is_err());
        assert!(DeploymentConfig::from_toml("[tenants.gpu0]\nbatch = 1").is_err());
        assert!(DeploymentConfig::from_toml("[tenants]\ninference_target = 1.5").is_err());
        assert!(
            DeploymentConfig::from_toml("[tenants.node7]\nbatch = 1").is_err(),
            "override outside cluster.nodes"
        );
        assert!(DeploymentConfig::from_toml("[tenants]\nbatch_priority = \"vip\"").is_err());
    }

    #[test]
    fn multi_tenant_preset_builds_a_fleet() {
        let p = find_preset("multi-tenant").unwrap();
        assert!(p.tenants.enabled);
        assert!(p.demote_to_host);
        assert!(p.harvest_config().demote_to_host);
        let fleet = p.tenant_fleet().expect("enabled mix builds a fleet");
        assert_eq!(fleet.len(), 3, "training + inference + batch");
        // disabled mixes build none
        assert!(find_preset("paper-kv").unwrap().tenant_fleet().is_none());
        // the cluster spec carries the mix to every node
        let spec = p.cluster_spec();
        assert_eq!(spec.tenants.as_ref().unwrap(), &p.tenants);
    }

    #[test]
    fn find_preset_by_name() {
        assert!(find_preset("paper-moe").is_some());
        assert!(find_preset("nope").is_none());
    }

    #[test]
    fn materializes_runtime_types() {
        let cfg = find_preset("paper-kv").unwrap();
        let spec = cfg.node_spec();
        assert_eq!(spec.gpus.len(), 2);
        assert_eq!(spec.gpus[0].hbm_bytes, 80 * GIB);
        let hc = cfg.harvest_config();
        assert_eq!(hc.mig.len(), 2);
        let kv = cfg.kv_config().unwrap();
        assert_eq!(kv.model.name, "Kimi-K2");
        assert!(kv.use_harvest);
        let w = cfg.workload_spec();
        assert_eq!(w.n_requests, cfg.n_requests);
    }

    #[test]
    fn fabric_roundtrips_and_materializes() {
        let cfg = DeploymentConfig::from_toml("[node]\ngpus = 8\nfabric = \"ring\"").unwrap();
        assert_eq!(cfg.fabric, FabricKind::Ring);
        assert_eq!(cfg.node_spec().fabric, FabricKind::Ring);
        let back = DeploymentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.fabric, FabricKind::Ring);
        assert!(DeploymentConfig::from_toml("[node]\nfabric = \"torus\"").is_err());
        assert_eq!(find_preset("nvswitch-8").unwrap().fabric, FabricKind::NvSwitch);
    }

    #[test]
    fn cluster_keys_parse_and_materialize() {
        let cfg = DeploymentConfig::from_toml(
            "[cluster]\nnodes = 4\nrouter_policy = \"affinity\"\nfabric = \"ethernet\"\n\
             shed_queue_depth = 32\n[node]\ncxl_gib = 128",
        )
        .unwrap();
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.router_policy, RouterPolicy::PrefixAffinity);
        assert_eq!(cfg.node_fabric, NodeFabricKind::Ethernet);
        let spec = cfg.cluster_spec();
        assert_eq!(spec.nodes, 4);
        assert_eq!(spec.router, RouterPolicy::PrefixAffinity);
        assert_eq!(spec.fabric, NodeFabricKind::Ethernet);
        assert_eq!(spec.shed_queue_depth, 32);
        assert_eq!(spec.node.cxl_bytes, 128 * GIB);
        // shed 0 means "never shed"
        let cfg = DeploymentConfig::from_toml("").unwrap();
        assert_eq!(cfg.cluster_spec().shed_queue_depth, usize::MAX);
        // rejections
        assert!(DeploymentConfig::from_toml("[cluster]\nnodes = 0").is_err());
        assert!(DeploymentConfig::from_toml("[cluster]\nrouter_policy = \"x\"").is_err());
        assert!(DeploymentConfig::from_toml("[cluster]\nfabric = \"infiniband9\"").is_err());
    }

    #[test]
    fn slo_keys_parse_and_materialize() {
        let cfg = DeploymentConfig::from_toml(
            "[slo]\nadmission = \"occupancy\"\nttft_p99_ms = 30\ngoodput_floor_tps = 100.0\n\
             window_ms = 10\nhigh_watermark_pct = 85\nlow_watermark_pct = 60\n\
             [harvest]\nplacement = \"stability\"",
        )
        .unwrap();
        assert_eq!(cfg.slo_admission, "occupancy");
        assert_eq!(cfg.placement_spec().unwrap(), PlacementSpec::StabilityAware);
        let policy = cfg.admission_policy().unwrap();
        let acfg = policy.admission_config().expect("occupancy arms the controller");
        assert_eq!(acfg.slo.ttft_p99_ns, 30_000_000);
        assert_eq!(acfg.slo.window_ns, 10_000_000);
        assert_eq!(acfg.slo.goodput_floor_tps, 100.0);
        assert_eq!(acfg.high_watermark_pct, 85);
        assert_eq!(acfg.low_watermark_pct, 60);
        let spec = cfg.cluster_spec();
        assert_eq!(spec.placement, PlacementSpec::StabilityAware);
        assert_eq!(spec.effective_admission(), policy);
        // round-trips
        let back = DeploymentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.slo_admission, cfg.slo_admission);
        assert_eq!(back.slo_ttft_p99_ms, cfg.slo_ttft_p99_ms);
        assert_eq!(back.slo_goodput_floor_tps, cfg.slo_goodput_floor_tps);
        assert_eq!(back.slo_high_watermark_pct, cfg.slo_high_watermark_pct);
        assert_eq!(back.placement, cfg.placement);
        // the static default maps shed_queue_depth onto the legacy gate
        let d = DeploymentConfig::from_toml("[cluster]\nshed_queue_depth = 8").unwrap();
        assert_eq!(
            d.admission_policy().unwrap(),
            AdmissionPolicy::StaticDepth { shed_queue_depth: 8 }
        );
        assert!(d.admission_config().unwrap().is_none());
        // rejections
        assert!(DeploymentConfig::from_toml("[slo]\nadmission = \"magic\"").is_err());
        assert!(DeploymentConfig::from_toml("[slo]\nhigh_watermark_pct = 101").is_err());
        assert!(DeploymentConfig::from_toml(
            "[slo]\nhigh_watermark_pct = 50\nlow_watermark_pct = 60"
        )
        .is_err());
        assert!(DeploymentConfig::from_toml("[slo]\nttft_p99_ms = 0").is_err());
        assert!(DeploymentConfig::from_toml("[harvest]\nplacement = \"psychic\"").is_err());
    }

    #[test]
    fn slo_serve_preset_arms_the_control_plane() {
        let p = find_preset("slo-serve").unwrap();
        assert_eq!(p.router_policy, RouterPolicy::HarvestPriced);
        assert_eq!(p.slo_admission, "occupancy");
        let spec = p.cluster_spec();
        assert!(spec.effective_admission().admission_config().is_some());
        assert_eq!(spec.router, RouterPolicy::HarvestPriced);
    }

    #[test]
    fn cxl_expander_preset_attaches_tier() {
        let p = find_preset("cxl-expander").unwrap();
        assert_eq!(p.cxl_gib, 256);
        let spec = p.node_spec();
        assert_eq!(spec.cxl_bytes, 256 * GIB);
        assert!(crate::memsim::SimNode::new(spec).has_cxl());
    }

    #[test]
    fn coldtier_keys_parse_and_materialize() {
        let cfg = DeploymentConfig::from_toml(
            "[coldtier]\nssd_gib = 512\npage_kib = 1024\ncompress_ratio_pct = 40\n\
             compress_before_demote = true",
        )
        .unwrap();
        assert_eq!(cfg.ssd_gib, 512);
        assert_eq!(cfg.ssd_page_kib, 1024);
        assert_eq!(cfg.compress_ratio_pct, 40);
        assert!(cfg.compress_before_demote);
        let spec = cfg.node_spec();
        assert_eq!(spec.ssd_bytes, 512 * GIB);
        let hc = cfg.harvest_config();
        assert!(hc.compress_before_demote);
        assert_eq!(hc.compress_ratio_pct, 40);
        assert_eq!(hc.ssd_page_bytes, 1024 * 1024);
        // round-trips
        let back = DeploymentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.ssd_gib, cfg.ssd_gib);
        assert_eq!(back.compress_ratio_pct, cfg.compress_ratio_pct);
        // absent by default; rejections
        let d = DeploymentConfig::from_toml("").unwrap();
        assert_eq!(d.ssd_gib, 0);
        assert_eq!(d.node_spec().ssd_bytes, 0, "tier absent by default");
        assert!(DeploymentConfig::from_toml("[coldtier]\ncompress_ratio_pct = 0").is_err());
        assert!(DeploymentConfig::from_toml("[coldtier]\ncompress_ratio_pct = 100").is_err());
        assert!(DeploymentConfig::from_toml("[coldtier]\npage_kib = 0").is_err());
        assert!(DeploymentConfig::from_toml("[coldtier]\nssdgib = 1").is_err());
    }

    #[test]
    fn long_context_preset_attaches_ssd_tier() {
        let p = find_preset("long-context").unwrap();
        assert_eq!(p.ssd_gib, 1024);
        assert!(p.compress_before_demote);
        assert!(p.demote_to_host);
        let spec = p.node_spec();
        assert_eq!(spec.ssd_bytes, 1024 * GIB);
        assert_eq!(spec.cxl_bytes, 256 * GIB);
        let node = crate::memsim::SimNode::new(spec);
        assert!(node.has_ssd() && node.has_cxl());
        let hc = p.harvest_config();
        assert!(hc.compress_before_demote && hc.demote_to_host);
        assert_eq!(hc.ssd_page_bytes, 2048 * 1024);
    }

    #[test]
    fn cluster_preset_materializes_multi_node_spec() {
        let p = find_preset("cluster-4").unwrap();
        assert_eq!(p.nodes, 4);
        let spec = p.cluster_spec();
        assert_eq!(spec.nodes, 4);
        assert_eq!(spec.router, RouterPolicy::PrefixAffinity);
        let w = p.workload_spec();
        assert_eq!(w.n_prefix_groups, 8);
        assert_eq!(w.mean_interarrival_ns, 1_500_000);
        assert!(w.shared_prefix_tokens > 0);
        assert!(matches!(p.scheduler_spec().unwrap(), SchedulerSpec::Fcfs));
    }

    #[test]
    fn mig_preset_materializes_partitions() {
        let mut cfg = DeploymentConfig::default();
        cfg.mig_cache_gib = Some(10);
        let hc = cfg.harvest_config();
        assert!(hc.mig.iter().all(|m| m.harvest_limit() == Some(10 * GIB)));
    }
}
