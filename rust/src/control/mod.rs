//! SLO control plane: feedback-driven admission, harvest-priced routing.
//!
//! The serving layer has a sharp, queueing-theoretic stability boundary:
//! once KV-block occupancy saturates (or tenant pressure squeezes the
//! harvestable pool), throughput collapses and TTFT degrades
//! super-linearly. The static `shed_queue_depth` threshold cannot see
//! that boundary — it sheds on queue length alone, which lags occupancy
//! by the full pipeline depth.
//!
//! This module closes the loop:
//!
//! ```text
//!    arrivals ──▶ AdmissionController ──admit/defer──▶ NodeStepper
//!                   ▲          │shed                      │
//!         setpoint  │          ▼                          │ TTFT,
//!        (budget)   │     shed ledger                     │ tokens
//!                   │                                     ▼
//!                 SloMonitor ◀──── windowed TTFT / goodput┘
//! ```
//!
//! * [`slo`] — SLO targets (`p99 TTFT`, goodput floor) and the sliding
//!   [`SloMonitor`] window that measures achieved TTFT, goodput, and
//!   arrival-vs-drain rates.
//! * [`admission`] — the per-node [`AdmissionController`]: tri-state
//!   admit / defer / shed decisions against measured KV occupancy,
//!   tenant pressure, and the monitor's stability estimate, with
//!   hysteresis watermarks so it degrades gracefully instead of
//!   oscillating. The legacy static threshold survives as
//!   [`AdmissionPolicy::StaticDepth`].
//! * [`pricing`] — the router-scoring layer behind
//!   `RouterPolicy::HarvestPriced`: prices each node's *harvestable*
//!   capacity (free KV blocks + per-tier harvestable bytes discounted
//!   by reload cost and demotion risk under tenant churn).

pub mod admission;
pub mod pricing;
pub mod slo;

pub use admission::{
    AdmissionConfig, AdmissionController, AdmissionDecision, AdmissionPolicy, AdmissionSignals,
    AdmissionStats,
};
pub use pricing::{priced_capacity, PricingWeights};
pub use slo::{SloConfig, SloMonitor};
