//! Harvest pricing: score a node by what its memory is *worth*.
//!
//! Free KV blocks are worth full price — a request placed there runs
//! from local HBM. Harvestable bytes on colder tiers are worth less:
//! they must be reloaded across NVLink / the host bridge / NVMe before
//! they serve tokens, and under tenant churn they may be demoted out
//! from under the cache before they pay off at all. The pricer folds
//! both effects into one integer score the router can compare exactly
//! (per-mille weights and u128 cross-multiplication — no float ties, no
//! platform-dependent ordering).

use std::cmp::Ordering;

use crate::cluster::NodeView;

/// Per-mille value of a harvestable byte on each tier, ordered by
/// reload cost, plus the churn scale for the demotion-risk discount.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PricingWeights {
    /// Free local KV blocks (no reload needed): full price.
    pub local_pm: u32,
    /// Peer-GPU HBM harvestable over NVLink.
    pub peer_pm: u32,
    /// CXL-expander bytes.
    pub cxl_pm: u32,
    /// Host DRAM over the PCIe/host bridge.
    pub host_pm: u32,
    /// NVMe SSD pages (reload dominated by read latency).
    pub ssd_pm: u32,
    /// Churn half-life: the harvest-tier price is multiplied by
    /// `churn_scale / (churn_scale + sheds + demotions)`, so a node
    /// that has been demoting (tenant churn) or shedding (overload)
    /// recently is discounted smoothly.
    pub churn_scale: u64,
}

impl Default for PricingWeights {
    fn default() -> Self {
        Self { local_pm: 1000, peer_pm: 900, cxl_pm: 450, host_pm: 300, ssd_pm: 80, churn_scale: 64 }
    }
}

/// Price a node's harvestable capacity in weighted bytes (per-mille
/// scaled): full-price local KV blocks plus per-tier harvestable bytes
/// discounted by reload cost, the harvest portion further discounted by
/// demotion risk under the node's recent churn.
///
/// ```
/// use harvest::cluster::NodeView;
/// use harvest::control::{priced_capacity, PricingWeights};
///
/// let w = PricingWeights::default();
/// let mut v = NodeView::new(0, 0, 4);
/// v.block_bytes = 1024;
/// // 4 free blocks of 1 KiB at full price = 4096 * 1000.
/// assert_eq!(priced_capacity(&v, &w), 4096 * 1000);
/// // Host bytes are discounted to 300‰ of a local byte.
/// v.harvest_host_bytes = 1000;
/// assert_eq!(priced_capacity(&v, &w), 4096 * 1000 + 1000 * 300);
/// // Recent demotions discount the harvest-tier portion only.
/// v.demotions = 64;
/// assert_eq!(priced_capacity(&v, &w), 4096 * 1000 + 1000 * 300 / 2);
/// ```
pub fn priced_capacity(v: &NodeView, w: &PricingWeights) -> u128 {
    let local =
        v.free_local_blocks as u128 * v.block_bytes as u128 * w.local_pm as u128;
    let tiered = v.free_hbm_bytes as u128 * w.peer_pm as u128
        + v.harvest_cxl_bytes as u128 * w.cxl_pm as u128
        + v.harvest_host_bytes as u128 * w.host_pm as u128
        + v.harvest_ssd_bytes as u128 * w.ssd_pm as u128;
    let churn = v.sheds.saturating_add(v.demotions) as u128;
    let scale = w.churn_scale.max(1) as u128;
    local + tiered * (scale * 1000 / (scale + churn)) / 1000
}

/// Order two nodes by price-per-queued-request, best first: compares
/// `price / (queue_depth + 1)` by exact cross-multiplication, breaking
/// ties toward the prefix-holding node, then the lower node id.
pub fn price_order(a: &NodeView, b: &NodeView, w: &PricingWeights) -> Ordering {
    let pa = priced_capacity(a, w);
    let pb = priced_capacity(b, w);
    let lhs = pa * (b.queue_depth as u128 + 1);
    let rhs = pb * (a.queue_depth as u128 + 1);
    rhs.cmp(&lhs)
        .then_with(|| b.has_prefix.cmp(&a.has_prefix))
        .then_with(|| a.node.cmp(&b.node))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(node: usize, queue: usize, blocks: usize) -> NodeView {
        let mut v = NodeView::new(node, queue, blocks);
        v.block_bytes = 4096;
        v
    }

    #[test]
    fn local_blocks_beat_discounted_tiers() {
        let w = PricingWeights::default();
        let mut far = view(0, 0, 0);
        far.harvest_ssd_bytes = 8 * 4096; // same raw bytes, SSD tier
        let near = view(1, 0, 8);
        assert!(priced_capacity(&near, &w) > priced_capacity(&far, &w));
    }

    #[test]
    fn churn_discounts_harvest_but_not_local() {
        let w = PricingWeights::default();
        let mut calm = view(0, 0, 4);
        calm.harvest_host_bytes = 1 << 20;
        let mut churny = calm;
        churny.node = 1;
        churny.demotions = 1000;
        let calm_p = priced_capacity(&calm, &w);
        let churny_p = priced_capacity(&churny, &w);
        assert!(churny_p < calm_p);
        // The local component is untouched by churn.
        assert!(churny_p >= priced_capacity(&view(1, 0, 4), &w));
    }

    #[test]
    fn ordering_is_per_queue_slot_with_deterministic_ties() {
        let w = PricingWeights::default();
        // Same price, deeper queue loses.
        let shallow = view(0, 1, 8);
        let deep = view(1, 7, 8);
        assert_eq!(price_order(&shallow, &deep, &w), Ordering::Less);
        // Identical nodes: lower id wins.
        let a = view(0, 2, 8);
        let b = view(1, 2, 8);
        assert_eq!(price_order(&a, &b, &w), Ordering::Less);
        assert_eq!(price_order(&b, &a, &w), Ordering::Greater);
        // Prefix holder breaks otherwise-equal scores.
        let mut pfx = view(1, 2, 8);
        pfx.has_prefix = true;
        assert_eq!(price_order(&pfx, &a, &w), Ordering::Less);
    }
}
