//! Per-node admission control: admit / defer / shed with hysteresis.
//!
//! The controller sits in the node stepper's admission loop (the single
//! loop body shared by `SimEngine` and `ClusterNode`, so both paths stay
//! bit-for-bit identical) and decides, for each pending arrival, whether
//! to admit it now, defer it (leave it queued and re-examine on the next
//! step), or shed it. Decisions steer on three measured signals:
//!
//! 1. **Memory pressure** — the max of KV-block occupancy and tenant-held
//!    HBM fraction, run through a hysteresis state machine (enter the
//!    `Pressured` state at the high watermark, leave at the low one) so
//!    admission degrades gracefully instead of oscillating at a single
//!    threshold.
//! 2. **Stability** — the sliding-window arrival rate vs. drain rate from
//!    the [`SloMonitor`]; a queue that grows faster than it drains is past
//!    the queueing stability boundary and waiting will not save it.
//! 3. **SLO headroom** — predicted TTFT (wait already accrued plus the
//!    queueing estimate) against the monitor's effective budget.
//!
//! A request is shed only when all three say so: it is predicted to miss
//! the budget, the node is unstable, *and* pressure is at or above the
//! low watermark — the controller never sheds below the low watermark.

use crate::memsim::Ns;

use super::slo::{SloConfig, SloMonitor};

/// How a node decides which arrivals to serve.
///
/// The default is [`StaticDepth`](Self::StaticDepth) with an unbounded
/// depth (never shed), matching the legacy behaviour bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionPolicy {
    /// **Deprecated shim** for the legacy `shed_queue_depth` knob: shed
    /// at the router when every node's queue is at least this deep,
    /// spill/route below it. No feedback, no deferral; it cannot see
    /// the stability boundary. Kept so old configs (TOML key
    /// `cluster.shed_queue_depth`) keep working bit-for-bit — new
    /// configs should use the `[slo]` section, which selects
    /// [`SloOccupancy`](Self::SloOccupancy) instead.
    StaticDepth {
        /// Queue depth at which arrivals are shed; `usize::MAX` never sheds.
        shed_queue_depth: usize,
    },
    /// Occupancy-driven feedback control: each node runs an
    /// [`AdmissionController`] in its stepper and the router never
    /// sheds (all admission accounting is node-level).
    SloOccupancy(AdmissionConfig),
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy::StaticDepth { shed_queue_depth: usize::MAX }
    }
}

impl AdmissionPolicy {
    /// Short name for reports: `"static"` or `"occupancy"`.
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::StaticDepth { .. } => "static",
            AdmissionPolicy::SloOccupancy(_) => "occupancy",
        }
    }

    /// The controller config when this policy is feedback-driven.
    pub fn admission_config(&self) -> Option<AdmissionConfig> {
        match self {
            AdmissionPolicy::StaticDepth { .. } => None,
            AdmissionPolicy::SloOccupancy(cfg) => Some(*cfg),
        }
    }
}

/// Tuning for the occupancy-driven [`AdmissionController`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionConfig {
    /// SLO targets and monitor window.
    pub slo: SloConfig,
    /// Memory-pressure per-cent at which the node enters the
    /// `Pressured` hysteresis state (new arrivals defer).
    pub high_watermark_pct: u32,
    /// Per-cent at which the node leaves `Pressured`. Shedding never
    /// happens below this watermark.
    pub low_watermark_pct: u32,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self { slo: SloConfig::default(), high_watermark_pct: 90, low_watermark_pct: 70 }
    }
}

impl AdmissionConfig {
    fn high_pm(&self) -> u32 {
        self.high_watermark_pct.saturating_mul(10)
    }

    fn low_pm(&self) -> u32 {
        self.low_watermark_pct.saturating_mul(10)
    }
}

/// The controller's verdict for one pending arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Start serving the request now.
    Admit,
    /// Leave it at the head of the queue; re-examine on the next step.
    Defer,
    /// Reject it permanently (counted in the shed ledger).
    Shed,
}

/// Measured node state sampled by the stepper at decision time.
#[derive(Debug, Clone, Copy, Default)]
pub struct AdmissionSignals {
    /// KV-block pool occupancy, per-mille (`used * 1000 / capacity`).
    pub occupancy_pm: u32,
    /// Tenant-held fraction of total HBM, per-mille.
    pub tenant_pressure_pm: u32,
    /// Requests queued behind this one plus requests currently live.
    pub queue_depth: usize,
    /// Requests currently being served. A node with zero live work
    /// never defers (deferring with no work would freeze virtual time).
    pub live: usize,
}

impl AdmissionSignals {
    /// Combined memory pressure: max of KV occupancy and tenant-held
    /// fraction, per-mille.
    pub fn pressure_pm(&self) -> u32 {
        self.occupancy_pm.max(self.tenant_pressure_pm)
    }
}

/// Counters exposed for tests and reports.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionStats {
    /// Requests admitted (including after deferral).
    pub admitted: u64,
    /// Defer decisions issued (one request may defer many times).
    pub defer_events: u64,
    /// Requests shed by the controller.
    pub shed: u64,
    /// Times the hysteresis state machine entered `Pressured`.
    pub pressure_enters: u64,
    /// Times it left `Pressured`.
    pub pressure_exits: u64,
    /// Minimum memory pressure (per-mille) observed at any shed;
    /// `u32::MAX` if nothing was shed. Tests assert this never drops
    /// below the low watermark.
    pub min_shed_pressure_pm: u32,
}

impl Default for AdmissionStats {
    fn default() -> Self {
        Self {
            admitted: 0,
            defer_events: 0,
            shed: 0,
            pressure_enters: 0,
            pressure_exits: 0,
            min_shed_pressure_pm: u32::MAX,
        }
    }
}

impl AdmissionStats {
    /// Register the decision counters into the unified metrics registry
    /// under `prefix` (e.g. `"admission"`).
    pub fn register(&self, reg: &mut crate::obs::MetricsRegistry, prefix: &str) {
        reg.counter(&format!("{prefix}.admitted"), self.admitted);
        reg.counter(&format!("{prefix}.defer_events"), self.defer_events);
        reg.counter(&format!("{prefix}.shed"), self.shed);
        reg.counter(&format!("{prefix}.pressure_enters"), self.pressure_enters);
        reg.counter(&format!("{prefix}.pressure_exits"), self.pressure_exits);
        if self.min_shed_pressure_pm != u32::MAX {
            reg.gauge(&format!("{prefix}.min_shed_pressure_pm"), self.min_shed_pressure_pm as f64);
        }
    }
}

/// Feedback admission controller for one serving node.
///
/// Deterministic: all state is derived from virtual-time signals the
/// stepper feeds it, so a 1-node cluster and a bare `SimEngine` running
/// the same workload make identical decisions.
///
/// ```
/// use harvest::control::{
///     AdmissionConfig, AdmissionController, AdmissionDecision, AdmissionSignals,
/// };
///
/// let mut ctl = AdmissionController::new(AdmissionConfig::default());
/// // Cold start, empty node: admit.
/// let idle = AdmissionSignals { occupancy_pm: 100, ..Default::default() };
/// assert_eq!(ctl.decide(0, 0, &idle), AdmissionDecision::Admit);
/// // Above the high watermark with live work: defer, don't thrash.
/// let pressed = AdmissionSignals {
///     occupancy_pm: 950,
///     queue_depth: 4,
///     live: 2,
///     ..Default::default()
/// };
/// assert_eq!(ctl.decide(1_000, 1_000, &pressed), AdmissionDecision::Defer);
/// assert!(!ctl.accepting());
/// ```
#[derive(Debug, Clone)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    monitor: SloMonitor,
    pressured: bool,
    stats: AdmissionStats,
    last_predicted_ttft_ns: Ns,
}

impl AdmissionController {
    /// A controller in the relaxed (not pressured) state.
    pub fn new(cfg: AdmissionConfig) -> Self {
        let monitor = SloMonitor::new(cfg.slo.window_ns);
        Self {
            cfg,
            monitor,
            pressured: false,
            stats: AdmissionStats::default(),
            last_predicted_ttft_ns: 0,
        }
    }

    /// The tuning this controller runs with.
    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Record an arrival in the monitor window (once per request).
    pub fn note_arrival(&mut self, at: Ns) {
        self.monitor.note_arrival(at);
    }

    /// Record a completion: feeds achieved TTFT and goodput back into
    /// the budget setpoint.
    pub fn note_finish(&mut self, at: Ns, ttft_ns: Ns, tokens: u64) {
        self.monitor.note_finish(at, ttft_ns, tokens);
    }

    /// `true` while the node is below the high watermark (hysteresis
    /// state relaxed). Routers prefer accepting nodes.
    pub fn accepting(&self) -> bool {
        !self.pressured
    }

    /// Decision counters.
    pub fn stats(&self) -> AdmissionStats {
        self.stats
    }

    /// Read-only view of the monitor (for reports).
    pub fn monitor_mut(&mut self) -> &mut SloMonitor {
        &mut self.monitor
    }

    /// The TTFT (wait already accrued + queueing estimate) the last
    /// [`decide`](Self::decide) call predicted — the third input the
    /// tracer attaches to admission decision events.
    pub fn last_predicted_ttft_ns(&self) -> Ns {
        self.last_predicted_ttft_ns
    }

    /// Decide the fate of the request that arrived at `arrival`, given
    /// the node state in `sig` at virtual time `now`.
    pub fn decide(&mut self, now: Ns, arrival: Ns, sig: &AdmissionSignals) -> AdmissionDecision {
        let pressure = sig.pressure_pm();
        if !self.pressured && pressure >= self.cfg.high_pm() {
            self.pressured = true;
            self.stats.pressure_enters += 1;
        } else if self.pressured && pressure <= self.cfg.low_pm() {
            self.pressured = false;
            self.stats.pressure_exits += 1;
        }

        let budget = self.monitor.effective_budget(now, self.cfg.slo.ttft_p99_ns);
        let waited = now.saturating_sub(arrival);
        let predicted_ttft = waited.saturating_add(self.monitor.est_wait_ns(now, sig.queue_depth));
        self.last_predicted_ttft_ns = predicted_ttft;
        let over_budget = predicted_ttft > budget;
        let unstable =
            self.monitor.arrivals_in_window(now) > self.monitor.finishes_in_window(now);
        // Never shed below the low watermark.
        let can_shed = pressure >= self.cfg.low_pm();
        // A goodput shortfall suppresses shedding unless memory is
        // critical — shedding while under-delivering tokens only digs
        // the goodput hole deeper.
        let floor = self.cfg.slo.goodput_floor_tps;
        let goodput_ok = floor <= 0.0 || self.monitor.goodput_tps(now) >= floor;

        let decision = if over_budget && unstable && can_shed && (goodput_ok || self.pressured) {
            AdmissionDecision::Shed
        } else if self.pressured && sig.live > 0 {
            AdmissionDecision::Defer
        } else {
            AdmissionDecision::Admit
        };
        match decision {
            AdmissionDecision::Admit => self.stats.admitted += 1,
            AdmissionDecision::Defer => self.stats.defer_events += 1,
            AdmissionDecision::Shed => {
                self.stats.shed += 1;
                self.stats.min_shed_pressure_pm = self.stats.min_shed_pressure_pm.min(pressure);
            }
        }
        decision
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(occ_pm: u32, queue: usize, live: usize) -> AdmissionSignals {
        AdmissionSignals {
            occupancy_pm: occ_pm,
            tenant_pressure_pm: 0,
            queue_depth: queue,
            live,
        }
    }

    #[test]
    fn cold_start_admits() {
        let mut ctl = AdmissionController::new(AdmissionConfig::default());
        assert_eq!(ctl.decide(0, 0, &sig(0, 0, 0)), AdmissionDecision::Admit);
        assert_eq!(ctl.stats().admitted, 1);
    }

    #[test]
    fn idle_node_never_defers() {
        let mut ctl = AdmissionController::new(AdmissionConfig::default());
        // Way above the high watermark, but no live work: deferring
        // would freeze virtual time, so the controller admits.
        let d = ctl.decide(10, 10, &sig(990, 0, 0));
        assert_eq!(d, AdmissionDecision::Admit);
        assert!(!ctl.accepting());
    }

    #[test]
    fn hysteresis_band_holds_state() {
        let mut ctl = AdmissionController::new(AdmissionConfig::default());
        // 90% high, 70% low. 80% does not enter Pressured...
        ctl.decide(0, 0, &sig(800, 1, 1));
        assert!(ctl.accepting());
        // ...95% does...
        ctl.decide(1, 1, &sig(950, 1, 1));
        assert!(!ctl.accepting());
        // ...and 80% (inside the dead band) keeps it Pressured.
        ctl.decide(2, 2, &sig(800, 1, 1));
        assert!(!ctl.accepting());
        // 70% releases it.
        ctl.decide(3, 3, &sig(700, 1, 1));
        assert!(ctl.accepting());
        assert_eq!(ctl.stats().pressure_enters, 1);
        assert_eq!(ctl.stats().pressure_exits, 1);
    }

    #[test]
    fn sheds_only_when_unstable_over_budget_and_above_low_watermark() {
        let cfg = AdmissionConfig {
            slo: SloConfig { ttft_p99_ns: 1_000, goodput_floor_tps: 0.0, window_ns: 10_000 },
            ..Default::default()
        };
        let mut ctl = AdmissionController::new(cfg);
        // Build a slow drain estimate: 1 finish per 10 µs window.
        ctl.note_finish(5_000, 500, 4);
        for t in 0..8u64 {
            ctl.note_arrival(5_000 + t);
        }
        // Over budget (queue 8 * 10 µs each >> 1 µs budget), unstable
        // (8 arrivals vs 1 finish), pressure above low watermark: shed.
        let d = ctl.decide(5_010, 5_010, &sig(750, 8, 2));
        assert_eq!(d, AdmissionDecision::Shed);
        // Identical load below the low watermark: never shed.
        let mut relaxed = AdmissionController::new(cfg);
        relaxed.note_finish(5_000, 500, 4);
        for t in 0..8u64 {
            relaxed.note_arrival(5_000 + t);
        }
        let d = relaxed.decide(5_010, 5_010, &sig(200, 8, 2));
        assert_ne!(d, AdmissionDecision::Shed);
        assert_eq!(relaxed.stats().min_shed_pressure_pm, u32::MAX);
    }

    #[test]
    fn goodput_floor_suppresses_shedding_when_relaxed() {
        let cfg = AdmissionConfig {
            slo: SloConfig {
                ttft_p99_ns: 1_000,
                goodput_floor_tps: 1e12, // unreachable floor
                window_ns: 10_000,
            },
            ..Default::default()
        };
        let mut ctl = AdmissionController::new(cfg);
        ctl.note_finish(5_000, 500, 4);
        for t in 0..8u64 {
            ctl.note_arrival(5_000 + t);
        }
        // Same overload as above (pressure 75% is above low, below
        // high) — but goodput is under the floor, so no shed.
        let d = ctl.decide(5_010, 5_010, &sig(750, 8, 2));
        assert_ne!(d, AdmissionDecision::Shed);
    }
}
