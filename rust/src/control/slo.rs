//! SLO targets and the sliding-window monitor that measures them.
//!
//! [`SloConfig`] carries the targets (p99 TTFT, goodput floor) parsed
//! from the `[slo]` TOML section. [`SloMonitor`] tracks a sliding
//! window of arrivals and completions in virtual time and derives the
//! signals the admission controller steers on: achieved TTFT p99,
//! completed-token goodput, the arrival-vs-drain stability estimate,
//! and the *effective* TTFT budget (the setpoint tightens when the
//! window is already missing the target, so the controller reacts
//! before the miss compounds).

use std::collections::VecDeque;

use crate::memsim::Ns;

/// Service-level objectives for a serving node.
///
/// Parsed from the `[slo]` TOML section; all signals are evaluated over
/// a sliding window of [`window_ns`](Self::window_ns) virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// Target p99 time-to-first-token. Deferred-admission wait counts
    /// against this budget (TTFT is measured from arrival, not from
    /// admission), so the controller cannot game the metric by queueing.
    pub ttft_p99_ns: Ns,
    /// Goodput floor in completed tokens/sec; `0.0` disables the floor.
    /// While the window's goodput is below the floor, shedding is
    /// suppressed unless memory is critical (hysteresis state pressed).
    pub goodput_floor_tps: f64,
    /// Sliding-window length for all monitor signals.
    pub window_ns: Ns,
}

impl Default for SloConfig {
    fn default() -> Self {
        Self {
            ttft_p99_ns: 50_000_000, // 50 ms
            goodput_floor_tps: 0.0,
            window_ns: 20_000_000, // 20 ms
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct FinishRecord {
    at: Ns,
    ttft_ns: Ns,
    tokens: u64,
}

/// Sliding-window tracker of achieved TTFT, goodput, and arrival/drain
/// rates, in virtual time.
///
/// Feeds the admission controller's setpoint: when the windowed p99
/// TTFT already exceeds the target, [`SloMonitor::effective_budget`]
/// tightens proportionally so admission turns conservative *before*
/// the miss compounds.
///
/// ```
/// use harvest::control::SloMonitor;
///
/// let mut m = SloMonitor::new(1_000);
/// m.note_arrival(100);
/// m.note_arrival(200);
/// m.note_finish(250, 150, 8);
/// assert_eq!(m.arrivals_in_window(250), 2);
/// assert_eq!(m.finishes_in_window(250), 1);
/// // One finish in a 1 µs window => estimated drain interval 1 µs/req,
/// // so a queue of 3 predicts a 3 µs wait.
/// assert_eq!(m.est_wait_ns(250, 3), 3_000);
/// // The window slides: at t=1300 the arrival at t=100 has aged out.
/// assert_eq!(m.arrivals_in_window(1_300), 1);
/// ```
#[derive(Debug, Clone)]
pub struct SloMonitor {
    window_ns: Ns,
    arrivals: VecDeque<Ns>,
    finishes: VecDeque<FinishRecord>,
}

impl SloMonitor {
    /// A monitor with a sliding window of `window_ns` (clamped to ≥ 1).
    pub fn new(window_ns: Ns) -> Self {
        Self { window_ns: window_ns.max(1), arrivals: VecDeque::new(), finishes: VecDeque::new() }
    }

    /// Window length in nanoseconds.
    pub fn window_ns(&self) -> Ns {
        self.window_ns
    }

    /// Record a request arrival at virtual time `at`.
    pub fn note_arrival(&mut self, at: Ns) {
        self.arrivals.push_back(at);
    }

    /// Record a request completion: finished at `at`, with first token
    /// `ttft_ns` after arrival, having generated `tokens` tokens.
    pub fn note_finish(&mut self, at: Ns, ttft_ns: Ns, tokens: u64) {
        self.finishes.push_back(FinishRecord { at, ttft_ns, tokens });
    }

    fn prune(&mut self, now: Ns) {
        let cutoff = now.saturating_sub(self.window_ns);
        while self.arrivals.front().is_some_and(|&a| a < cutoff) {
            self.arrivals.pop_front();
        }
        while self.finishes.front().is_some_and(|f| f.at < cutoff) {
            self.finishes.pop_front();
        }
    }

    /// Arrivals observed inside the window ending at `now`.
    pub fn arrivals_in_window(&mut self, now: Ns) -> usize {
        self.prune(now);
        self.arrivals.len()
    }

    /// Completions observed inside the window ending at `now`.
    pub fn finishes_in_window(&mut self, now: Ns) -> usize {
        self.prune(now);
        self.finishes.len()
    }

    /// Estimated per-request drain interval: window length divided by
    /// windowed completions. `None` before the first completion lands
    /// (cold start — the controller admits rather than guess).
    pub fn drain_interval_ns(&mut self, now: Ns) -> Option<Ns> {
        self.prune(now);
        let n = self.finishes.len() as u64;
        if n == 0 { None } else { Some(self.window_ns / n) }
    }

    /// Predicted queueing wait for a request behind `queue_depth`
    /// others, from the windowed drain rate. Zero at cold start.
    pub fn est_wait_ns(&mut self, now: Ns, queue_depth: usize) -> Ns {
        match self.drain_interval_ns(now) {
            Some(step) => (queue_depth as u64).saturating_mul(step),
            None => 0,
        }
    }

    /// Achieved p99 TTFT over the window, `None` if no completions.
    pub fn ttft_p99(&mut self, now: Ns) -> Option<Ns> {
        self.prune(now);
        if self.finishes.is_empty() {
            return None;
        }
        let mut ttfts: Vec<Ns> = self.finishes.iter().map(|f| f.ttft_ns).collect();
        ttfts.sort_unstable();
        let rank = (ttfts.len() - 1) * 99 / 100;
        Some(ttfts[rank])
    }

    /// Completed-token goodput over the window, in tokens/sec.
    pub fn goodput_tps(&mut self, now: Ns) -> f64 {
        self.prune(now);
        let tokens: u64 = self.finishes.iter().map(|f| f.tokens).sum();
        tokens as f64 * 1e9 / self.window_ns as f64
    }

    /// The effective TTFT budget given a `target`: equal to the target
    /// while the window is meeting it, tightened proportionally
    /// (`target²/achieved`, floored at `target/4`) once the windowed
    /// p99 exceeds it. This is the feedback setpoint — a node already
    /// missing its SLO admits less, not more.
    pub fn effective_budget(&mut self, now: Ns, target: Ns) -> Ns {
        match self.ttft_p99(now) {
            Some(achieved) if achieved > target && achieved > 0 => {
                let tightened =
                    (target as u128 * target as u128 / achieved as u128) as Ns;
                tightened.max(target / 4)
            }
            _ => target,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_slides_and_prunes() {
        let mut m = SloMonitor::new(1_000);
        for t in [0u64, 400, 800, 1_200] {
            m.note_arrival(t);
        }
        // Window [200, 1200]: arrival at t=0 aged out.
        assert_eq!(m.arrivals_in_window(1_200), 3);
        assert_eq!(m.arrivals_in_window(2_300), 0);
    }

    #[test]
    fn drain_rate_and_est_wait() {
        let mut m = SloMonitor::new(10_000);
        assert_eq!(m.drain_interval_ns(0), None);
        assert_eq!(m.est_wait_ns(0, 100), 0);
        for i in 0..5u64 {
            m.note_finish(i * 1_000, 500, 4);
        }
        // 5 finishes in a 10 µs window -> 2 µs per request.
        assert_eq!(m.drain_interval_ns(4_000), Some(2_000));
        assert_eq!(m.est_wait_ns(4_000, 3), 6_000);
    }

    #[test]
    fn goodput_counts_completed_tokens_only() {
        let mut m = SloMonitor::new(1_000_000_000); // 1 s window
        m.note_finish(10, 100, 32);
        m.note_finish(20, 100, 32);
        assert!((m.goodput_tps(30) - 64.0).abs() < 1e-9);
    }

    #[test]
    fn budget_tightens_when_missing_target() {
        let mut m = SloMonitor::new(1_000_000);
        // Meeting the target: budget == target.
        m.note_finish(100, 40, 1);
        assert_eq!(m.effective_budget(100, 100), 100);
        // Missing by 2x: budget halves.
        m.note_finish(200, 200, 1);
        assert_eq!(m.effective_budget(200, 100), 50);
        // Missing catastrophically: floored at target/4.
        m.note_finish(300, 100_000, 1);
        assert_eq!(m.effective_budget(300, 100), 25);
    }
}
