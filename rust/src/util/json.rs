//! Minimal JSON parser + writer (serde_json is not vendored on this
//! image). Supports the full JSON grammar minus exotic number forms;
//! used for `artifacts/manifest.json`, config files and metric dumps.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking up `{key}`)"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 || f > 2f64.powi(53) {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// `[1,2,3]` -> Vec<usize>.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects: `obj([("a", 1.into())])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(pairs: I) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, found `{}`", c as char, self.i, self.b[self.i] as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte `{}` at {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}` at byte {}, found `{}`", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected `,` or `]` at byte {}, found `{}`", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate"))?
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        c => bail!("bad escape `\\{}`", c as char),
                    }
                }
                c if c < 0x20 => bail!("raw control char in string"),
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c)?;
                        if start + len > self.b.len() {
                            bail!("truncated utf8");
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid utf8 lead byte"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀 ü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀 ü");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn real_manifest_parses() {
        // The actual artifact manifest, if built, must parse.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert!(m.get("config").is_ok());
            assert!(m.get("executables").is_ok());
        }
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "s": [1,2,3]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("s").unwrap().as_usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }
}
