//! Statistics helpers for metrics and bench harnesses (criterion is not
//! vendored; rust/benches/ build their own timing loops on top of these).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation; 0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation (q in [0, 100]). Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Running summary of a stream of samples (latencies, sizes, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn stddev(&self) -> f64 {
        stddev(&self.samples)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn percentile(&self, q: f64) -> f64 {
        percentile(&self.samples, q)
    }

    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Empirical CDF: fraction of samples <= x (the Fig. 2 primitive).
pub fn cdf_at(samples: &[f64], x: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let n = samples.iter().filter(|&&s| s <= x).count();
    n as f64 / samples.len() as f64
}

/// Fixed-bucket histogram over [lo, hi) with `n` equal bins (plus
/// under/overflow), used for latency distributions in metrics.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Self { lo, hi, buckets: vec![0; n], underflow: 0, overflow: 0, count: 0 }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let i = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[i.min(n - 1)] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.lo;
        }
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return self.lo + width * (i as f64 + 1.0);
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(cdf_at(&[], 1.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [1.0, 2.0, 2.0, 5.0];
        assert_eq!(cdf_at(&xs, 0.5), 0.0);
        assert_eq!(cdf_at(&xs, 2.0), 0.75);
        assert_eq!(cdf_at(&xs, 5.0), 1.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..100 {
            h.add(i as f64);
        }
        assert_eq!(h.count(), 100);
        let q50 = h.quantile(0.5);
        assert!((49.0..=51.0).contains(&q50), "q50={q50}");
    }

    #[test]
    fn histogram_overflow_underflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(-5.0);
        h.add(50.0);
        h.add(5.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.buckets().iter().sum::<u64>(), 1);
    }

    #[test]
    fn summary_roundup() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 3);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert_eq!(s.total(), 6.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }
}
