//! Small self-contained utilities.
//!
//! This image builds fully offline against the vendored `xla` crate
//! closure, so the usual ecosystem crates (serde_json, rand, criterion,
//! proptest) are unavailable. The pieces of them this project needs are
//! small and hand-rolled here, with their own tests:
//!
//! * [`json`] — minimal recursive-descent JSON parser + writer (for
//!   `artifacts/manifest.json`, config files and metric dumps).
//! * [`rng`] — deterministic xoshiro256** RNG + the distributions the
//!   simulators need (normal, lognormal, zipf, exponential).
//! * [`stats`] — mean/percentile/histogram helpers for benches/metrics.
//! * [`check`] — a tiny randomized property-test harness (no shrinking;
//!   failures print the reproducing seed).

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;

/// Randomized property-test harness: runs `cases` random cases of `f`,
/// seeding each case deterministically from `base_seed + i`. On failure,
/// panics with the case seed so the failure is reproducible by unit test.
///
/// A stand-in for `proptest` (not vendored on this image): no shrinking,
/// but deterministic replay via the printed seed.
pub fn check<F>(name: &str, cases: u64, base_seed: u64, mut f: F)
where
    F: FnMut(&mut rng::Rng) -> Result<(), String>,
{
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i);
        let mut rng = rng::Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at seed {seed}: {msg}");
        }
    }
}

/// Format a byte count human-readably (MiB/GiB), for logs and tables.
pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{b:.0} B")
    }
}

/// Format nanoseconds human-readably (ns/µs/ms/s).
pub fn fmt_ns(ns: u64) -> String {
    match ns {
        0..=999 => format!("{ns} ns"),
        1_000..=999_999 => format!("{:.2} µs", ns as f64 / 1e3),
        1_000_000..=999_999_999 => format!("{:.2} ms", ns as f64 / 1e6),
        _ => format!("{:.3} s", ns as f64 / 1e9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(17), "17 ns");
        assert_eq!(fmt_ns(1_500), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000), "2.50 ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.000 s");
    }

    #[test]
    fn check_passes_and_is_deterministic() {
        let mut seen = Vec::new();
        check("collect", 3, 42, |rng| {
            seen.push(rng.u64());
            Ok(())
        });
        let mut seen2 = Vec::new();
        check("collect2", 3, 42, |rng| {
            seen2.push(rng.u64());
            Ok(())
        });
        assert_eq!(seen, seen2);
    }

    #[test]
    #[should_panic(expected = "property `boom` failed at seed 7")]
    fn check_reports_seed() {
        check("boom", 5, 7, |_| Err("nope".into()));
    }
}
