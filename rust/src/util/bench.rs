//! Minimal bench harness (criterion is not vendored on this image).
//!
//! Two measurement styles:
//!
//! * [`Bench::wall`] — wall-clock timing with warmup + fixed iteration
//!   count, reporting mean / p50 / p99 (used by `rust/benches/hot_path.rs`
//!   and the perf pass).
//! * The paper-table benches (`fig*.rs`) mostly report *virtual-time*
//!   results from the simulator; they use [`Table`] for aligned output.
//!
//! Output format is stable so `cargo bench | tee bench_output.txt` diffs
//! cleanly between optimization iterations.

use super::json::Json;
use super::stats::{percentile, Summary};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Wall-clock bench runner.
pub struct Bench {
    warmup_iters: u32,
    iters: u32,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 3, iters: 30 }
    }
}

/// One wall-clock measurement result (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct WallResult {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub iters: u32,
}

impl WallResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            super::fmt_ns(self.mean_ns as u64),
            super::fmt_ns(self.p50_ns as u64),
            super::fmt_ns(self.p99_ns as u64),
            self.iters
        );
    }
}

impl Bench {
    pub fn new(warmup_iters: u32, iters: u32) -> Self {
        assert!(iters > 0);
        Self { warmup_iters, iters }
    }

    /// Print the header matching [`WallResult::print`] rows.
    pub fn header() {
        println!("{:<44} {:>12} {:>12} {:>12}", "BENCH", "MEAN", "P50", "P99");
    }

    /// Time `f` (which should include any per-iteration setup itself or
    /// amortize it via closures capturing prepared state).
    pub fn wall<F: FnMut()>(&self, name: &str, mut f: F) -> WallResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let mut s = Summary::new();
        for &x in &samples {
            s.add(x);
        }
        let r = WallResult {
            name: name.to_string(),
            mean_ns: s.mean(),
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
            iters: self.iters,
        };
        r.print();
        r
    }
}

/// Machine-readable bench summary: named JSON records accumulated during
/// a bench run and written as one `BENCH_<name>.json`-style document, so
/// CI (and humans diffing runs) consume results without scraping the
/// aligned-table stdout. Keys are insertion-independent (BTreeMap), so
/// the emitted file is byte-stable for identical results.
pub struct JsonReport {
    path: PathBuf,
    entries: BTreeMap<String, Json>,
}

impl JsonReport {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), entries: BTreeMap::new() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Record one named result (later adds under the same key override).
    pub fn add(&mut self, key: &str, value: Json) {
        self.entries.insert(key.to_string(), value);
    }

    /// Write the accumulated document to [`JsonReport::path`].
    pub fn write(&self) -> std::io::Result<()> {
        let doc = Json::Obj(self.entries.clone());
        std::fs::write(&self.path, doc.to_string() + "\n")
    }
}

/// Opaque value sink — prevents the optimizer from deleting the measured
/// work (`std::hint::black_box` stand-in usage point for benches).
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned table printer for the paper-figure benches.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    /// Column widths; first column is left-aligned, the rest right-aligned.
    pub fn new(widths: &[usize]) -> Self {
        Self { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            if i == 0 {
                line.push_str(&format!("{cell:<w$}"));
            } else {
                line.push_str(&format!(" {cell:>w$}"));
            }
        }
        println!("{}", line.trim_end());
    }

    pub fn sep(&self) {
        let total: usize = self.widths.iter().sum::<usize>() + self.widths.len() - 1;
        println!("{}", "-".repeat(total));
    }
}

/// Shorthand for building string rows: `cells!["a", 1.5; "{:.1}"]`-free,
/// just map to `to_string`.
#[macro_export]
macro_rules! cells {
    ($($x:expr),* $(,)?) => {
        &[$($x.to_string()),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_measures_positive_time() {
        let b = Bench::new(1, 5);
        let r = b.wall("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(sink(i));
            }
            sink(acc);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn json_report_roundtrips_through_parser() {
        let dir = std::env::temp_dir().join("harvest_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let mut r = JsonReport::new(&path);
        r.add("alpha", crate::util::json::obj([("tps", Json::from(123.5))]));
        r.add("beta", Json::from(7u64));
        r.write().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("alpha").unwrap().get("tps").unwrap().as_f64().unwrap(), 123.5);
        assert_eq!(parsed.get("beta").unwrap().as_u64().unwrap(), 7);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn table_prints_without_panic() {
        let t = Table::new(&[10, 8, 8]);
        t.row(cells!["model", "a", "b"]);
        t.sep();
        t.row(cells!["x", 1, 2.5]);
    }
}
