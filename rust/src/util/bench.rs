//! Minimal bench harness (criterion is not vendored on this image).
//!
//! Two measurement styles:
//!
//! * [`Bench::wall`] — wall-clock timing with warmup + fixed iteration
//!   count, reporting mean / p50 / p99 (used by `rust/benches/hot_path.rs`
//!   and the perf pass).
//! * The paper-table benches (`fig*.rs`) mostly report *virtual-time*
//!   results from the simulator; they use [`Table`] for aligned output.
//!
//! Output format is stable so `cargo bench | tee bench_output.txt` diffs
//! cleanly between optimization iterations.

use super::json::{obj, Json};
use super::stats::{percentile, Summary};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Wall-clock bench runner.
pub struct Bench {
    warmup_iters: u32,
    iters: u32,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 3, iters: 30 }
    }
}

/// One wall-clock measurement result (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct WallResult {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub iters: u32,
}

impl WallResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            super::fmt_ns(self.mean_ns as u64),
            super::fmt_ns(self.p50_ns as u64),
            super::fmt_ns(self.p99_ns as u64),
            self.iters
        );
    }
}

impl Bench {
    pub fn new(warmup_iters: u32, iters: u32) -> Self {
        assert!(iters > 0);
        Self { warmup_iters, iters }
    }

    /// Print the header matching [`WallResult::print`] rows.
    pub fn header() {
        println!("{:<44} {:>12} {:>12} {:>12}", "BENCH", "MEAN", "P50", "P99");
    }

    /// Time `f` (which should include any per-iteration setup itself or
    /// amortize it via closures capturing prepared state).
    pub fn wall<F: FnMut()>(&self, name: &str, mut f: F) -> WallResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let mut s = Summary::new();
        for &x in &samples {
            s.add(x);
        }
        let r = WallResult {
            name: name.to_string(),
            mean_ns: s.mean(),
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
            iters: self.iters,
        };
        r.print();
        r
    }
}

/// Machine-readable bench summary: named JSON records accumulated during
/// a bench run and written as one `BENCH_<name>.json`-style document, so
/// CI (and humans diffing runs) consume results without scraping the
/// aligned-table stdout. Keys are insertion-independent (BTreeMap), so
/// the emitted file is byte-stable for identical results.
pub struct JsonReport {
    path: PathBuf,
    entries: BTreeMap<String, Json>,
}

impl JsonReport {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), entries: BTreeMap::new() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Record one named result (later adds under the same key override).
    pub fn add(&mut self, key: &str, value: Json) {
        self.entries.insert(key.to_string(), value);
    }

    /// Write the accumulated document to [`JsonReport::path`].
    pub fn write(&self) -> std::io::Result<()> {
        let doc = Json::Obj(self.entries.clone());
        std::fs::write(&self.path, doc.to_string() + "\n")
    }

    /// Append the accumulated document as one datapoint of a committed
    /// perf *trajectory* (`{"points": [{label, smoke, data}, …]}`), the
    /// format `harvest guard` compares across PRs. A missing or
    /// unparseable file starts an empty trajectory; a legacy flat bench
    /// document is first wrapped as a `"seed"` point so history is kept.
    /// The trajectory is capped at [`TRAJECTORY_CAP`] points (oldest
    /// dropped first).
    pub fn append_trajectory(&self, label: &str, smoke: bool) -> std::io::Result<()> {
        let mut points = load_trajectory(&self.path);
        points.push(TrajectoryPoint {
            label: label.to_string(),
            smoke,
            data: Json::Obj(self.entries.clone()),
        });
        if points.len() > TRAJECTORY_CAP {
            let excess = points.len() - TRAJECTORY_CAP;
            points.drain(..excess);
        }
        let arr: Vec<Json> =
            points.into_iter().map(|p| point_json(&p.label, p.smoke, p.data)).collect();
        let doc = obj([("points", Json::Arr(arr))]);
        std::fs::write(&self.path, doc.to_string() + "\n")
    }
}

/// Max datapoints kept per committed trajectory file.
pub const TRAJECTORY_CAP: usize = 50;

/// One datapoint of a committed bench trajectory: the bench document
/// (`data`) tagged with the run that produced it (`label`, typically a
/// commit sha) and whether it came from the CI smoke tier (`smoke`) —
/// smoke and full runs are never compared against each other.
#[derive(Debug, Clone, PartialEq)]
pub struct TrajectoryPoint {
    pub label: String,
    pub smoke: bool,
    pub data: Json,
}

fn point_json(label: &str, smoke: bool, data: Json) -> Json {
    obj([("label", Json::from(label)), ("smoke", Json::Bool(smoke)), ("data", data)])
}

/// Parse a `BENCH_*.json` document into trajectory points. Accepts both
/// the trajectory form (`{"points": […]}`) and the legacy flat bench
/// document, which wraps as a single pre-trajectory `"seed"` point.
pub fn parse_trajectory(doc: &Json) -> Vec<TrajectoryPoint> {
    if let Some(Json::Arr(points)) = doc.opt("points") {
        points
            .iter()
            .map(|p| TrajectoryPoint {
                label: p.opt("label").and_then(|l| l.as_str().ok()).unwrap_or("?").to_string(),
                smoke: matches!(p.opt("smoke"), Some(Json::Bool(true))),
                data: p.opt("data").cloned().unwrap_or(Json::Null),
            })
            .collect()
    } else {
        vec![TrajectoryPoint { label: "seed".to_string(), smoke: false, data: doc.clone() }]
    }
}

/// Read a trajectory file; missing or unparseable files read as empty.
pub fn load_trajectory(path: &Path) -> Vec<TrajectoryPoint> {
    match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(doc) => parse_trajectory(&doc),
            Err(_) => Vec::new(),
        },
        Err(_) => Vec::new(),
    }
}

/// Walk a dotted path (`"knee.occupancy_p99_pre_knee_ns"`) into one
/// point's bench document. Path segments themselves never contain dots.
pub fn metric_at(data: &Json, dotted: &str) -> Option<f64> {
    let mut cur = data;
    for key in dotted.split('.') {
        cur = cur.opt(key)?;
    }
    cur.as_f64().ok()
}

/// The guard comparison pair: the newest point's metric and the metric
/// of the most recent *earlier* point with the same smoke flag. `None`
/// until the trajectory holds two comparable points carrying the metric
/// (the "baseline recorded" case).
pub fn latest_pair(points: &[TrajectoryPoint], dotted: &str) -> Option<(f64, f64)> {
    let latest = points.last()?;
    let latest_v = metric_at(&latest.data, dotted)?;
    let prev = points[..points.len() - 1].iter().rev().find(|p| p.smoke == latest.smoke)?;
    let prev_v = metric_at(&prev.data, dotted)?;
    Some((prev_v, latest_v))
}

/// Fractional regression of `latest` against `prev` (positive = worse,
/// e.g. `0.25` = 25% slower). Non-positive baselines compare as 0.
pub fn regression_frac(prev: f64, latest: f64, higher_better: bool) -> f64 {
    if prev <= 0.0 {
        return 0.0;
    }
    if higher_better {
        (prev - latest) / prev
    } else {
        (latest - prev) / prev
    }
}

/// Opaque value sink — prevents the optimizer from deleting the measured
/// work (`std::hint::black_box` stand-in usage point for benches).
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned table printer for the paper-figure benches.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    /// Column widths; first column is left-aligned, the rest right-aligned.
    pub fn new(widths: &[usize]) -> Self {
        Self { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            if i == 0 {
                line.push_str(&format!("{cell:<w$}"));
            } else {
                line.push_str(&format!(" {cell:>w$}"));
            }
        }
        println!("{}", line.trim_end());
    }

    pub fn sep(&self) {
        let total: usize = self.widths.iter().sum::<usize>() + self.widths.len() - 1;
        println!("{}", "-".repeat(total));
    }
}

/// Shorthand for building string rows: `cells!["a", 1.5; "{:.1}"]`-free,
/// just map to `to_string`.
#[macro_export]
macro_rules! cells {
    ($($x:expr),* $(,)?) => {
        &[$($x.to_string()),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_measures_positive_time() {
        let b = Bench::new(1, 5);
        let r = b.wall("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(sink(i));
            }
            sink(acc);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn json_report_roundtrips_through_parser() {
        let dir = std::env::temp_dir().join("harvest_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let mut r = JsonReport::new(&path);
        r.add("alpha", crate::util::json::obj([("tps", Json::from(123.5))]));
        r.add("beta", Json::from(7u64));
        r.write().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("alpha").unwrap().get("tps").unwrap().as_f64().unwrap(), 123.5);
        assert_eq!(parsed.get("beta").unwrap().as_u64().unwrap(), 7);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn trajectory_wraps_legacy_file_and_appends() {
        let dir = std::env::temp_dir().join("harvest_bench_traj_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_traj.json");
        // Start from a legacy flat document (pre-trajectory format).
        std::fs::write(&path, "{\"knee\": {\"qps\": 120.0}}\n").unwrap();
        let mut r = JsonReport::new(&path);
        r.add("knee", crate::util::json::obj([("qps", Json::from(90.0))]));
        r.append_trajectory("abc123", true).unwrap();
        let points = load_trajectory(&path);
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].label, "seed");
        assert!(!points[0].smoke);
        assert_eq!(metric_at(&points[0].data, "knee.qps"), Some(120.0));
        assert_eq!(points[1].label, "abc123");
        assert!(points[1].smoke);
        assert_eq!(metric_at(&points[1].data, "knee.qps"), Some(90.0));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn guard_pair_compares_same_smoke_tier_only() {
        let pt = |label: &str, smoke: bool, v: f64| TrajectoryPoint {
            label: label.to_string(),
            smoke,
            data: crate::util::json::obj([("steps_per_sec", Json::from(v))]),
        };
        // Seed (full run) must not serve as baseline for a smoke point.
        let points = vec![pt("seed", false, 500.0), pt("a", true, 100.0), pt("b", true, 80.0)];
        let (prev, latest) = latest_pair(&points, "steps_per_sec").unwrap();
        assert_eq!((prev, latest), (100.0, 80.0));
        assert!((regression_frac(prev, latest, true) - 0.2).abs() < 1e-9);
        assert!(regression_frac(prev, latest, false) < 0.0);
        // Only one smoke point → no comparable baseline yet.
        let young = vec![pt("seed", false, 500.0), pt("a", true, 100.0)];
        assert!(latest_pair(&young, "steps_per_sec").is_none());
        assert!(latest_pair(&points, "missing.metric").is_none());
    }

    #[test]
    fn table_prints_without_panic() {
        let t = Table::new(&[10, 8, 8]);
        t.row(cells!["model", "a", "b"]);
        t.sep();
        t.row(cells!["x", 1, 2.5]);
    }
}
