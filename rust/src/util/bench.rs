//! Minimal bench harness (criterion is not vendored on this image).
//!
//! Two measurement styles:
//!
//! * [`Bench::wall`] — wall-clock timing with warmup + fixed iteration
//!   count, reporting mean / p50 / p99 (used by `rust/benches/hot_path.rs`
//!   and the perf pass).
//! * The paper-table benches (`fig*.rs`) mostly report *virtual-time*
//!   results from the simulator; they use [`Table`] for aligned output.
//!
//! Output format is stable so `cargo bench | tee bench_output.txt` diffs
//! cleanly between optimization iterations.

use super::stats::{percentile, Summary};
use std::time::Instant;

/// Wall-clock bench runner.
pub struct Bench {
    warmup_iters: u32,
    iters: u32,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 3, iters: 30 }
    }
}

/// One wall-clock measurement result (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct WallResult {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub iters: u32,
}

impl WallResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            super::fmt_ns(self.mean_ns as u64),
            super::fmt_ns(self.p50_ns as u64),
            super::fmt_ns(self.p99_ns as u64),
            self.iters
        );
    }
}

impl Bench {
    pub fn new(warmup_iters: u32, iters: u32) -> Self {
        assert!(iters > 0);
        Self { warmup_iters, iters }
    }

    /// Print the header matching [`WallResult::print`] rows.
    pub fn header() {
        println!("{:<44} {:>12} {:>12} {:>12}", "BENCH", "MEAN", "P50", "P99");
    }

    /// Time `f` (which should include any per-iteration setup itself or
    /// amortize it via closures capturing prepared state).
    pub fn wall<F: FnMut()>(&self, name: &str, mut f: F) -> WallResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let mut s = Summary::new();
        for &x in &samples {
            s.add(x);
        }
        let r = WallResult {
            name: name.to_string(),
            mean_ns: s.mean(),
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
            iters: self.iters,
        };
        r.print();
        r
    }
}

/// Opaque value sink — prevents the optimizer from deleting the measured
/// work (`std::hint::black_box` stand-in usage point for benches).
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned table printer for the paper-figure benches.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    /// Column widths; first column is left-aligned, the rest right-aligned.
    pub fn new(widths: &[usize]) -> Self {
        Self { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            if i == 0 {
                line.push_str(&format!("{cell:<w$}"));
            } else {
                line.push_str(&format!(" {cell:>w$}"));
            }
        }
        println!("{}", line.trim_end());
    }

    pub fn sep(&self) {
        let total: usize = self.widths.iter().sum::<usize>() + self.widths.len() - 1;
        println!("{}", "-".repeat(total));
    }
}

/// Shorthand for building string rows: `cells!["a", 1.5; "{:.1}"]`-free,
/// just map to `to_string`.
#[macro_export]
macro_rules! cells {
    ($($x:expr),* $(,)?) => {
        &[$($x.to_string()),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_measures_positive_time() {
        let b = Bench::new(1, 5);
        let r = b.wall("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(sink(i));
            }
            sink(acc);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn table_prints_without_panic() {
        let t = Table::new(&[10, 8, 8]);
        t.row(cells!["model", "a", "b"]);
        t.sep();
        t.row(cells!["x", 1, 2.5]);
    }
}
