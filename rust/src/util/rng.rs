//! Deterministic RNG (xoshiro256**) + the distributions the simulators
//! need. No external crates; every simulation in this repo is exactly
//! reproducible from its seed.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent child stream (for per-component RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiasedness.
        let mut x = self.u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Sample from an explicit discrete distribution (weights need not be
    /// normalised). Returns an index.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Zipf(s) sampler over {0, .., n-1} using precomputed CDF — models the
/// skewed expert-activation / prefix-reuse popularity the paper leans on
/// (§4.2: "expert access patterns are highly skewed").
#[derive(Debug, Clone)]
pub struct Zipf {
    /// Walker alias tables: O(1) sampling (the router hot path samples
    /// top-k × tokens × layers × micro-batches per decode pass — see
    /// EXPERIMENTS.md §Perf).
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut w: Vec<f64> = (1..=n).map(|i| 1.0 / (i as f64).powf(s)).collect();
        let total: f64 = w.iter().sum();
        // scaled to mean 1
        for x in &mut w {
            *x *= n as f64 / total;
        }
        // Vose's alias construction.
        let mut prob = vec![0.0; n];
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &x) in w.iter().enumerate() {
            if x < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s_), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s_] = w[s_];
            alias[s_] = l;
            w[l] = (w[l] + w[s_]) - 1.0;
            if w[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &i in large.iter().chain(small.iter()) {
            prob[i] = 1.0;
            alias[i] = i;
        }
        Self { prob, alias }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let n = self.prob.len();
        let i = rng.below(n as u64) as usize;
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    pub fn n(&self) -> usize {
        self.prob.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let x = r.range(3, 6);
            assert!((3..=6).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 6;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(8);
        let z = Zipf::new(16, 1.1);
        let mut counts = [0u32; 16];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[8] * 4, "counts={counts:?}");
        assert!(counts.iter().all(|&c| c > 0));
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0], "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.u64(), b.u64());
    }
}
