//! Watermark-driven write-back eviction planning.
//!
//! The [`Evictor`] decides *what to push down the ladder and when*:
//! it tracks last-touch times and dirty bits per cached entry, and when
//! occupancy crosses the high watermark it plans evictions —
//! oldest-idle first, skipping entries touched more recently than the
//! configured idle age — until the projected occupancy falls back
//! under the low watermark. Dirty entries come back as write-backs
//! (the bytes must reach the lower tier before the fast copy is
//! reclaimed); clean entries are plain drops.
//!
//! The evictor is pure planning: it never moves bytes itself. Callers
//! (the KV offload manager, the tier-ladder bench) execute each
//! [`EvictAction`] with `Transfer::migrate` / `Transfer::compress` and
//! then [`Evictor::forget`] the entry.

use std::collections::{BTreeMap, BTreeSet};

/// Virtual-time nanoseconds (matches the simulator clock).
type Ns = u64;

/// Thresholds steering [`Evictor::plan`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvictorConfig {
    /// Start evicting when `used > high_watermark * capacity`.
    pub high_watermark: f64,
    /// Keep evicting until projected `used <= low_watermark * capacity`.
    pub low_watermark: f64,
    /// Only entries idle at least this long are eviction candidates.
    pub idle_age_ns: Ns,
}

impl Default for EvictorConfig {
    /// Evict above 90% occupancy down to 70%, considering entries idle
    /// for at least 1 ms of virtual time.
    fn default() -> Self {
        Self { high_watermark: 0.90, low_watermark: 0.70, idle_age_ns: 1_000_000 }
    }
}

/// One planned eviction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictAction {
    /// The caller-assigned entry id (e.g. a KV block id).
    pub id: u64,
    /// True if the entry is dirty and its bytes must be written back
    /// to the lower tier; false means the copy can simply be dropped.
    pub write_back: bool,
}

/// Dirty/age tracker plus watermark eviction planner.
///
/// ```
/// use harvest::coldtier::{Evictor, EvictorConfig};
///
/// let mut ev = Evictor::new(EvictorConfig {
///     high_watermark: 0.8,
///     low_watermark: 0.5,
///     idle_age_ns: 100,
/// });
/// ev.touch(1, 0);
/// ev.touch(2, 50);
/// ev.mark_dirty(1);
///
/// // 90 of 100 bytes used at t=500: over the 80% high watermark, so
/// // plan evictions (oldest idle first) down to the 50% low watermark.
/// let plan = ev.plan(90, 100, 500, |_| 40);
/// assert_eq!(plan.len(), 1); // one 40-byte victim gets us to 50
/// assert_eq!(plan[0].id, 1); // entry 1 is oldest
/// assert!(plan[0].write_back); // and dirty
/// ```
#[derive(Debug, Clone, Default)]
pub struct Evictor {
    config: EvictorConfig,
    last_touch: BTreeMap<u64, Ns>,
    dirty: BTreeSet<u64>,
}

impl Evictor {
    /// New evictor with the given thresholds.
    ///
    /// # Panics
    ///
    /// Panics unless `0 < low <= high <= 1`.
    pub fn new(config: EvictorConfig) -> Self {
        assert!(
            config.low_watermark > 0.0
                && config.low_watermark <= config.high_watermark
                && config.high_watermark <= 1.0,
            "watermarks must satisfy 0 < low <= high <= 1"
        );
        Self { config, last_touch: BTreeMap::new(), dirty: BTreeSet::new() }
    }

    /// The active thresholds.
    pub fn config(&self) -> EvictorConfig {
        self.config
    }

    /// Record an access to `id` at virtual time `now` (registers the
    /// entry on first touch).
    pub fn touch(&mut self, id: u64, now: Ns) {
        self.last_touch.insert(id, now);
    }

    /// Mark `id` dirty: its next eviction must write back.
    pub fn mark_dirty(&mut self, id: u64) {
        self.dirty.insert(id);
    }

    /// Clear the dirty bit (e.g. after an explicit write-back).
    pub fn mark_clean(&mut self, id: u64) {
        self.dirty.remove(&id);
    }

    /// Is `id` currently dirty?
    pub fn is_dirty(&self, id: u64) -> bool {
        self.dirty.contains(&id)
    }

    /// Number of tracked entries.
    pub fn tracked(&self) -> usize {
        self.last_touch.len()
    }

    /// Last touch time for `id`, if tracked.
    pub fn last_touch(&self, id: u64) -> Option<Ns> {
        self.last_touch.get(&id).copied()
    }

    /// Drop all state for `id` (call after executing its eviction).
    pub fn forget(&mut self, id: u64) {
        self.last_touch.remove(&id);
        self.dirty.remove(&id);
    }

    /// Plan evictions for a tier holding `used` of `capacity` bytes at
    /// virtual time `now`; `size_of(id)` reports each entry's size.
    ///
    /// Returns an empty plan while `used <= high_watermark * capacity`.
    /// Otherwise picks tracked entries oldest-idle first — skipping any
    /// touched within `idle_age_ns` — until the projected occupancy is
    /// at or below the low watermark (or candidates run out). Planned
    /// entries are *not* forgotten; the caller forgets them once the
    /// eviction actually executes.
    pub fn plan(
        &self,
        used: u64,
        capacity: u64,
        now: Ns,
        mut size_of: impl FnMut(u64) -> u64,
    ) -> Vec<EvictAction> {
        let high = (self.config.high_watermark * capacity as f64) as u64;
        let low = (self.config.low_watermark * capacity as f64) as u64;
        if used <= high {
            return Vec::new();
        }

        // Oldest idle first; entry id breaks ties deterministically.
        let mut candidates: Vec<(Ns, u64)> = self
            .last_touch
            .iter()
            .filter(|(_, &t)| now.saturating_sub(t) >= self.config.idle_age_ns)
            .map(|(&id, &t)| (t, id))
            .collect();
        candidates.sort_unstable();

        let mut projected = used;
        let mut plan = Vec::new();
        for (_, id) in candidates {
            if projected <= low {
                break;
            }
            plan.push(EvictAction { id, write_back: self.dirty.contains(&id) });
            projected = projected.saturating_sub(size_of(id));
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn evictor() -> Evictor {
        Evictor::new(EvictorConfig { high_watermark: 0.8, low_watermark: 0.5, idle_age_ns: 100 })
    }

    #[test]
    fn under_high_watermark_plans_nothing() {
        let mut ev = evictor();
        ev.touch(1, 0);
        assert!(ev.plan(80, 100, 1_000, |_| 10).is_empty());
    }

    #[test]
    fn evicts_oldest_idle_down_to_low_watermark() {
        let mut ev = evictor();
        ev.touch(1, 0); // oldest
        ev.touch(2, 10);
        ev.touch(3, 950); // too recent at now=1000 (idle 50 < 100)
        ev.mark_dirty(2);

        let plan = ev.plan(95, 100, 1_000, |_| 25);
        // 95 -> 70 -> 45 <= 50: two victims, oldest first.
        assert_eq!(
            plan,
            vec![
                EvictAction { id: 1, write_back: false },
                EvictAction { id: 2, write_back: true },
            ]
        );
    }

    #[test]
    fn recent_entries_are_exempt_even_under_pressure() {
        let mut ev = evictor();
        ev.touch(1, 990);
        ev.touch(2, 995);
        assert!(ev.plan(100, 100, 1_000, |_| 50).is_empty());
    }

    #[test]
    fn dirty_bit_lifecycle() {
        let mut ev = evictor();
        ev.touch(7, 0);
        assert!(!ev.is_dirty(7));
        ev.mark_dirty(7);
        assert!(ev.is_dirty(7));
        ev.mark_clean(7);
        assert!(!ev.is_dirty(7));
        ev.mark_dirty(7);
        ev.forget(7);
        assert!(!ev.is_dirty(7));
        assert_eq!(ev.tracked(), 0);
        assert_eq!(ev.last_touch(7), None);
    }

    #[test]
    #[should_panic(expected = "watermarks")]
    fn inverted_watermarks_panic() {
        let _ = Evictor::new(EvictorConfig {
            high_watermark: 0.5,
            low_watermark: 0.8,
            idle_age_ns: 0,
        });
    }
}
