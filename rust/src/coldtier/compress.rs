//! Modeled layer-wise KV compression.
//!
//! The [`Compressor`] models PyramidInfer-style token pruning plus
//! quantization as two numbers: the **ratio** (compressed size as a
//! percent of the original) and the **decode-side cost** (ns per
//! original byte to reconstruct the tensors on reload). Compressing is
//! free in virtual time — pruning happens as a side effect of attention
//! compute — so the model charges nothing up front and everything on
//! the next read, which is exactly when a real serving stack would pay
//! the dequantize/scatter kernels.
//!
//! The size formula is shared verbatim with the harvest controller's
//! in-place `compress_lease` so that pager accounting, lease
//! accounting, and this model can never disagree.

/// Compression-ratio + decompression-cost model.
///
/// ```
/// use harvest::coldtier::Compressor;
///
/// // Keep 25% of bytes; decompression reconstructs at 4 GB/s (0.25 ns/byte).
/// let c = Compressor::new(25, 0.25);
/// assert_eq!(c.compressed_size(1024), 256);
/// assert_eq!(c.compressed_size(1), 1); // never rounds to zero
/// assert_eq!(c.saved_bytes(1024), 768);
/// assert_eq!(c.decompress_cost_ns(1024), 256);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Compressor {
    ratio_pct: u32,
    decompress_ns_per_byte: f64,
}

impl Default for Compressor {
    /// Keep 50% of bytes; reconstruct at ~4 GB/s (0.25 ns per original
    /// byte).
    fn default() -> Self {
        Self::new(50, 0.25)
    }
}

impl Compressor {
    /// New model keeping `ratio_pct` percent of bytes and charging
    /// `decompress_ns_per_byte` (per *original* byte) on reload.
    ///
    /// # Panics
    ///
    /// Panics unless `1 <= ratio_pct <= 99` and the cost is
    /// non-negative and finite.
    pub fn new(ratio_pct: u32, decompress_ns_per_byte: f64) -> Self {
        assert!((1..=99).contains(&ratio_pct), "compression ratio must be 1..=99 percent");
        assert!(
            decompress_ns_per_byte.is_finite() && decompress_ns_per_byte >= 0.0,
            "decompression cost must be finite and non-negative"
        );
        Self { ratio_pct, decompress_ns_per_byte }
    }

    /// Compressed size as a percent of the original.
    pub fn ratio_pct(&self) -> u32 {
        self.ratio_pct
    }

    /// Decode-side reconstruction cost in ns per original byte.
    pub fn decompress_ns_per_byte(&self) -> f64 {
        self.decompress_ns_per_byte
    }

    /// Size after compressing `original` bytes: floor at the ratio but
    /// never below one byte. Zero stays zero (nothing to compress).
    ///
    /// This is the exact formula the harvest controller applies when it
    /// shrinks a lease in place, so tier accounting and this model
    /// always agree.
    pub fn compressed_size(&self, original: u64) -> u64 {
        if original == 0 {
            return 0;
        }
        (original * u64::from(self.ratio_pct) / 100).max(1)
    }

    /// Bytes released by compressing `original` bytes.
    pub fn saved_bytes(&self, original: u64) -> u64 {
        original - self.compressed_size(original)
    }

    /// Virtual-time cost to reconstruct a segment that was `original`
    /// bytes before compression.
    pub fn decompress_cost_ns(&self, original: u64) -> u64 {
        (original as f64 * self.decompress_ns_per_byte).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_controller_formula() {
        let c = Compressor::new(50, 0.25);
        assert_eq!(c.compressed_size(0), 0);
        assert_eq!(c.compressed_size(1), 1); // 1*50/100 = 0 -> clamped to 1
        assert_eq!(c.compressed_size(100), 50);
        assert_eq!(c.compressed_size(101), 50); // floor division
        let gib = 1u64 << 30;
        assert_eq!(c.compressed_size(2 * gib), gib);
        assert_eq!(c.saved_bytes(2 * gib), gib);
    }

    #[test]
    fn decompress_cost_scales_with_original_bytes() {
        let c = Compressor::new(25, 0.5);
        assert_eq!(c.decompress_cost_ns(0), 0);
        assert_eq!(c.decompress_cost_ns(1), 1); // 0.5 ns rounds up
        assert_eq!(c.decompress_cost_ns(1000), 500);
        let free = Compressor::new(25, 0.0);
        assert_eq!(free.decompress_cost_ns(1 << 30), 0);
    }

    #[test]
    fn default_is_half_size_at_4gbps() {
        let c = Compressor::default();
        assert_eq!(c.ratio_pct(), 50);
        assert_eq!(c.compressed_size(1 << 20), 1 << 19);
        assert!((c.decompress_ns_per_byte() - 0.25).abs() < f64::EPSILON);
    }

    #[test]
    #[should_panic(expected = "ratio")]
    fn ratio_100_panics() {
        let _ = Compressor::new(100, 0.25);
    }

    #[test]
    #[should_panic(expected = "ratio")]
    fn ratio_0_panics() {
        let _ = Compressor::new(0, 0.25);
    }
}
