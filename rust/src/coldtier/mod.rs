//! The SSD cold tier (the bottom rung of the demotion ladder).
//!
//! The paper's tier story stops at host memory: when host and CXL fill
//! up, revoking a lossy lease ends in `Dropped` → recompute, so a
//! long-idle multi-turn session pays full prefill on return. This
//! subsystem extends the ladder two rungs further — **compressed in
//! place**, then **paged out to a byte-addressed SSD arena** — so idle
//! sessions age peer → host/CXL → compressed → SSD and come back with
//! zero recomputes, paying only page-in latency plus a modeled
//! decompression cost.
//!
//! Three components, each usable standalone:
//!
//! * [`Pager`] — fixed-size pages over the SSD arena
//!   ([`crate::memsim::SimNode::ssd`]): a page table keyed by arena
//!   [`crate::memsim::AllocId`] plus free accounting. Every SSD-resident
//!   lease occupies whole pages, so arena occupancy always equals
//!   `pages_mapped() * page_bytes()` — the invariant
//!   [`Pager::balances`] checks.
//! * [`Evictor`] — watermark-driven write-back planning: dirty tracking
//!   and last-touch ages per cached entry, and a [`Evictor::plan`] that
//!   picks oldest-idle victims (write-back for dirty entries, plain
//!   drop for clean ones) until occupancy falls back under the low
//!   watermark.
//! * [`Compressor`] — modeled layer-wise token-pruning/quantization
//!   (PyramidInfer-style): a configurable compressed-size ratio and a
//!   decode-side decompression cost in ns/byte. Compression itself is
//!   free in virtual time — the cost is charged when the bytes are next
//!   read.
//!
//! The tier machinery in [`crate::harvest`] wires these in:
//! `MemoryTier::Ssd` allocations route through the controller's pager,
//! `Transfer::compress` / `Transfer::decompress` reshape leases in
//! place, and the pressure ladder under
//! [`crate::harvest::HarvestConfig::compress_before_demote`] tries
//! compress → demote → drop before losing any bytes.
//!
//! ```
//! use harvest::coldtier::{Compressor, Pager};
//! use harvest::memsim::{FitStrategy, Hbm};
//!
//! // A 16 MiB SSD arena paged at 2 MiB.
//! let mut ssd = Hbm::new(16 << 20, FitStrategy::BestFit);
//! let mut pager = Pager::new(2 << 20);
//! let comp = Compressor::new(50, 0.25);
//!
//! // A 5 MiB KV segment compresses to 2.5 MiB and pages out in 2 pages.
//! let compressed = comp.compressed_size(5 << 20);
//! assert_eq!(compressed, (5 << 20) / 2);
//! let seg = ssd.alloc(pager.padded(compressed)).unwrap();
//! pager.map(seg, compressed);
//! assert_eq!(pager.pages_mapped(), 2);
//! assert!(pager.balances(&ssd));
//!
//! // Decode-side: reloading charges the modeled decompression cost.
//! assert_eq!(comp.decompress_cost_ns(5 << 20), ((5u64 << 20) as f64 * 0.25) as u64);
//! pager.unmap(seg);
//! ssd.free(seg);
//! assert!(pager.balances(&ssd));
//! ```

pub mod compress;
pub mod evict;
pub mod pager;

pub use compress::Compressor;
pub use evict::{EvictAction, Evictor, EvictorConfig};
pub use pager::{PageRun, Pager};
