//! Fixed-size paging over the byte-addressed SSD arena.
//!
//! The SSD arena ([`crate::memsim::SimNode::ssd`]) is an ordinary
//! [`Hbm`] byte allocator, but NVMe devices don't hand out bytes — they
//! hand out blocks. The [`Pager`] models that: every cold-tier resident
//! occupies a whole number of fixed-size pages, and the pager keeps the
//! page table (arena segment → page run) plus free accounting so the
//! tier machinery can assert, at every boundary, that the page table
//! and the arena agree ([`Pager::balances`]).
//!
//! The pager does not own the arena; callers allocate
//! [`Pager::padded`] bytes from it, then [`Pager::map`] the returned
//! [`AllocId`] with the *logical* (unpadded) size. The difference is
//! tracked as internal-fragmentation slack ([`Pager::slack_bytes`]).

use std::collections::BTreeMap;

use crate::memsim::{AllocId, Hbm};

/// One page-table entry: the run of pages backing an arena segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageRun {
    /// Number of fixed-size pages in the run.
    pub pages: u64,
    /// Logical bytes stored (≤ `pages * page_bytes`).
    pub logical_bytes: u64,
}

/// Page table + free accounting for the SSD arena.
///
/// ```
/// use harvest::coldtier::Pager;
/// use harvest::memsim::{FitStrategy, Hbm};
///
/// let mut ssd = Hbm::new(8 << 20, FitStrategy::BestFit);
/// let mut pager = Pager::new(2 << 20); // 2 MiB pages
///
/// // A 3 MiB payload rounds up to 2 pages (4 MiB).
/// assert_eq!(pager.padded(3 << 20), 4 << 20);
/// let seg = ssd.alloc(pager.padded(3 << 20)).unwrap();
/// pager.map(seg, 3 << 20);
///
/// assert_eq!(pager.pages_mapped(), 2);
/// assert_eq!(pager.mapped_bytes(), 4 << 20);
/// assert_eq!(pager.logical_bytes(), 3 << 20);
/// assert_eq!(pager.slack_bytes(), 1 << 20);
/// assert!(pager.balances(&ssd));
///
/// pager.unmap(seg);
/// ssd.free(seg);
/// assert_eq!(pager.pages_mapped(), 0);
/// assert!(pager.balances(&ssd));
/// ```
#[derive(Debug, Clone)]
pub struct Pager {
    page_bytes: u64,
    table: BTreeMap<AllocId, PageRun>,
    pages_mapped: u64,
    logical_bytes: u64,
}

impl Pager {
    /// New pager with the given page size.
    ///
    /// # Panics
    ///
    /// Panics if `page_bytes` is zero.
    pub fn new(page_bytes: u64) -> Self {
        assert!(page_bytes > 0, "page size must be non-zero");
        Self { page_bytes, table: BTreeMap::new(), pages_mapped: 0, logical_bytes: 0 }
    }

    /// The fixed page size in bytes.
    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    /// Pages needed to hold `size` logical bytes (zero stays zero).
    pub fn pages_for(&self, size: u64) -> u64 {
        size.div_ceil(self.page_bytes)
    }

    /// `size` rounded up to a whole number of pages — the amount to
    /// actually allocate from the SSD arena.
    pub fn padded(&self, size: u64) -> u64 {
        self.pages_for(size) * self.page_bytes
    }

    /// Record that arena segment `seg` (of [`Self::padded`]`(size)`
    /// bytes) now backs `size` logical bytes.
    ///
    /// # Panics
    ///
    /// Panics if `seg` is already mapped or `size` is zero — both
    /// indicate tier-accounting bugs upstream.
    pub fn map(&mut self, seg: AllocId, size: u64) {
        assert!(size > 0, "mapping zero logical bytes");
        let run = PageRun { pages: self.pages_for(size), logical_bytes: size };
        let prev = self.table.insert(seg, run);
        assert!(prev.is_none(), "segment already mapped in page table");
        self.pages_mapped += run.pages;
        self.logical_bytes += run.logical_bytes;
    }

    /// Drop the page-table entry for `seg`, returning its run.
    ///
    /// The caller still owns the arena segment and must free it
    /// separately.
    ///
    /// # Panics
    ///
    /// Panics if `seg` is not mapped.
    pub fn unmap(&mut self, seg: AllocId) -> PageRun {
        let run = self.table.remove(&seg).expect("unmap of segment not in page table");
        self.pages_mapped -= run.pages;
        self.logical_bytes -= run.logical_bytes;
        run
    }

    /// Page-table entry for `seg`, if mapped.
    pub fn run_of(&self, seg: AllocId) -> Option<PageRun> {
        self.table.get(&seg).copied()
    }

    /// Number of mapped segments (page-table entries).
    pub fn mapped_segments(&self) -> usize {
        self.table.len()
    }

    /// Total pages currently mapped.
    pub fn pages_mapped(&self) -> u64 {
        self.pages_mapped
    }

    /// Total mapped bytes (`pages_mapped * page_bytes`) — must equal
    /// SSD arena occupancy at every quiescent boundary.
    pub fn mapped_bytes(&self) -> u64 {
        self.pages_mapped * self.page_bytes
    }

    /// Total logical bytes stored across all runs.
    pub fn logical_bytes(&self) -> u64 {
        self.logical_bytes
    }

    /// Internal fragmentation: mapped minus logical bytes.
    pub fn slack_bytes(&self) -> u64 {
        self.mapped_bytes() - self.logical_bytes
    }

    /// Does the page table agree with the arena? True iff
    /// [`Self::mapped_bytes`] equals `arena.used()`.
    pub fn balances(&self, arena: &Hbm) -> bool {
        self.mapped_bytes() == arena.used()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::FitStrategy;

    const MIB: u64 = 1024 * 1024;

    #[test]
    fn rounding_and_accounting() {
        let pager = Pager::new(2 * MIB);
        assert_eq!(pager.pages_for(0), 0);
        assert_eq!(pager.pages_for(1), 1);
        assert_eq!(pager.pages_for(2 * MIB), 1);
        assert_eq!(pager.pages_for(2 * MIB + 1), 2);
        assert_eq!(pager.padded(3 * MIB), 4 * MIB);
        assert_eq!(pager.padded(0), 0);
    }

    #[test]
    fn map_unmap_balances_against_arena() {
        let mut ssd = Hbm::new(16 * MIB, FitStrategy::BestFit);
        let mut pager = Pager::new(2 * MIB);

        let a = ssd.alloc(pager.padded(3 * MIB)).unwrap();
        pager.map(a, 3 * MIB);
        let b = ssd.alloc(pager.padded(2 * MIB)).unwrap();
        pager.map(b, 2 * MIB);

        assert_eq!(pager.mapped_segments(), 2);
        assert_eq!(pager.pages_mapped(), 3);
        assert_eq!(pager.mapped_bytes(), 6 * MIB);
        assert_eq!(pager.logical_bytes(), 5 * MIB);
        assert_eq!(pager.slack_bytes(), MIB);
        assert!(pager.balances(&ssd));
        assert_eq!(pager.run_of(a), Some(PageRun { pages: 2, logical_bytes: 3 * MIB }));

        let run = pager.unmap(a);
        assert_eq!(run.pages, 2);
        ssd.free(a);
        assert!(pager.balances(&ssd));
        assert_eq!(pager.run_of(a), None);

        pager.unmap(b);
        ssd.free(b);
        assert_eq!(pager.pages_mapped(), 0);
        assert_eq!(pager.logical_bytes(), 0);
        assert!(pager.balances(&ssd));
    }

    #[test]
    #[should_panic(expected = "already mapped")]
    fn double_map_panics() {
        let mut ssd = Hbm::new(4 * MIB, FitStrategy::BestFit);
        let mut pager = Pager::new(MIB);
        let a = ssd.alloc(MIB).unwrap();
        pager.map(a, MIB);
        pager.map(a, MIB);
    }

    #[test]
    #[should_panic(expected = "not in page table")]
    fn unmap_unknown_panics() {
        let mut pager = Pager::new(MIB);
        pager.unmap(AllocId(42));
    }
}
