//! Virtual-time span/instant tracer with a bounded ring buffer.
//!
//! Recording is thread-local and **zero-overhead when off**: every
//! recording entry point first reads one thread-local `Cell<bool>` and
//! returns. Events carry only `&'static str` names and a fixed array of
//! numeric args — nothing is formatted or allocated until export, so a
//! hot simulation loop can trace unconditionally.
//!
//! Timestamps are **virtual** nanoseconds ([`Ns`]) from the simulation
//! clock, never wall-clock: a traced run and an untraced run see the
//! identical timeline. Export is Chrome trace-event JSON
//! ([`to_chrome_json`]) loadable in Perfetto / `chrome://tracing`, with
//! `pid` = node id and `tid` = subsystem.
//!
//! ```
//! use harvest::obs::trace::{self, Subsystem};
//!
//! trace::enable(4096);
//! trace::set_node(2);
//! trace::span(Subsystem::Transfer, "fetch", 100, 350, &[("bytes", 4096)]);
//! trace::instant(Subsystem::Admission, "shed", 400, &[("occ_pm", 950)]);
//! let events = trace::take();
//! assert_eq!(events.len(), 2);
//! assert_eq!(events[0].node, 2);
//! assert!(events[0].is_span() && !events[1].is_span());
//! trace::disable();
//! assert!(!trace::is_enabled());
//! ```

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;

use crate::memsim::{DeviceId, Ns};
use crate::util::json::Json;

/// Which layer of the system an event came from. Becomes the Chrome
/// trace `tid` (one lane per subsystem under each node's `pid`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Subsystem {
    /// `NodeStepper` phases: admit, prefill, kv_sync, compute, decode…
    Stepper,
    /// DMA transfer ops (populate / fetch / migrate / compress…).
    Transfer,
    /// Revocation outcomes applied by the KV manager.
    Revocation,
    /// Cold-tier ladder rungs (age-out demotions and compressions).
    ColdTier,
    /// Prefetch planner lifecycle: plan → issue → hit / late / waste.
    Prefetch,
    /// Admission controller decisions with their input signals.
    Admission,
    /// Cluster router decisions.
    Router,
    /// Tenant-actor wakes.
    Tenant,
}

/// All subsystems, in `tid` order.
pub const SUBSYSTEMS: [Subsystem; 8] = [
    Subsystem::Stepper,
    Subsystem::Transfer,
    Subsystem::Revocation,
    Subsystem::ColdTier,
    Subsystem::Prefetch,
    Subsystem::Admission,
    Subsystem::Router,
    Subsystem::Tenant,
];

impl Subsystem {
    /// Stable lane name used as the Chrome trace category and thread name.
    pub fn name(self) -> &'static str {
        match self {
            Subsystem::Stepper => "stepper",
            Subsystem::Transfer => "transfer",
            Subsystem::Revocation => "revocation",
            Subsystem::ColdTier => "coldtier",
            Subsystem::Prefetch => "prefetch",
            Subsystem::Admission => "admission",
            Subsystem::Router => "router",
            Subsystem::Tenant => "tenant",
        }
    }

    /// Chrome trace `tid` (1-based, stable across runs).
    pub fn tid(self) -> u32 {
        match self {
            Subsystem::Stepper => 1,
            Subsystem::Transfer => 2,
            Subsystem::Revocation => 3,
            Subsystem::ColdTier => 4,
            Subsystem::Prefetch => 5,
            Subsystem::Admission => 6,
            Subsystem::Router => 7,
            Subsystem::Tenant => 8,
        }
    }
}

/// Maximum numeric args carried per event (fixed so recording never
/// allocates).
pub const MAX_ARGS: usize = 4;

/// One recorded span or instant. `Copy`, allocation-free: names are
/// `&'static str` and args are a fixed `(&str, u64)` array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Node (cluster member) the event belongs to — Chrome trace `pid`.
    pub node: u32,
    /// Source lane — Chrome trace `tid`.
    pub sub: Subsystem,
    /// Event name (static, no formatting at record time).
    pub name: &'static str,
    /// Virtual start time (equals [`end`](Self::end) for instants).
    pub start: Ns,
    /// Virtual end time.
    pub end: Ns,
    span: bool,
    args: [(&'static str, u64); MAX_ARGS],
    nargs: u8,
}

impl TraceEvent {
    /// `true` for duration spans, `false` for instants.
    pub fn is_span(&self) -> bool {
        self.span
    }

    /// The populated numeric args.
    pub fn args(&self) -> &[(&'static str, u64)] {
        &self.args[..self.nargs as usize]
    }
}

struct Tracer {
    cap: usize,
    ring: VecDeque<TraceEvent>,
    node: u32,
    hint: Ns,
    dropped: u64,
}

impl Tracer {
    fn push(&mut self, ev: TraceEvent) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(ev);
    }
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static TRACER: RefCell<Tracer> = RefCell::new(Tracer {
        cap: 0,
        ring: VecDeque::new(),
        node: 0,
        hint: 0,
        dropped: 0,
    });
}

/// Turn tracing on for this thread with a ring of `ring_cap` events
/// (clamped to ≥ 1). Clears any previously recorded events.
pub fn enable(ring_cap: usize) {
    TRACER.with(|t| {
        let mut t = t.borrow_mut();
        t.cap = ring_cap.max(1);
        t.ring.clear();
        t.dropped = 0;
    });
    ENABLED.with(|e| e.set(true));
}

/// Turn tracing off for this thread (recorded events stay until
/// [`take`] or the next [`enable`]).
pub fn disable() {
    ENABLED.with(|e| e.set(false));
}

/// Whether tracing is on for this thread. This is the fast-path check
/// every recording entry point performs first — one `Cell` read.
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Set the node id attached to subsequently recorded events. Cluster
/// drivers call this before stepping each node; single-node engines use
/// node 0. No-op when tracing is off.
#[inline]
pub fn set_node(node: u32) {
    if !is_enabled() {
        return;
    }
    TRACER.with(|t| t.borrow_mut().node = node);
}

/// Current node context (0 when tracing is off or unset).
pub fn current_node() -> u32 {
    TRACER.with(|t| t.borrow().node)
}

/// Set the virtual-time hint used by [`instant_now`] for call sites
/// that have no natural timestamp of their own. No-op when off.
#[inline]
pub fn set_time(now: Ns) {
    if !is_enabled() {
        return;
    }
    TRACER.with(|t| t.borrow_mut().hint = now);
}

fn pack(args: &[(&'static str, u64)]) -> ([(&'static str, u64); MAX_ARGS], u8) {
    let mut packed = [("", 0u64); MAX_ARGS];
    let n = args.len().min(MAX_ARGS);
    packed[..n].copy_from_slice(&args[..n]);
    (packed, n as u8)
}

/// Record a duration span `[start, end]` in virtual time. Extra args
/// beyond [`MAX_ARGS`] are silently dropped. No-op when off.
#[inline]
pub fn span(sub: Subsystem, name: &'static str, start: Ns, end: Ns, args: &[(&'static str, u64)]) {
    if !is_enabled() {
        return;
    }
    let (packed, nargs) = pack(args);
    TRACER.with(|t| {
        let mut t = t.borrow_mut();
        let node = t.node;
        t.push(TraceEvent { node, sub, name, start, end, span: true, args: packed, nargs });
    });
}

/// Record an instant at virtual time `at`. No-op when off.
#[inline]
pub fn instant(sub: Subsystem, name: &'static str, at: Ns, args: &[(&'static str, u64)]) {
    if !is_enabled() {
        return;
    }
    let (packed, nargs) = pack(args);
    TRACER.with(|t| {
        let mut t = t.borrow_mut();
        let node = t.node;
        t.push(TraceEvent { node, sub, name, start: at, end: at, span: false, args: packed, nargs });
    });
}

/// Record an instant at the current [`set_time`] hint — for call sites
/// (e.g. prefetch cancellation) that are not handed a timestamp. No-op
/// when off.
#[inline]
pub fn instant_now(sub: Subsystem, name: &'static str, args: &[(&'static str, u64)]) {
    if !is_enabled() {
        return;
    }
    let at = TRACER.with(|t| t.borrow().hint);
    instant(sub, name, at, args);
}

/// Drain and return all recorded events (oldest first).
pub fn take() -> Vec<TraceEvent> {
    TRACER.with(|t| t.borrow_mut().ring.drain(..).collect())
}

/// Copy of the current ring contents without draining (used by the
/// flight recorder to snapshot state at a trigger).
pub fn snapshot() -> Vec<TraceEvent> {
    TRACER.with(|t| t.borrow().ring.iter().copied().collect())
}

/// Events evicted from the ring so far (oldest-first overflow).
pub fn dropped() -> u64 {
    TRACER.with(|t| t.borrow().dropped)
}

/// Numeric code for a device in event args: `Gpu(i)` → `i`, host →
/// 1000, CXL → 1001, SSD → 1002.
pub fn dev(d: DeviceId) -> u64 {
    match d {
        DeviceId::Gpu(i) => i as u64,
        DeviceId::Host => 1000,
        DeviceId::Cxl => 1001,
        DeviceId::Ssd => 1002,
    }
}

fn event_json(ev: &TraceEvent) -> Json {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("name".into(), Json::Str(ev.name.into()));
    obj.insert("cat".into(), Json::Str(ev.sub.name().into()));
    obj.insert("pid".into(), Json::Num(ev.node as f64));
    obj.insert("tid".into(), Json::Num(ev.sub.tid() as f64));
    obj.insert("ts".into(), Json::Num(ev.start as f64 / 1_000.0));
    if ev.span {
        obj.insert("ph".into(), Json::Str("X".into()));
        obj.insert("dur".into(), Json::Num(ev.end.saturating_sub(ev.start) as f64 / 1_000.0));
    } else {
        obj.insert("ph".into(), Json::Str("i".into()));
        obj.insert("s".into(), Json::Str("t".into()));
    }
    if !ev.args().is_empty() {
        let mut args = std::collections::BTreeMap::new();
        for &(k, v) in ev.args() {
            args.insert(k.to_string(), Json::Num(v as f64));
        }
        obj.insert("args".into(), Json::Obj(args));
    }
    Json::Obj(obj)
}

/// Export events as Chrome trace-event JSON (the `{"traceEvents": […]}`
/// object form), loadable in Perfetto or `chrome://tracing`. `pid` is
/// the node, `tid` the subsystem; timestamps are virtual µs. Metadata
/// events name each process/thread lane.
pub fn to_chrome_json(events: &[TraceEvent]) -> Json {
    let mut out = Vec::new();
    let mut nodes: Vec<u32> = events.iter().map(|e| e.node).collect();
    nodes.sort_unstable();
    nodes.dedup();
    for &node in &nodes {
        let mut meta = std::collections::BTreeMap::new();
        meta.insert("name".into(), Json::Str("process_name".into()));
        meta.insert("ph".into(), Json::Str("M".into()));
        meta.insert("pid".into(), Json::Num(node as f64));
        let mut args = std::collections::BTreeMap::new();
        args.insert("name".into(), Json::Str(format!("node{node}")));
        meta.insert("args".into(), Json::Obj(args));
        out.push(Json::Obj(meta));
        for sub in SUBSYSTEMS {
            let mut meta = std::collections::BTreeMap::new();
            meta.insert("name".into(), Json::Str("thread_name".into()));
            meta.insert("ph".into(), Json::Str("M".into()));
            meta.insert("pid".into(), Json::Num(node as f64));
            meta.insert("tid".into(), Json::Num(sub.tid() as f64));
            let mut args = std::collections::BTreeMap::new();
            args.insert("name".into(), Json::Str(sub.name().into()));
            meta.insert("args".into(), Json::Obj(args));
            out.push(Json::Obj(meta));
        }
    }
    out.extend(events.iter().map(event_json));
    let mut root = std::collections::BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(out));
    root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        disable();
        span(Subsystem::Stepper, "step", 0, 10, &[]);
        instant(Subsystem::Router, "route", 5, &[]);
        enable(16);
        assert!(take().is_empty());
        disable();
    }

    #[test]
    fn ring_evicts_oldest_first() {
        enable(4);
        for i in 0..10u64 {
            instant(Subsystem::Stepper, "tick", i, &[("i", i)]);
        }
        let evs = take();
        disable();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs.iter().map(|e| e.start).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(dropped(), 6);
    }

    #[test]
    fn args_truncate_at_max() {
        enable(4);
        let args: Vec<(&'static str, u64)> =
            vec![("a", 1), ("b", 2), ("c", 3), ("d", 4), ("e", 5)];
        span(Subsystem::Transfer, "copy", 0, 1, &args);
        let evs = take();
        disable();
        assert_eq!(evs[0].args().len(), MAX_ARGS);
        assert_eq!(evs[0].args()[3], ("d", 4));
    }

    #[test]
    fn chrome_export_shape() {
        enable(16);
        set_node(3);
        span(Subsystem::Transfer, "fetch", 2_000, 5_000, &[("bytes", 64)]);
        let json = to_chrome_json(&take());
        disable();
        let evs = json.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name + 8 thread_name metadata events + the span.
        assert_eq!(evs.len(), 10);
        let span = evs.last().unwrap();
        assert_eq!(span.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(span.get("pid").unwrap().as_u64().unwrap(), 3);
        assert_eq!(span.get("ts").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(span.get("dur").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn dev_codes_are_stable() {
        assert_eq!(dev(DeviceId::Gpu(7)), 7);
        assert_eq!(dev(DeviceId::Host), 1000);
        assert_eq!(dev(DeviceId::Cxl), 1001);
        assert_eq!(dev(DeviceId::Ssd), 1002);
    }
}
