//! Flight recorder: automatic trace-ring dumps at SLO incidents.
//!
//! The control plane exists to prevent exactly three bad outcomes: a
//! TTFT window miss, a burst of shed requests, and a tenant OOM that
//! harvested memory contributed to (`BrokerStats::oom_with_harvest`).
//! When armed, the recorder watches per-node signals the stepper feeds
//! it at the end of every step and, on an incident, snapshots the
//! tracer's ring ([`crate::obs::trace::snapshot`]) — the last-N events
//! leading up to the incident — as a [`FlightDump`] postmortem.
//!
//! Triggers are edge-triggered per node (a sustained miss produces one
//! dump, not one per step) and the dump list is bounded, so an armed
//! recorder in a pathological run stays cheap.
//!
//! ```
//! use harvest::obs::flight::{self, FlightConfig, FlightSignals};
//! use harvest::obs::trace;
//!
//! trace::enable(256);
//! flight::arm(FlightConfig::default());
//! // A window miss: achieved p99 40 ms against a 10 ms target.
//! let sig = FlightSignals {
//!     ttft_p99_ns: 40_000_000,
//!     ttft_target_ns: 10_000_000,
//!     ..Default::default()
//! };
//! flight::observe(0, 1_000, &sig);
//! let dumps = flight::take_dumps();
//! assert_eq!(dumps.len(), 1);
//! assert_eq!(dumps[0].reason, "ttft_window_miss");
//! flight::disarm();
//! trace::disable();
//! ```

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};

use crate::memsim::Ns;
use crate::util::json::Json;

use super::trace::{self, TraceEvent};

/// Tuning for the flight recorder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlightConfig {
    /// Sliding window for shed-burst detection.
    pub window_ns: Ns,
    /// Sheds within the window that count as a burst.
    pub shed_burst: u64,
    /// Maximum dumps kept (later incidents are dropped, not rotated —
    /// the first occurrences are the diagnostic ones).
    pub max_dumps: usize,
}

impl Default for FlightConfig {
    fn default() -> Self {
        Self { window_ns: 20_000_000, shed_burst: 4, max_dumps: 8 }
    }
}

/// Per-node signals sampled by the stepper at the end of a step.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlightSignals {
    /// Achieved windowed p99 TTFT (0 = unknown / no completions yet).
    pub ttft_p99_ns: Ns,
    /// SLO target (0 = no target configured; miss detection off).
    pub ttft_target_ns: Ns,
    /// Requests shed by this node during this step.
    pub new_sheds: u64,
    /// Cumulative tenant OOMs that harvested memory contributed to.
    pub oom_with_harvest: u64,
}

/// One postmortem: the trace ring as it stood when a trigger fired.
#[derive(Debug, Clone)]
pub struct FlightDump {
    /// Which trigger fired: `"ttft_window_miss"`, `"shed_burst"`, or
    /// `"oom_with_harvest"`.
    pub reason: &'static str,
    /// Node the triggering signal came from.
    pub node: u32,
    /// Virtual time of the trigger.
    pub at: Ns,
    /// Ring contents at the trigger (oldest first).
    pub events: Vec<TraceEvent>,
}

#[derive(Default)]
struct NodeState {
    miss_latched: bool,
    shed_times: VecDeque<Ns>,
    burst_latched: bool,
    oom_seen: u64,
}

struct Recorder {
    cfg: FlightConfig,
    nodes: BTreeMap<u32, NodeState>,
    dumps: Vec<FlightDump>,
}

thread_local! {
    static RECORDER: RefCell<Option<Recorder>> = const { RefCell::new(None) };
}

/// Arm the recorder for this thread (clears prior dumps and state).
pub fn arm(cfg: FlightConfig) {
    RECORDER.with(|r| {
        *r.borrow_mut() =
            Some(Recorder { cfg, nodes: BTreeMap::new(), dumps: Vec::new() });
    });
}

/// Disarm and discard all state for this thread.
pub fn disarm() {
    RECORDER.with(|r| *r.borrow_mut() = None);
}

/// Whether the recorder is armed on this thread — the stepper's
/// fast-path check before it gathers any signals.
#[inline]
pub fn is_armed() -> bool {
    RECORDER.with(|r| r.borrow().is_some())
}

/// Feed one step's signals for `node` at virtual time `now`. Fires at
/// most one dump per call; triggers are edge-triggered per node.
pub fn observe(node: u32, now: Ns, sig: &FlightSignals) {
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        let Some(rec) = r.as_mut() else { return };
        let cfg = rec.cfg;
        let st = rec.nodes.entry(node).or_default();

        // TTFT window miss: fire on the false→true transition only.
        let missing =
            sig.ttft_target_ns > 0 && sig.ttft_p99_ns > 0 && sig.ttft_p99_ns > sig.ttft_target_ns;
        let mut reason = None;
        if missing && !st.miss_latched {
            reason = Some("ttft_window_miss");
        }
        st.miss_latched = missing;

        // Shed burst: N sheds inside a sliding virtual-time window.
        for _ in 0..sig.new_sheds {
            st.shed_times.push_back(now);
        }
        let cutoff = now.saturating_sub(cfg.window_ns);
        while st.shed_times.front().is_some_and(|&t| t < cutoff) {
            st.shed_times.pop_front();
        }
        let bursting = (st.shed_times.len() as u64) >= cfg.shed_burst;
        if reason.is_none() && bursting && !st.burst_latched {
            reason = Some("shed_burst");
        }
        st.burst_latched = bursting;

        // Harvest-implicated tenant OOM: fire on every increase.
        if reason.is_none() && sig.oom_with_harvest > st.oom_seen {
            reason = Some("oom_with_harvest");
        }
        st.oom_seen = st.oom_seen.max(sig.oom_with_harvest);

        if let Some(reason) = reason {
            if rec.dumps.len() < cfg.max_dumps {
                rec.dumps.push(FlightDump { reason, node, at: now, events: trace::snapshot() });
            }
        }
    });
}

/// Drain accumulated dumps (recorder stays armed).
pub fn take_dumps() -> Vec<FlightDump> {
    RECORDER.with(|r| match r.borrow_mut().as_mut() {
        Some(rec) => std::mem::take(&mut rec.dumps),
        None => Vec::new(),
    })
}

/// Render dumps as JSON: `[{reason, node, at_ns, trace: {traceEvents}}]`.
pub fn dumps_to_json(dumps: &[FlightDump]) -> Json {
    Json::Arr(
        dumps
            .iter()
            .map(|d| {
                let mut obj = BTreeMap::new();
                obj.insert("reason".into(), Json::Str(d.reason.into()));
                obj.insert("node".into(), Json::Num(d.node as f64));
                obj.insert("at_ns".into(), Json::Num(d.at as f64));
                obj.insert("trace".into(), trace::to_chrome_json(&d.events));
                Json::Obj(obj)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet() -> FlightSignals {
        FlightSignals::default()
    }

    #[test]
    fn window_miss_is_edge_triggered() {
        arm(FlightConfig::default());
        let miss = FlightSignals { ttft_p99_ns: 90, ttft_target_ns: 50, ..quiet() };
        observe(0, 100, &miss);
        observe(0, 200, &miss); // still missing: latched, no new dump
        observe(0, 300, &quiet()); // recovers
        observe(0, 400, &miss); // misses again: second dump
        let dumps = take_dumps();
        disarm();
        assert_eq!(dumps.len(), 2);
        assert!(dumps.iter().all(|d| d.reason == "ttft_window_miss"));
    }

    #[test]
    fn shed_burst_uses_sliding_window() {
        arm(FlightConfig { window_ns: 1_000, shed_burst: 3, max_dumps: 8 });
        observe(1, 100, &FlightSignals { new_sheds: 2, ..quiet() });
        assert!(take_dumps().is_empty());
        observe(1, 200, &FlightSignals { new_sheds: 1, ..quiet() });
        let dumps = take_dumps();
        assert_eq!(dumps.len(), 1);
        assert_eq!(dumps[0].reason, "shed_burst");
        assert_eq!(dumps[0].node, 1);
        // Far in the future the window has drained; a single shed is
        // quiet again.
        observe(1, 10_000, &FlightSignals { new_sheds: 1, ..quiet() });
        assert!(take_dumps().is_empty());
        disarm();
    }

    #[test]
    fn oom_fires_per_increase_and_dumps_are_bounded() {
        arm(FlightConfig { max_dumps: 2, ..FlightConfig::default() });
        observe(0, 10, &FlightSignals { oom_with_harvest: 1, ..quiet() });
        observe(0, 20, &FlightSignals { oom_with_harvest: 1, ..quiet() }); // no increase
        observe(0, 30, &FlightSignals { oom_with_harvest: 2, ..quiet() });
        observe(0, 40, &FlightSignals { oom_with_harvest: 3, ..quiet() }); // over cap
        let dumps = take_dumps();
        disarm();
        assert_eq!(dumps.len(), 2);
        assert!(dumps.iter().all(|d| d.reason == "oom_with_harvest"));
    }

    #[test]
    fn disarmed_observe_is_noop() {
        disarm();
        observe(0, 10, &FlightSignals { oom_with_harvest: 5, ..quiet() });
        assert!(!is_armed());
        assert!(take_dumps().is_empty());
    }

    #[test]
    fn dumps_include_ring_snapshot() {
        trace::enable(64);
        trace::instant(trace::Subsystem::Admission, "shed", 90, &[]);
        arm(FlightConfig { window_ns: 1_000, shed_burst: 1, max_dumps: 4 });
        observe(2, 100, &FlightSignals { new_sheds: 1, ..quiet() });
        let dumps = take_dumps();
        disarm();
        trace::disable();
        assert_eq!(dumps.len(), 1);
        assert_eq!(dumps[0].events.len(), 1);
        let json = dumps_to_json(&dumps).to_string();
        assert!(json.contains("shed_burst"));
    }
}
