//! Offline latency forensics over exported observability artifacts.
//!
//! `harvest analyze` (see `main.rs`) feeds this module a Chrome
//! trace-event document (from `serve --trace`) and optionally a report
//! document (from `serve --report`) and renders what it returns:
//!
//! * [`analyze_trace`] — flamegraph-style per-`(subsystem, span)`
//!   rollups, the step critical-path denominator, and the top-K longest
//!   individual spans across the run;
//! * [`attribution_totals`] / [`slow_requests`] — the per-component
//!   causal attribution table and the slowest-request forensics out of
//!   a report's `attribution` section (see [`crate::obs::attrib`]).
//!
//! Everything here is pure parsing/aggregation over [`Json`] values, so
//! the unit tests cover the analysis without spawning a serve run.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Rollup of one `(subsystem, span-name)` lane across the whole trace.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanStat {
    pub subsystem: String,
    pub name: String,
    pub count: u64,
    /// Sum of span durations, µs (trace timestamps are virtual µs).
    pub total_us: f64,
    /// Longest single span, µs.
    pub max_us: f64,
}

impl SpanStat {
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_us / self.count as f64
        }
    }
}

/// One long individual span (top-K forensics).
#[derive(Debug, Clone, PartialEq)]
pub struct SlowSpan {
    pub subsystem: String,
    pub name: String,
    pub node: u32,
    pub ts_us: f64,
    pub dur_us: f64,
}

/// Everything `analyze` derives from one trace document.
#[derive(Debug, Clone, Default)]
pub struct TraceAnalysis {
    /// Distinct node ids (`pid`s) that emitted events.
    pub nodes: Vec<u32>,
    /// Per-lane rollups, sorted by total duration descending.
    pub spans: Vec<SpanStat>,
    /// Instant-event counts per `(subsystem, name)`.
    pub instants: Vec<(String, String, u64)>,
    /// Total time inside `stepper/step` spans — the critical-path
    /// denominator the per-phase percentages are quoted against.
    pub step_total_us: f64,
    /// The `top_k` longest individual spans.
    pub slowest: Vec<SlowSpan>,
}

/// Aggregate a Chrome trace-event document (the `{"traceEvents": […]}`
/// object form written by `serve --trace`). Metadata (`"M"`) events are
/// skipped; `"X"` spans roll up by `(cat, name)`; `"i"` instants are
/// counted.
pub fn analyze_trace(doc: &Json, top_k: usize) -> Result<TraceAnalysis> {
    let Some(Json::Arr(events)) = doc.opt("traceEvents") else {
        bail!("not a Chrome trace document: no traceEvents array");
    };
    let mut spans: BTreeMap<(String, String), SpanStat> = BTreeMap::new();
    let mut instants: BTreeMap<(String, String), u64> = BTreeMap::new();
    let mut nodes: Vec<u32> = Vec::new();
    let mut slowest: Vec<SlowSpan> = Vec::new();
    let mut step_total_us = 0.0;
    for ev in events {
        let ph = ev.opt("ph").and_then(|p| p.as_str().ok()).unwrap_or("");
        if ph != "X" && ph != "i" {
            continue;
        }
        let sub = ev.opt("cat").and_then(|c| c.as_str().ok()).unwrap_or("?").to_string();
        let name = ev.opt("name").and_then(|n| n.as_str().ok()).unwrap_or("?").to_string();
        let node = ev.opt("pid").and_then(|p| p.as_u64().ok()).unwrap_or(0) as u32;
        if !nodes.contains(&node) {
            nodes.push(node);
        }
        if ph == "i" {
            *instants.entry((sub, name)).or_insert(0) += 1;
            continue;
        }
        let dur = ev.opt("dur").and_then(|d| d.as_f64().ok()).unwrap_or(0.0);
        let ts = ev.opt("ts").and_then(|t| t.as_f64().ok()).unwrap_or(0.0);
        if sub == "stepper" && name == "step" {
            step_total_us += dur;
        }
        let stat = spans.entry((sub.clone(), name.clone())).or_insert_with(|| SpanStat {
            subsystem: sub.clone(),
            name: name.clone(),
            count: 0,
            total_us: 0.0,
            max_us: 0.0,
        });
        stat.count += 1;
        stat.total_us += dur;
        stat.max_us = stat.max_us.max(dur);
        slowest.push(SlowSpan { subsystem: sub, name, node, ts_us: ts, dur_us: dur });
    }
    nodes.sort_unstable();
    let mut spans: Vec<SpanStat> = spans.into_values().collect();
    spans.sort_by(|a, b| b.total_us.total_cmp(&a.total_us));
    slowest.sort_by(|a, b| b.dur_us.total_cmp(&a.dur_us));
    slowest.truncate(top_k);
    let instants = instants.into_iter().map(|((s, n), c)| (s, n, c)).collect();
    Ok(TraceAnalysis { nodes, spans, instants, step_total_us, slowest })
}

/// Pull the per-component `(name, ttft_ns, decode_ns)` totals out of a
/// report document's `attribution.totals` section, sorted by combined
/// charge descending. `None` when the report has no attribution (run
/// without `--report` / `[obs] attribution`).
pub fn attribution_totals(report: &Json) -> Option<Vec<(String, u64, u64)>> {
    let Json::Obj(totals) = report.opt("attribution")?.opt("totals")? else {
        return None;
    };
    let mut rows: Vec<(String, u64, u64)> = totals
        .iter()
        .map(|(name, v)| {
            let ttft = v.opt("ttft_ns").and_then(|x| x.as_u64().ok()).unwrap_or(0);
            let decode = v.opt("decode_ns").and_then(|x| x.as_u64().ok()).unwrap_or(0);
            (name.clone(), ttft, decode)
        })
        .collect();
    rows.sort_by_key(|(name, t, d)| (std::cmp::Reverse(t + d), name.clone()));
    Some(rows)
}

/// The slowest-by-TTFT request forensics out of a report document:
/// `(id, ttft_ns, e2e_ns, [(component, ns)])` rows, already ranked by
/// the serve run.
#[allow(clippy::type_complexity)]
pub fn slow_requests(report: &Json) -> Option<Vec<(u64, u64, u64, Vec<(String, u64)>)>> {
    let Json::Arr(items) = report.opt("attribution")?.opt("slowest_by_ttft")? else {
        return None;
    };
    let mut out = Vec::new();
    for it in items {
        let id = it.opt("id").and_then(|x| x.as_u64().ok()).unwrap_or(0);
        let ttft = it.opt("ttft_ns").and_then(|x| x.as_u64().ok()).unwrap_or(0);
        let e2e = it.opt("e2e_ns").and_then(|x| x.as_u64().ok()).unwrap_or(0);
        let mut comps = Vec::new();
        if let Some(Json::Obj(m)) = it.opt("ttft_components") {
            for (k, v) in m {
                comps.push((k.clone(), v.as_u64().unwrap_or(0)));
            }
        }
        comps.sort_by_key(|(name, ns)| (std::cmp::Reverse(*ns), name.clone()));
        out.push((id, ttft, e2e, comps));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::attrib::{AttribTracker, Component};
    use crate::obs::trace::{self, Subsystem};

    fn sample_trace() -> Json {
        trace::enable(64);
        trace::set_node(0);
        trace::span(Subsystem::Stepper, "step", 0, 10_000, &[]);
        trace::span(Subsystem::Stepper, "kv_sync", 0, 2_000, &[]);
        trace::span(Subsystem::Transfer, "fetch", 2_000, 9_000, &[("bytes", 4096)]);
        trace::instant(Subsystem::Admission, "shed", 500, &[]);
        trace::set_node(1);
        trace::span(Subsystem::Stepper, "step", 0, 6_000, &[]);
        let doc = trace::to_chrome_json(&trace::take());
        trace::disable();
        doc
    }

    #[test]
    fn trace_rollup_groups_by_lane() {
        let a = analyze_trace(&sample_trace(), 2).unwrap();
        assert_eq!(a.nodes, vec![0, 1]);
        // stepper/step dominates: 10µs + 6µs across the two nodes.
        assert_eq!(a.spans[0].name, "step");
        assert_eq!(a.spans[0].count, 2);
        assert!((a.spans[0].total_us - 16.0).abs() < 1e-9);
        assert!((a.step_total_us - 16.0).abs() < 1e-9);
        assert_eq!(a.instants, vec![("admission".into(), "shed".into(), 1)]);
        assert_eq!(a.slowest.len(), 2);
        assert_eq!(a.slowest[0].name, "step");
        assert!((a.slowest[0].dur_us - 10.0).abs() < 1e-9);
    }

    #[test]
    fn analyze_rejects_non_trace_documents() {
        assert!(analyze_trace(&Json::Null, 4).is_err());
    }

    #[test]
    fn report_sections_roundtrip_through_analysis() {
        let mut t = AttribTracker::new();
        t.note_admit(3, 0, 100);
        t.charge(3, Component::PrefillCompute, 700);
        t.note_first_token(3, 700);
        t.note_finish(3, 700);
        let mut root = std::collections::BTreeMap::new();
        root.insert("attribution".to_string(), t.report().to_json(4));
        let report = Json::Obj(root);
        let rows = attribution_totals(&report).unwrap();
        assert_eq!(rows[0].0, "prefill_compute");
        assert_eq!(rows[0].1, 600);
        let slow = slow_requests(&report).unwrap();
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].0, 3);
        assert_eq!(slow[0].1, 700);
        assert_eq!(slow[0].3[0], ("prefill_compute".to_string(), 600));
    }

    #[test]
    fn missing_attribution_is_none() {
        assert!(attribution_totals(&Json::Obj(Default::default())).is_none());
        assert!(slow_requests(&Json::Obj(Default::default())).is_none());
    }
}
