//! One metrics registry for every stat surface in the crate.
//!
//! The simulator grew seven disjoint stat structs (`ServeMetrics`,
//! `KvStats`, `TierLedger`, `BrokerStats`, `AdmissionStats`, the
//! prefetch ledger, `PeerMonitor` tier slots), each with its own
//! accessors and JSON. [`MetricsRegistry`] is the single snapshot tree
//! they all register into: dot-separated metric names
//! (`"serve.ttft_p99_ns"`, `"kv.reloads.ssd"`) nest into one JSON
//! object, and [`LogHistogram`] keeps full TTFT/TBT distributions with
//! fixed log₂ buckets so merged rollups stay exact (bucket-wise sums,
//! never averaged percentiles).
//!
//! ```
//! use harvest::obs::registry::{LogHistogram, MetricsRegistry};
//!
//! let mut h = LogHistogram::default();
//! for v in [100u64, 200, 400, 800] {
//!     h.record(v);
//! }
//! assert_eq!(h.count(), 4);
//! assert_eq!(h.sum(), 1_500);
//!
//! let mut reg = MetricsRegistry::new();
//! reg.counter("serve.requests_finished", 4);
//! reg.gauge("serve.goodput_tok_s", 123.5);
//! reg.hist("serve.ttft_ns", &h);
//! let json = reg.to_json();
//! let finished = json.get("serve").unwrap().get("requests_finished").unwrap();
//! assert_eq!(finished.as_u64().unwrap(), 4);
//! ```

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Number of log₂ buckets (values up to `u64::MAX` bucket by leading
/// bit: bucket 0 holds zero, bucket *i* holds `[2^(i-1), 2^i)`).
pub const BUCKETS: usize = 65;

/// Fixed-size log₂-bucket histogram of `u64` samples.
///
/// Percentiles interpolate linearly within the rank-holding bucket
/// (≤ 2× relative error from the bucket width, unbiased at low
/// counts), and [`merge`](Self::merge) is an exact bucket-wise sum —
/// two nodes' histograms merge into the true cluster distribution,
/// unlike averaging per-node percentile points.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self { counts: [0; BUCKETS], count: 0, sum: 0 }
    }
}

impl LogHistogram {
    fn bucket(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// Upper bound of bucket `i` (inclusive).
    fn bucket_upper(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// `true` when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean sample value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Bucket-wise merge: the exact histogram of the union of samples.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Approximate percentile `p` in `[0, 100]`: linear interpolation
    /// across the bucket holding the rank-`p` sample, by rank within
    /// the bucket (0 when empty). The bucket's last rank maps to its
    /// upper bound, so `percentile(100.0)` still covers the maximum
    /// sample — but a lone sample near a bucket's bottom no longer
    /// reports as the bucket top (the old upper-bound bias at low
    /// counts).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lower = if i == 0 { 0 } else { 1u64 << (i - 1) };
                let upper = Self::bucket_upper(i);
                let rank_in_bucket = target - seen; // 1..=c
                let width = (upper - lower) as u128;
                return lower + (width * rank_in_bucket as u128 / c as u128) as u64;
            }
            seen += c;
        }
        Self::bucket_upper(BUCKETS - 1)
    }

    /// JSON snapshot: count, sum, mean, p50/p90/p99, and the non-empty
    /// buckets as `[lower_bound, count]` pairs.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let lower = if i == 0 { 0 } else { 1u64 << (i - 1) };
                Json::Arr(vec![Json::Num(lower as f64), Json::Num(c as f64)])
            })
            .collect();
        let mut obj = BTreeMap::new();
        obj.insert("count".into(), Json::Num(self.count as f64));
        obj.insert("sum".into(), Json::Num(self.sum as f64));
        obj.insert("mean".into(), Json::Num(self.mean()));
        obj.insert("p50".into(), Json::Num(self.percentile(50.0) as f64));
        obj.insert("p90".into(), Json::Num(self.percentile(90.0) as f64));
        obj.insert("p99".into(), Json::Num(self.percentile(99.0) as f64));
        obj.insert("buckets".into(), Json::Arr(buckets));
        Json::Obj(obj)
    }
}

/// One registered metric.
#[derive(Debug, Clone, PartialEq)]
pub enum Metric {
    /// Monotonic count; merges by addition.
    Counter(u64),
    /// Point-in-time value; merges by taking the newer value.
    Gauge(f64),
    /// Full distribution; merges bucket-wise.
    Hist(LogHistogram),
}

/// Snapshot tree of named metrics.
///
/// Names are dot-separated paths (`"kv.reloads.host"`); [`to_json`]
/// (Self::to_json) nests them into one object so `serve`, the benches,
/// and rollups all emit the same shape.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    metrics: BTreeMap<String, Metric>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or overwrite) a counter.
    pub fn counter(&mut self, name: &str, v: u64) {
        self.metrics.insert(name.to_string(), Metric::Counter(v));
    }

    /// Register (or overwrite) a gauge.
    pub fn gauge(&mut self, name: &str, v: f64) {
        self.metrics.insert(name.to_string(), Metric::Gauge(v));
    }

    /// Register (or overwrite) a histogram snapshot.
    pub fn hist(&mut self, name: &str, h: &LogHistogram) {
        self.metrics.insert(name.to_string(), Metric::Hist(h.clone()));
    }

    /// Look up a metric by full dotted name.
    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.metrics.get(name)
    }

    /// Number of registered metrics.
    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    /// `true` when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    /// Merge another registry in: counters add, gauges take `other`'s
    /// value, histograms merge bucket-wise. Metrics only in `other` are
    /// inserted.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (name, m) in &other.metrics {
            match (self.metrics.get_mut(name), m) {
                (Some(Metric::Counter(a)), Metric::Counter(b)) => *a += b,
                (Some(Metric::Gauge(a)), Metric::Gauge(b)) => *a = *b,
                (Some(Metric::Hist(a)), Metric::Hist(b)) => a.merge(b),
                _ => {
                    self.metrics.insert(name.clone(), m.clone());
                }
            }
        }
    }

    /// Nest dotted names into one JSON tree. A name that collides with
    /// a parent path (`"a.b"` and `"a.b.c"`) keeps the later entry —
    /// callers keep namespaces distinct by convention.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        for (name, m) in &self.metrics {
            let leaf = match m {
                Metric::Counter(v) => Json::Num(*v as f64),
                Metric::Gauge(v) => Json::Num(*v),
                Metric::Hist(h) => h.to_json(),
            };
            insert_path(&mut root, name, leaf);
        }
        Json::Obj(root)
    }
}

fn insert_path(root: &mut BTreeMap<String, Json>, path: &str, leaf: Json) {
    match path.split_once('.') {
        None => {
            root.insert(path.to_string(), leaf);
        }
        Some((head, rest)) => {
            let entry =
                root.entry(head.to_string()).or_insert_with(|| Json::Obj(BTreeMap::new()));
            if !matches!(entry, Json::Obj(_)) {
                *entry = Json::Obj(BTreeMap::new());
            }
            if let Json::Obj(map) = entry {
                insert_path(map, rest, leaf);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_leading_bit() {
        let mut h = LogHistogram::default();
        h.record(0);
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(1 << 40);
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 6 + (1 << 40));
        // p50 of {0,1,2,3,2^40}: rank-3 sample is the first of two in
        // bucket [2,4), so interpolation reports the bucket's lower
        // half rather than its upper bound.
        assert_eq!(h.percentile(50.0), 2);
        // The last rank of the top bucket still maps to its upper bound.
        assert_eq!(h.percentile(100.0), (1u64 << 41) - 1);
    }

    #[test]
    fn histogram_merge_is_bucketwise() {
        let mut a = LogHistogram::default();
        let mut b = LogHistogram::default();
        for _ in 0..99 {
            a.record(10);
        }
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 100);
        // The tail sample survives the merge exactly: p100 sits in
        // 1M's bucket, not at an averaged midpoint.
        assert!(a.percentile(100.0) >= 1_000_000);
        // p50 (rank 50 of 99 in bucket [8,16)) interpolates to
        // 8 + 7*50/99 = 11 instead of pinning to the upper bound 15.
        assert_eq!(a.percentile(50.0), 11);
    }

    #[test]
    fn registry_merges_by_kind() {
        let mut a = MetricsRegistry::new();
        a.counter("x.count", 2);
        a.gauge("x.rate", 1.0);
        let mut b = MetricsRegistry::new();
        b.counter("x.count", 3);
        b.gauge("x.rate", 9.0);
        b.counter("y.only", 7);
        a.merge(&b);
        assert_eq!(a.get("x.count"), Some(&Metric::Counter(5)));
        assert_eq!(a.get("x.rate"), Some(&Metric::Gauge(9.0)));
        assert_eq!(a.get("y.only"), Some(&Metric::Counter(7)));
    }

    #[test]
    fn to_json_nests_dotted_paths() {
        let mut reg = MetricsRegistry::new();
        reg.counter("kv.reloads.host", 4);
        reg.counter("kv.reloads.ssd", 1);
        reg.gauge("serve.tps", 10.5);
        let json = reg.to_json();
        let reloads = json.get("kv").unwrap().get("reloads").unwrap();
        assert_eq!(reloads.get("host").unwrap().as_u64().unwrap(), 4);
        assert_eq!(reloads.get("ssd").unwrap().as_u64().unwrap(), 1);
        let tps = json.get("serve").unwrap().get("tps").unwrap();
        assert_eq!(tps.as_f64().unwrap(), 10.5);
    }

    #[test]
    fn empty_histogram_percentiles_are_zero() {
        let h = LogHistogram::default();
        assert_eq!(h.percentile(99.0), 0);
        assert!(h.is_empty());
        assert_eq!(h.mean(), 0.0);
    }
}
