//! Unified observability plane: tracing, metrics, profiling, postmortems.
//!
//! Four cooperating pieces, all zero-overhead when off and all strictly
//! read-only with respect to the simulation (no virtual time is spent,
//! no control-flow decision ever depends on them — see
//! `tests/obs_differential.rs` for the bit-for-bit proof):
//!
//! | piece | what it captures |
//! |-------|------------------|
//! | [`trace`] | virtual-time spans/instants in a bounded ring, exported as Chrome trace-event JSON (Perfetto-loadable; pid = node, tid = subsystem) |
//! | [`registry`] | one snapshot tree of counters/gauges/log-bucket histograms that every stat surface registers into |
//! | [`profile`] | wall-clock per-phase accumulator for the stepper hot loop |
//! | [`flight`] | flight recorder — dumps the trace ring when the SLO control plane sees a window miss, a shed burst, or a tenant OOM-with-harvest |
//! | [`attrib`] | per-request causal latency attribution (conservation-exact TTFT/decode decomposition) + harvest tax/dividend accounting |
//! | [`analyze`] | offline forensics over an exported trace + report: critical-path breakdowns, per-phase rollups, top-K slow requests |
//!
//! All state is thread-local: parallel test threads and parallel bench
//! harnesses never observe each other, and no `&mut` plumbing threads
//! through the simulation APIs. Enable via the `[obs]` TOML section and
//! the `serve --trace <path>` CLI flag, or programmatically:
//!
//! ```
//! use harvest::obs::{profile, trace};
//!
//! trace::enable(1024);
//! profile::enable();
//! trace::span(trace::Subsystem::Stepper, "step", 0, 1_000, &[("cohort", 4)]);
//! let events = trace::take();
//! assert_eq!(events.len(), 1);
//! let json = trace::to_chrome_json(&events).to_string();
//! assert!(json.contains("traceEvents"));
//! trace::disable();
//! profile::disable();
//! ```

pub mod analyze;
pub mod attrib;
pub mod flight;
pub mod profile;
pub mod registry;
pub mod trace;

pub use attrib::{
    harvest_economics, AttribTracker, AttributionReport, Component, HarvestEconomics,
    RequestAttribution, TierPricing,
};
pub use flight::{FlightConfig, FlightDump, FlightSignals};
pub use profile::{Phase, PhaseProfile, PhaseTimer};
pub use registry::{LogHistogram, Metric, MetricsRegistry};
pub use trace::{Subsystem, TraceEvent};
