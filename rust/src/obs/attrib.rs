//! Per-request causal latency attribution (latency forensics).
//!
//! PR 9's telemetry says *what happened*; this module says *where the
//! time went*. Every admitted request carries an attribution ledger
//! that decomposes its measured TTFT and decode latency into an
//! exhaustive, mutually-exclusive set of [`Component`]s — queue wait,
//! admission deferral, prefill compute, per-source-tier KV reload
//! stalls, decompression, revocation recompute, link interference,
//! aging sweeps, scheduler wait, batched compute — with a conservation
//! invariant: the components sum **bit-exactly** to the measured
//! latency, and the "unattributed" remainder is pinned to zero by
//! `tests/attrib_conservation.rs`.
//!
//! The mechanism is cursor-based telescoping: each ledger tracks the
//! last virtual-time point it has attributed up to, and every stepper
//! phase charges `now - cursor` to exactly one component (or splits it
//! across the KV components in proportion to what [`KvStats`] says
//! happened inside the window). Sums telescope, so conservation holds
//! by construction — no clock read is ever double-counted or dropped.
//!
//! The tracker is strictly read-only with respect to the simulation: it
//! observes the clock and KV counters, never advances time, and no
//! control-flow decision depends on it (`tests/obs_differential.rs`
//! proves an armed run is bit-for-bit identical to an off run).
//!
//! On top of the ledgers, [`harvest_economics`] prices the **harvest
//! tax** (what revocable/compressed placement cost us: recompute +
//! decompression) against the **harvest dividend** (what the fast tiers
//! saved versus a host-baseline counterfactual priced from
//! [`LinkModel`]), so the registry can answer "was harvesting worth
//! it?" per run.
//!
//! ```
//! use harvest::kv::KvStats;
//! use harvest::obs::attrib::{harvest_economics, TierPricing};
//!
//! let stats = KvStats {
//!     bytes_from_peer: 64 << 20,
//!     reload_ns_peer: 200_000,
//!     recompute_ns: 50_000,
//!     ..Default::default()
//! };
//! let econ = harvest_economics(&stats, &TierPricing::default());
//! assert_eq!(econ.tax_ns, 50_000);
//! assert!(econ.dividend_ns > 0); // peer reload beat the host price
//! ```

use std::collections::BTreeMap;

use crate::kv::manager::RELOAD_CHUNK_BYTES;
use crate::kv::KvStats;
use crate::memsim::{LinkModel, Ns};
use crate::obs::registry::MetricsRegistry;
use crate::util::json::Json;

/// Number of attribution components (array length of the ledgers).
pub const NUM_COMPONENTS: usize = 15;

/// One cause a nanosecond of request latency can be charged to. The set
/// is exhaustive and mutually exclusive: every attributed window lands
/// in exactly one component.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Component {
    /// Router/queue wait: arrival until the admission verdict that
    /// first examined the request (first deferral, or admission).
    QueueWait = 0,
    /// Admission deferral: first `Defer` verdict until admission.
    AdmissionDefer = 1,
    /// Fresh-suffix prefill compute.
    PrefillCompute = 2,
    /// Waiting on a prefix whose blocks were still arriving over the
    /// node fabric (cluster spillover migration gate).
    PrefixFabric = 3,
    /// KV reload stall served from peer HBM (unloaded-price share).
    ReloadPeer = 4,
    /// KV reload stall served from CXL memory (unloaded-price share).
    ReloadCxl = 5,
    /// KV reload stall served from host DRAM (unloaded-price share).
    ReloadHost = 6,
    /// KV reload stall served from the SSD cold tier (unloaded-price
    /// share).
    ReloadSsd = 7,
    /// Decompression of compressed-in-place blocks on reload.
    Decompress = 8,
    /// Revocation-induced recompute (prefill replay of dropped blocks).
    Recompute = 9,
    /// Link interference: the share of a reload stall *above* the
    /// unloaded [`LinkModel`] price — queueing behind co-tenant
    /// collectives, other reloads, or migration traffic on the link.
    Interference = 10,
    /// Cold-ladder idle-aging sweep running inside the step.
    AgingSweep = 11,
    /// Waiting for a decode slot (not selected into the cohort, or
    /// waiting for earlier cohort members' appends).
    SchedulerWait = 12,
    /// Batched decode compute.
    Compute = 13,
    /// KV bookkeeping the window-split could not price (reservation
    /// eviction cascades, prefetch admission) — and the residual
    /// nanoseconds of integer splits, so conservation stays exact.
    KvOther = 14,
}

impl Component {
    /// Every component, in ledger-array order.
    pub const ALL: [Component; NUM_COMPONENTS] = [
        Component::QueueWait,
        Component::AdmissionDefer,
        Component::PrefillCompute,
        Component::PrefixFabric,
        Component::ReloadPeer,
        Component::ReloadCxl,
        Component::ReloadHost,
        Component::ReloadSsd,
        Component::Decompress,
        Component::Recompute,
        Component::Interference,
        Component::AgingSweep,
        Component::SchedulerWait,
        Component::Compute,
        Component::KvOther,
    ];

    /// Stable snake_case name (registry keys, JSON, tables).
    pub fn name(self) -> &'static str {
        match self {
            Component::QueueWait => "queue_wait",
            Component::AdmissionDefer => "admission_defer",
            Component::PrefillCompute => "prefill_compute",
            Component::PrefixFabric => "prefix_fabric",
            Component::ReloadPeer => "reload_peer",
            Component::ReloadCxl => "reload_cxl",
            Component::ReloadHost => "reload_host",
            Component::ReloadSsd => "reload_ssd",
            Component::Decompress => "decompress",
            Component::Recompute => "recompute",
            Component::Interference => "interference",
            Component::AgingSweep => "aging_sweep",
            Component::SchedulerWait => "scheduler_wait",
            Component::Compute => "compute",
            Component::KvOther => "kv_other",
        }
    }
}

/// Finished-request ledger: measured latencies plus their component
/// decomposition. Invariants (pinned by `tests/attrib_conservation.rs`):
/// `ttft` sums to exactly `ttft_ns`, and `ttft_ns` plus the `decode`
/// sum equals exactly `e2e_ns`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestAttribution {
    /// Request id (`SeqId.0`).
    pub id: u64,
    pub arrival: Ns,
    /// Measured `first_token_at - arrival`.
    pub ttft_ns: Ns,
    /// Measured `finished_at - arrival`.
    pub e2e_ns: Ns,
    /// TTFT decomposition, indexed by `Component as usize`.
    pub ttft: [Ns; NUM_COMPONENTS],
    /// Decode-phase decomposition, indexed by `Component as usize`.
    pub decode: [Ns; NUM_COMPONENTS],
}

impl RequestAttribution {
    /// Sum of the TTFT components.
    pub fn ttft_sum(&self) -> Ns {
        self.ttft.iter().sum()
    }

    /// Sum of the decode-phase components.
    pub fn decode_sum(&self) -> Ns {
        self.decode.iter().sum()
    }

    /// Nanoseconds of measured latency the ledger failed to attribute.
    /// Zero by construction (the conservation property test pins it).
    pub fn unattributed_ns(&self) -> Ns {
        let ttft_gap = self.ttft_ns.saturating_sub(self.ttft_sum());
        let decode_gap =
            self.e2e_ns.saturating_sub(self.ttft_ns).saturating_sub(self.decode_sum());
        ttft_gap + decode_gap
    }

    /// Combined TTFT + decode charge for one component.
    pub fn total(&self, c: Component) -> Ns {
        self.ttft[c as usize] + self.decode[c as usize]
    }
}

/// Unloaded per-tier reload pricing, used two ways: to split a measured
/// KV stall into pure reload cost vs [`Component::Interference`], and
/// to price the host-baseline counterfactual for
/// [`harvest_economics`]. Transfers are priced per
/// [`RELOAD_CHUNK_BYTES`] descriptor, matching how the KV manager
/// actually issues them.
#[derive(Debug, Clone, Copy)]
pub struct TierPricing {
    pub peer: LinkModel,
    pub cxl: LinkModel,
    pub host: LinkModel,
    pub ssd: LinkModel,
}

impl Default for TierPricing {
    fn default() -> Self {
        Self {
            peer: LinkModel::nvlink_h100(),
            cxl: LinkModel::cxl_mem(),
            host: LinkModel::pcie5_host(),
            ssd: LinkModel::nvme_ssd(),
        }
    }
}

impl TierPricing {
    /// Unloaded cost of moving `bytes` over `link` in
    /// [`RELOAD_CHUNK_BYTES`] descriptors (0 for 0 bytes).
    fn chunked(link: &LinkModel, bytes: u64) -> Ns {
        if bytes == 0 {
            return 0;
        }
        let full = bytes / RELOAD_CHUNK_BYTES;
        let rem = bytes % RELOAD_CHUNK_BYTES;
        let mut total = full.saturating_mul(link.latency(RELOAD_CHUNK_BYTES));
        if rem > 0 {
            total = total.saturating_add(link.latency(rem));
        }
        total
    }

    /// Unloaded price of serving `bytes` from the host baseline — the
    /// counterfactual every harvest tier is measured against.
    pub fn host_price(&self, bytes: u64) -> Ns {
        Self::chunked(&self.host, bytes)
    }

    /// Unloaded price of serving `bytes` from the tier behind
    /// `component` (one of the four `Reload*` components).
    pub fn tier_price(&self, component: Component, bytes: u64) -> Ns {
        let link = match component {
            Component::ReloadPeer => &self.peer,
            Component::ReloadCxl => &self.cxl,
            Component::ReloadHost => &self.host,
            Component::ReloadSsd => &self.ssd,
            _ => return 0,
        };
        Self::chunked(link, bytes)
    }
}

/// Split a measured clock window of `delta` ns across the KV components
/// in proportion to what the [`KvStats`] delta (`after - before`) says
/// happened inside it. Per tier, the unloaded-price share of the
/// recorded stall is charged to that tier's `Reload*` component and the
/// excess to [`Component::Interference`]; recompute and decompression
/// charge their own components. The integer-proportional split's
/// residual lands in [`Component::KvOther`], so the returned array
/// **always sums to exactly `delta`**.
pub fn split_kv_window(
    delta: Ns,
    before: &KvStats,
    after: &KvStats,
    pricing: &TierPricing,
) -> [Ns; NUM_COMPONENTS] {
    let mut out = [0u64; NUM_COMPONENTS];
    if delta == 0 {
        return out;
    }
    let tiers = [
        (
            Component::ReloadPeer,
            after.reload_ns_peer - before.reload_ns_peer,
            after.bytes_from_peer - before.bytes_from_peer,
        ),
        (
            Component::ReloadCxl,
            after.reload_ns_cxl - before.reload_ns_cxl,
            after.bytes_from_cxl - before.bytes_from_cxl,
        ),
        (
            Component::ReloadHost,
            after.reload_ns_host - before.reload_ns_host,
            after.bytes_from_host - before.bytes_from_host,
        ),
        (
            Component::ReloadSsd,
            after.reload_ns_ssd - before.reload_ns_ssd,
            after.bytes_from_ssd - before.bytes_from_ssd,
        ),
    ];
    let mut weights = [0u64; NUM_COMPONENTS];
    for (comp, actual, bytes) in tiers {
        let unloaded = pricing.tier_price(comp, bytes);
        let pure = actual.min(unloaded);
        weights[comp as usize] += pure;
        weights[Component::Interference as usize] += actual - pure;
    }
    weights[Component::Recompute as usize] = after.recompute_ns - before.recompute_ns;
    weights[Component::Decompress as usize] = after.decompress_ns - before.decompress_ns;
    let total: u64 = weights.iter().sum();
    if total == 0 {
        out[Component::KvOther as usize] = delta;
        return out;
    }
    let mut assigned = 0u64;
    for i in 0..NUM_COMPONENTS {
        let share = (delta as u128 * weights[i] as u128 / total as u128) as u64;
        out[i] = share;
        assigned += share;
    }
    // Integer-division residual: keep the sum exact.
    out[Component::KvOther as usize] += delta - assigned;
    out
}

/// One in-flight request's ledger.
#[derive(Debug, Clone)]
struct Ledger {
    arrival: Ns,
    /// Last virtual-time point attributed (telescoping charge cursor).
    cursor: Ns,
    /// `Some(t)` once the first token was produced; earlier charges go
    /// to the TTFT array, later ones to the decode array.
    first_token_at: Option<Ns>,
    ttft: [Ns; NUM_COMPONENTS],
    decode: [Ns; NUM_COMPONENTS],
}

impl Ledger {
    fn add(&mut self, c: Component, ns: Ns) {
        match self.first_token_at {
            None => self.ttft[c as usize] += ns,
            Some(_) => self.decode[c as usize] += ns,
        }
    }
}

/// Stepper-side attribution state machine (armed via
/// `SimEngineConfig::with_attribution` / `[obs] attribution`). The
/// stepper calls one hook per phase boundary; everything here is
/// observation-only.
#[derive(Debug, Clone, Default)]
pub struct AttribTracker {
    pricing: TierPricing,
    /// First `Defer` verdict time, per still-pending request.
    first_defer: BTreeMap<u64, Ns>,
    /// Admitted, not yet finished.
    live: BTreeMap<u64, Ledger>,
    /// Finished-request ledgers, in finish order.
    done: Vec<RequestAttribution>,
}

impl AttribTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// A `Defer` verdict; only the first one is remembered (the
    /// queue-wait / defer-wait boundary).
    pub fn note_defer(&mut self, id: u64, now: Ns) {
        self.first_defer.entry(id).or_insert(now);
    }

    /// A `Shed` verdict: the request will never be served — drop any
    /// deferral record.
    pub fn note_shed(&mut self, id: u64) {
        self.first_defer.remove(&id);
    }

    /// Admission: open the ledger and settle the pre-admission wait
    /// (arrival → first defer → admit).
    pub fn note_admit(&mut self, id: u64, arrival: Ns, now: Ns) {
        let mut ledger = Ledger {
            arrival,
            cursor: now,
            first_token_at: None,
            ttft: [0; NUM_COMPONENTS],
            decode: [0; NUM_COMPONENTS],
        };
        let defer_from = self.first_defer.remove(&id).unwrap_or(now).clamp(arrival, now);
        ledger.ttft[Component::QueueWait as usize] = defer_from - arrival;
        ledger.ttft[Component::AdmissionDefer as usize] = now - defer_from;
        self.live.insert(id, ledger);
    }

    /// Charge `[cursor, upto)` to `c` and move the cursor.
    pub fn charge(&mut self, id: u64, c: Component, upto: Ns) {
        if let Some(l) = self.live.get_mut(&id) {
            let ns = upto.saturating_sub(l.cursor);
            l.add(c, ns);
            l.cursor = l.cursor.max(upto);
        }
    }

    /// Charge `[cursor, upto)` for every id in `ids` to `c`.
    pub fn charge_many(&mut self, ids: impl IntoIterator<Item = u64>, c: Component, upto: Ns) {
        for id in ids {
            self.charge(id, c, upto);
        }
    }

    /// Charge `[cursor, upto)` split across the KV components per
    /// [`split_kv_window`] of the stats delta.
    pub fn charge_kv(&mut self, id: u64, upto: Ns, before: &KvStats, after: &KvStats) {
        if let Some(l) = self.live.get_mut(&id) {
            let delta = upto.saturating_sub(l.cursor);
            let split = split_kv_window(delta, before, after, &self.pricing);
            for (i, &ns) in split.iter().enumerate() {
                if ns > 0 {
                    l.add(Component::ALL[i], ns);
                }
            }
            l.cursor = l.cursor.max(upto);
        }
    }

    /// KV-split charge for every id in `ids`.
    pub fn charge_kv_many(
        &mut self,
        ids: impl IntoIterator<Item = u64>,
        upto: Ns,
        before: &KvStats,
        after: &KvStats,
    ) {
        for id in ids {
            self.charge_kv(id, upto, before, after);
        }
    }

    /// First token produced: seal the TTFT side (its components now sum
    /// to exactly `now - arrival`) and flip subsequent charges to the
    /// decode array.
    pub fn note_first_token(&mut self, id: u64, now: Ns) {
        if let Some(l) = self.live.get_mut(&id) {
            l.cursor = l.cursor.max(now);
            l.first_token_at = Some(now);
        }
    }

    /// Request finished at `now` (must equal the ledger cursor for the
    /// decode side to telescope): seal and move to the finished list.
    pub fn note_finish(&mut self, id: u64, now: Ns) {
        let Some(mut l) = self.live.remove(&id) else { return };
        // Defensive: any gap between the last charge and the recorded
        // finish stays attributed (scheduler wait), never silently lost.
        let gap = now.saturating_sub(l.cursor);
        if gap > 0 {
            l.add(Component::SchedulerWait, gap);
        }
        let first = l.first_token_at.unwrap_or(now);
        self.done.push(RequestAttribution {
            id,
            arrival: l.arrival,
            ttft_ns: first - l.arrival,
            e2e_ns: now - l.arrival,
            ttft: l.ttft,
            decode: l.decode,
        });
    }

    /// Finished-request ledgers accumulated so far.
    pub fn report(&self) -> AttributionReport {
        AttributionReport { requests: self.done.clone() }
    }
}

/// Run-level attribution rollup: the finished-request ledgers plus
/// component totals. Cluster reports concatenate per-node reports with
/// [`AttributionReport::merge`], so the cluster totals are exactly the
/// sum of the per-node totals.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AttributionReport {
    pub requests: Vec<RequestAttribution>,
}

impl AttributionReport {
    /// Total TTFT-side charge for `c` across all requests.
    pub fn ttft_total(&self, c: Component) -> Ns {
        self.requests.iter().map(|r| r.ttft[c as usize]).sum()
    }

    /// Total decode-side charge for `c` across all requests.
    pub fn decode_total(&self, c: Component) -> Ns {
        self.requests.iter().map(|r| r.decode[c as usize]).sum()
    }

    /// Combined TTFT + decode total for `c`.
    pub fn total(&self, c: Component) -> Ns {
        self.ttft_total(c) + self.decode_total(c)
    }

    /// Sum of measured TTFT across requests.
    pub fn ttft_measured_total(&self) -> Ns {
        self.requests.iter().map(|r| r.ttft_ns).sum()
    }

    /// Sum of measured end-to-end latency across requests.
    pub fn e2e_measured_total(&self) -> Ns {
        self.requests.iter().map(|r| r.e2e_ns).sum()
    }

    /// Total unattributed nanoseconds (zero by construction).
    pub fn unattributed_total(&self) -> Ns {
        self.requests.iter().map(|r| r.unattributed_ns()).sum()
    }

    /// Fold another node's report in (cluster rollup).
    pub fn merge(&mut self, other: &AttributionReport) {
        self.requests.extend(other.requests.iter().cloned());
    }

    /// Register the rollup under `prefix` (e.g. `"attrib"`): per-
    /// component TTFT/decode totals plus the measured sums and the
    /// (zero) unattributed remainder.
    pub fn register(&self, reg: &mut MetricsRegistry, prefix: &str) {
        reg.counter(&format!("{prefix}.requests"), self.requests.len() as u64);
        reg.counter(&format!("{prefix}.ttft_measured_ns"), self.ttft_measured_total());
        reg.counter(&format!("{prefix}.e2e_measured_ns"), self.e2e_measured_total());
        reg.counter(&format!("{prefix}.unattributed_ns"), self.unattributed_total());
        for c in Component::ALL {
            reg.counter(&format!("{prefix}.ttft.{}_ns", c.name()), self.ttft_total(c));
            reg.counter(&format!("{prefix}.decode.{}_ns", c.name()), self.decode_total(c));
        }
    }

    /// JSON for `serve --report` / `analyze`: component totals plus the
    /// `top_k` slowest requests by TTFT with their non-zero components.
    pub fn to_json(&self, top_k: usize) -> Json {
        let mut totals = BTreeMap::new();
        for c in Component::ALL {
            let mut t = BTreeMap::new();
            t.insert("ttft_ns".into(), Json::Num(self.ttft_total(c) as f64));
            t.insert("decode_ns".into(), Json::Num(self.decode_total(c) as f64));
            totals.insert(c.name().to_string(), Json::Obj(t));
        }
        let mut order: Vec<&RequestAttribution> = self.requests.iter().collect();
        order.sort_by_key(|r| (std::cmp::Reverse(r.ttft_ns), r.id));
        let slowest: Vec<Json> = order
            .into_iter()
            .take(top_k)
            .map(|r| {
                let mut comps = BTreeMap::new();
                for c in Component::ALL {
                    if r.ttft[c as usize] > 0 {
                        comps.insert(c.name().to_string(), Json::Num(r.ttft[c as usize] as f64));
                    }
                }
                let mut o = BTreeMap::new();
                o.insert("id".into(), Json::Num(r.id as f64));
                o.insert("arrival_ns".into(), Json::Num(r.arrival as f64));
                o.insert("ttft_ns".into(), Json::Num(r.ttft_ns as f64));
                o.insert("e2e_ns".into(), Json::Num(r.e2e_ns as f64));
                o.insert("ttft_components".into(), Json::Obj(comps));
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("requests".into(), Json::Num(self.requests.len() as f64));
        root.insert("ttft_measured_ns".into(), Json::Num(self.ttft_measured_total() as f64));
        root.insert("e2e_measured_ns".into(), Json::Num(self.e2e_measured_total() as f64));
        root.insert("unattributed_ns".into(), Json::Num(self.unattributed_total() as f64));
        root.insert("totals".into(), Json::Obj(totals));
        root.insert("slowest_by_ttft".into(), Json::Arr(slowest));
        Json::Obj(root)
    }
}

/// Harvest cost/benefit accounting derived from [`KvStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HarvestEconomics {
    /// What harvesting cost: revocation recompute plus decompression of
    /// ladder-compressed blocks.
    pub tax_ns: Ns,
    /// What harvesting saved: for every byte served from a
    /// faster-than-host tier (peer HBM, CXL), the unloaded host price
    /// minus the time the fast tier actually took (clamped at zero per
    /// tier — a congested fast tier can save nothing, but never counts
    /// as negative savings here; congestion shows up in the tax-free
    /// [`Component::Interference`] attribution instead).
    pub dividend_ns: Ns,
}

impl HarvestEconomics {
    /// Dividend minus tax (signed: negative means harvesting lost time
    /// net of the host-baseline counterfactual).
    pub fn net_ns(&self) -> i128 {
        self.dividend_ns as i128 - self.tax_ns as i128
    }

    /// Register under `prefix`: `harvest_tax_ns` / `harvest_dividend_ns`
    /// counters and a signed `harvest_net_ns` gauge.
    pub fn register(&self, reg: &mut MetricsRegistry, prefix: &str) {
        reg.counter(&format!("{prefix}.harvest_tax_ns"), self.tax_ns);
        reg.counter(&format!("{prefix}.harvest_dividend_ns"), self.dividend_ns);
        reg.gauge(&format!("{prefix}.harvest_net_ns"), self.net_ns() as f64);
    }
}

/// Price the harvest tax/dividend out of a run's [`KvStats`].
pub fn harvest_economics(stats: &KvStats, pricing: &TierPricing) -> HarvestEconomics {
    let tax_ns = stats.recompute_ns + stats.decompress_ns;
    let mut dividend_ns = 0u64;
    for (bytes, actual) in [
        (stats.bytes_from_peer, stats.reload_ns_peer),
        (stats.bytes_from_cxl, stats.reload_ns_cxl),
    ] {
        dividend_ns += pricing.host_price(bytes).saturating_sub(actual);
    }
    HarvestEconomics { tax_ns, dividend_ns }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pricing() -> TierPricing {
        TierPricing::default()
    }

    #[test]
    fn split_conserves_delta_exactly() {
        let before = KvStats::default();
        let after = KvStats {
            reload_ns_peer: 123_457,
            bytes_from_peer: 32 << 20,
            reload_ns_host: 999_999,
            bytes_from_host: 2 << 20,
            recompute_ns: 77_777,
            decompress_ns: 31,
            ..Default::default()
        };
        for delta in [0u64, 1, 999, 1_000_003, u32::MAX as u64] {
            let split = split_kv_window(delta, &before, &after, &pricing());
            assert_eq!(split.iter().sum::<u64>(), delta, "delta={delta}");
        }
    }

    #[test]
    fn split_with_no_kv_activity_lands_in_other() {
        let s = KvStats::default();
        let split = split_kv_window(5_000, &s, &s, &pricing());
        assert_eq!(split[Component::KvOther as usize], 5_000);
        assert_eq!(split.iter().sum::<u64>(), 5_000);
    }

    #[test]
    fn split_charges_excess_stall_to_interference() {
        let before = KvStats::default();
        let unloaded = pricing().tier_price(Component::ReloadPeer, RELOAD_CHUNK_BYTES);
        // One peer-tier chunk that took 10x its unloaded price.
        let after = KvStats {
            bytes_from_peer: RELOAD_CHUNK_BYTES,
            reload_ns_peer: unloaded * 10,
            ..Default::default()
        };
        let split = split_kv_window(unloaded * 10, &before, &after, &pricing());
        assert_eq!(split[Component::ReloadPeer as usize], unloaded);
        assert_eq!(split[Component::Interference as usize], unloaded * 9);
    }

    #[test]
    fn tracker_ledger_telescopes_to_measured_latency() {
        let mut t = AttribTracker::new();
        t.note_defer(7, 150);
        t.note_defer(7, 200); // repeat defers keep the first timestamp
        t.note_admit(7, 100, 300);
        t.charge(7, Component::PrefillCompute, 900);
        t.note_first_token(7, 900);
        t.charge(7, Component::SchedulerWait, 1_000);
        t.charge(7, Component::Compute, 1_500);
        t.note_finish(7, 1_500);
        let rep = t.report();
        assert_eq!(rep.requests.len(), 1);
        let r = &rep.requests[0];
        assert_eq!(r.ttft_ns, 800);
        assert_eq!(r.e2e_ns, 1_400);
        assert_eq!(r.ttft_sum(), r.ttft_ns);
        assert_eq!(r.ttft_ns + r.decode_sum(), r.e2e_ns);
        assert_eq!(r.unattributed_ns(), 0);
        assert_eq!(r.ttft[Component::QueueWait as usize], 50);
        assert_eq!(r.ttft[Component::AdmissionDefer as usize], 150);
        assert_eq!(r.ttft[Component::PrefillCompute as usize], 600);
        assert_eq!(r.decode[Component::SchedulerWait as usize], 100);
        assert_eq!(r.decode[Component::Compute as usize], 500);
    }

    #[test]
    fn merge_totals_are_per_node_sums() {
        let mut a = AttribTracker::new();
        a.note_admit(1, 0, 10);
        a.charge(1, Component::PrefillCompute, 50);
        a.note_first_token(1, 50);
        a.note_finish(1, 50);
        let mut b = AttribTracker::new();
        b.note_admit(2, 5, 10);
        b.charge(2, Component::PrefillCompute, 40);
        b.note_first_token(2, 40);
        b.note_finish(2, 40);
        let (ra, rb) = (a.report(), b.report());
        let mut merged = ra.clone();
        merged.merge(&rb);
        for c in Component::ALL {
            assert_eq!(merged.total(c), ra.total(c) + rb.total(c));
        }
        let expect = ra.ttft_measured_total() + rb.ttft_measured_total();
        assert_eq!(merged.ttft_measured_total(), expect);
    }

    #[test]
    fn economics_price_the_host_counterfactual() {
        let s = KvStats {
            bytes_from_peer: 64 << 20,
            reload_ns_peer: 100_000,
            recompute_ns: 40_000,
            decompress_ns: 2_000,
            ..Default::default()
        };
        let econ = harvest_economics(&s, &pricing());
        assert_eq!(econ.tax_ns, 42_000);
        let host = pricing().host_price(64 << 20);
        assert_eq!(econ.dividend_ns, host - 100_000);
        assert_eq!(econ.net_ns(), (host - 100_000) as i128 - 42_000);
    }

    #[test]
    fn report_json_has_totals_and_slowest() {
        let mut t = AttribTracker::new();
        for (id, arrival) in [(1u64, 0u64), (2, 10)] {
            t.note_admit(id, arrival, arrival + 100);
            t.charge(id, Component::PrefillCompute, arrival + 100 + 50 * id);
            t.note_first_token(id, arrival + 100 + 50 * id);
            t.note_finish(id, arrival + 100 + 50 * id);
        }
        let json = t.report().to_json(1);
        assert_eq!(json.get("requests").unwrap().as_u64().unwrap(), 2);
        assert_eq!(json.get("unattributed_ns").unwrap().as_u64().unwrap(), 0);
        let slow = json.get("slowest_by_ttft").unwrap();
        let Json::Arr(items) = slow else { panic!("expected array") };
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].get("id").unwrap().as_u64().unwrap(), 2);
    }

    #[test]
    fn register_emits_every_component() {
        let mut t = AttribTracker::new();
        t.note_admit(1, 0, 4);
        t.charge(1, Component::PrefillCompute, 9);
        t.note_first_token(1, 9);
        t.note_finish(1, 9);
        let mut reg = MetricsRegistry::new();
        t.report().register(&mut reg, "attrib");
        assert!(reg.get("attrib.ttft.prefill_compute_ns").is_some());
        assert!(reg.get("attrib.decode.compute_ns").is_some());
        assert!(reg.get("attrib.unattributed_ns").is_some());
        assert_eq!(reg.len(), 4 + 2 * NUM_COMPONENTS);
    }
}
