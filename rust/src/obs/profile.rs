//! Wall-clock per-phase profiler for the stepper hot loop.
//!
//! The stepper's `step()` is the whole serving hot path (ROADMAP open
//! item: "profile the remaining per-step costs"). This module
//! accumulates real (`std::time::Instant`) time per [`Phase`] into
//! thread-local counters via RAII [`PhaseTimer`] guards. When disabled
//! (the default) a timer is a `None` that does nothing on drop — a few
//! nanoseconds per call, cheap enough to leave in the hot loop
//! unconditionally (the `hot_path` bench pins this bound in CI).
//!
//! Wall-clock time never feeds back into the simulation: virtual time
//! and all decisions are identical with profiling on or off.
//!
//! ```
//! use harvest::obs::profile::{self, Phase};
//!
//! profile::enable();
//! {
//!     let _t = profile::timer(Phase::Decode);
//!     // ... work ...
//! }
//! let snap = profile::snapshot();
//! assert_eq!(snap.calls(Phase::Decode), 1);
//! profile::disable();
//! ```

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;

/// One accumulation bucket of the stepper loop.
///
/// `Total` wraps the whole `step()`; the remaining buckets are the
/// disjoint segments inside it, except `Prefill` which nests inside
/// `Admission` (so coverage sums exclude it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// The entire `step()` body.
    Total,
    /// Arrival noting, idle-jump, and the admit loop (includes Prefill).
    Admission,
    /// Prompt prefill of newly admitted requests (nested in Admission).
    Prefill,
    /// Scheduler cohort selection.
    Select,
    /// KV manager sync (revocation application, deferred releases).
    KvSync,
    /// Cold-tier aging sweep.
    Aging,
    /// Residency checks / reloads for the decode cohort.
    Residency,
    /// Prefetch lookahead planning and issue.
    Prefetch,
    /// Virtual compute advance (tenant fleet + clock).
    Compute,
    /// Token append + completion bookkeeping.
    Decode,
}

/// All phases, in display order.
pub const PHASES: [Phase; 10] = [
    Phase::Total,
    Phase::Admission,
    Phase::Prefill,
    Phase::Select,
    Phase::KvSync,
    Phase::Aging,
    Phase::Residency,
    Phase::Prefetch,
    Phase::Compute,
    Phase::Decode,
];

impl Phase {
    fn idx(self) -> usize {
        match self {
            Phase::Total => 0,
            Phase::Admission => 1,
            Phase::Prefill => 2,
            Phase::Select => 3,
            Phase::KvSync => 4,
            Phase::Aging => 5,
            Phase::Residency => 6,
            Phase::Prefetch => 7,
            Phase::Compute => 8,
            Phase::Decode => 9,
        }
    }

    /// Stable bucket name used in JSON output.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Total => "total",
            Phase::Admission => "admission",
            Phase::Prefill => "prefill",
            Phase::Select => "select",
            Phase::KvSync => "kv_sync",
            Phase::Aging => "aging",
            Phase::Residency => "residency",
            Phase::Prefetch => "prefetch",
            Phase::Compute => "compute",
            Phase::Decode => "decode",
        }
    }
}

/// Accumulated wall-clock nanoseconds and call counts per phase.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PhaseProfile {
    ns: [u64; PHASES.len()],
    calls: [u64; PHASES.len()],
}

impl PhaseProfile {
    /// Accumulated nanoseconds in `phase`.
    pub fn ns(&self, phase: Phase) -> u64 {
        self.ns[phase.idx()]
    }

    /// Number of completed timers for `phase`.
    pub fn calls(&self, phase: Phase) -> u64 {
        self.calls[phase.idx()]
    }

    /// Total nanoseconds measured across whole `step()` calls.
    pub fn total_ns(&self) -> u64 {
        self.ns(Phase::Total)
    }

    /// Sum of the disjoint top-level buckets (everything except
    /// `Total` itself and the nested `Prefill`).
    pub fn covered_ns(&self) -> u64 {
        PHASES
            .iter()
            .filter(|&&p| p != Phase::Total && p != Phase::Prefill)
            .map(|&p| self.ns(p))
            .sum()
    }

    /// `covered_ns / total_ns` — how much of the step the buckets
    /// explain (0 when nothing was measured).
    pub fn coverage(&self) -> f64 {
        let total = self.total_ns();
        if total == 0 {
            0.0
        } else {
            self.covered_ns() as f64 / total as f64
        }
    }

    /// Add another profile's accumulations into this one.
    pub fn merge(&mut self, other: &PhaseProfile) {
        for (a, b) in self.ns.iter_mut().zip(other.ns.iter()) {
            *a += b;
        }
        for (a, b) in self.calls.iter_mut().zip(other.calls.iter()) {
            *a += b;
        }
    }

    /// Per-phase `{ns, calls, pct_of_total}` plus a coverage summary.
    pub fn to_json(&self) -> Json {
        let total = self.total_ns();
        let mut phases = BTreeMap::new();
        for &p in &PHASES {
            let mut obj = BTreeMap::new();
            obj.insert("ns".into(), Json::Num(self.ns(p) as f64));
            obj.insert("calls".into(), Json::Num(self.calls(p) as f64));
            let pct = if total == 0 { 0.0 } else { self.ns(p) as f64 * 100.0 / total as f64 };
            obj.insert("pct_of_total".into(), Json::Num((pct * 100.0).round() / 100.0));
            phases.insert(p.name().to_string(), Json::Obj(obj));
        }
        let mut root = BTreeMap::new();
        root.insert("phases".into(), Json::Obj(phases));
        root.insert("total_ns".into(), Json::Num(total as f64));
        root.insert("covered_ns".into(), Json::Num(self.covered_ns() as f64));
        root.insert(
            "coverage".into(),
            Json::Num((self.coverage() * 10_000.0).round() / 10_000.0),
        );
        Json::Obj(root)
    }
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static ACCUM: RefCell<PhaseProfile> = RefCell::new(PhaseProfile::default());
}

/// Turn profiling on for this thread (accumulators keep prior totals;
/// call [`reset`] for a clean slate).
pub fn enable() {
    ENABLED.with(|e| e.set(true));
}

/// Turn profiling off for this thread.
pub fn disable() {
    ENABLED.with(|e| e.set(false));
}

/// Whether profiling is on for this thread.
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Zero all accumulators.
pub fn reset() {
    ACCUM.with(|a| *a.borrow_mut() = PhaseProfile::default());
}

/// Copy of the current accumulators.
pub fn snapshot() -> PhaseProfile {
    ACCUM.with(|a| a.borrow().clone())
}

/// Start timing `phase`; the elapsed wall-clock time is accumulated
/// when the returned guard drops. When profiling is off the guard holds
/// no `Instant` and its drop is a no-op.
#[inline]
pub fn timer(phase: Phase) -> PhaseTimer {
    PhaseTimer { phase, start: if is_enabled() { Some(Instant::now()) } else { None } }
}

/// RAII guard returned by [`timer`].
#[must_use = "the timer accumulates on drop; binding it to `_` drops immediately"]
pub struct PhaseTimer {
    phase: Phase,
    start: Option<Instant>,
}

impl Drop for PhaseTimer {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let dt = t0.elapsed().as_nanos() as u64;
            ACCUM.with(|a| {
                let mut a = a.borrow_mut();
                let i = self.phase.idx();
                a.ns[i] += dt;
                a.calls[i] += 1;
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timer_accumulates_nothing() {
        disable();
        reset();
        {
            let _t = timer(Phase::Compute);
        }
        let snap = snapshot();
        assert_eq!(snap.calls(Phase::Compute), 0);
        assert_eq!(snap.total_ns(), 0);
    }

    #[test]
    fn enabled_timer_counts_calls_and_time() {
        enable();
        reset();
        {
            let _total = timer(Phase::Total);
            let _t = timer(Phase::Decode);
            std::hint::black_box(vec![0u8; 1024]);
        }
        disable();
        let snap = snapshot();
        assert_eq!(snap.calls(Phase::Decode), 1);
        assert_eq!(snap.calls(Phase::Total), 1);
        assert!(snap.ns(Phase::Total) >= snap.ns(Phase::Decode));
        reset();
    }

    #[test]
    fn coverage_excludes_total_and_nested_prefill() {
        let mut p = PhaseProfile::default();
        p.ns[Phase::Total.idx()] = 100;
        p.ns[Phase::Admission.idx()] = 40;
        p.ns[Phase::Prefill.idx()] = 30; // nested inside Admission
        p.ns[Phase::Decode.idx()] = 50;
        assert_eq!(p.covered_ns(), 90);
        assert!((p.coverage() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_buckets() {
        let mut a = PhaseProfile::default();
        a.ns[Phase::Compute.idx()] = 10;
        a.calls[Phase::Compute.idx()] = 1;
        let mut b = PhaseProfile::default();
        b.ns[Phase::Compute.idx()] = 5;
        b.calls[Phase::Compute.idx()] = 2;
        a.merge(&b);
        assert_eq!(a.ns(Phase::Compute), 15);
        assert_eq!(a.calls(Phase::Compute), 3);
    }

    #[test]
    fn json_has_all_phases() {
        let json = PhaseProfile::default().to_json();
        let phases = json.get("phases").unwrap();
        for p in PHASES {
            assert!(phases.get(p.name()).is_ok(), "missing phase {}", p.name());
        }
    }
}
