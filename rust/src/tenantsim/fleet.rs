//! The [`TenantFleet`]: a node's co-tenant population, stepped on the
//! shared virtual clock.
//!
//! The fleet owns the actors and the [`PressureBroker`] and exposes one
//! entry point, [`TenantFleet::advance_to`] — a drop-in replacement for
//! [`HarvestRuntime::advance_to`] that dispatches actor events (in
//! virtual-time order, ties broken by actor index) on the way to `t`.
//! An empty fleet degenerates to exactly `hr.advance_to(t)`, and a
//! fleet of [`super::ReplayActor`]s only installs timelines, so
//! replay-mode runs reproduce pre-fleet pressure sequences bit-for-bit.
//!
//! ```
//! use harvest::harvest::{HarvestConfig, HarvestRuntime};
//! use harvest::memsim::{NodeSpec, SimNode, TenantLoad};
//! use harvest::tenantsim::{ReplayActor, TenantFleet};
//!
//! const GIB: u64 = 1 << 30;
//! let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()),
//!                                  HarvestConfig::for_node(2));
//! let mut fleet = TenantFleet::new();
//! // replay mode: the old exogenous timeline behind the actor trait
//! let load = TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000, 10 * GIB)]);
//! fleet.push(Box::new(ReplayActor::new("replay-1", 1, load)));
//! fleet.advance_to(&mut hr, 2_000);
//! assert_eq!(hr.node.harvestable_now(1), 70 * GIB);
//! ```

use super::actor::{ActorStats, TenantActor, TenantCtx, TenantPriority};
use super::actors::{BatchActor, InferenceActor, TrainingActor};
use super::broker::{BrokerStats, PressureBroker};
use crate::harvest::HarvestRuntime;
use crate::memsim::Ns;

const GIB: u64 = 1 << 30;
const MIB: u64 = 1 << 20;

/// Declarative actor mix — the `[tenants]` TOML section, also usable
/// per cluster node (`[tenants.node<k>]` overrides).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantMix {
    /// Master switch; a disabled mix builds an empty fleet.
    pub enabled: bool,
    /// Training jobs (each spans every GPU with a ring all-reduce).
    pub training: usize,
    /// Co-located inference services (one GPU each, KV-churn style).
    pub inference: usize,
    /// Bursty batch jobs (one GPU each).
    pub batch: usize,
    /// Persistent model footprint per GPU per training job (GiB).
    pub training_gib: u64,
    /// Oscillating activation footprint per GPU per training job (GiB).
    pub activation_gib: u64,
    /// Host-DRAM staging per training job (GiB) — host-tier pressure.
    pub host_gib: u64,
    /// Ring all-reduce payload per participant per step (MiB).
    pub collective_mib: u64,
    /// Training step cadence (µs).
    pub step_period_us: u64,
    /// Stationary mean GPU-memory utilisation each inference service
    /// targets (fraction of one GPU's capacity).
    pub inference_target: f64,
    /// Burst size per batch job (GiB).
    pub batch_gib: u64,
    /// Batch jobs' priority: `guaranteed` bursts revoke harvest leases
    /// (the paper's co-tenant), `best-effort` ones are preemptible
    /// fillers that lose to Harvest instead.
    pub batch_priority: TenantPriority,
    pub seed: u64,
}

impl Default for TenantMix {
    fn default() -> Self {
        Self {
            enabled: false,
            training: 1,
            inference: 1,
            batch: 1,
            training_gib: 8,
            activation_gib: 4,
            host_gib: 0,
            collective_mib: 64,
            step_period_us: 1_000,
            inference_target: 0.2,
            batch_gib: 8,
            batch_priority: TenantPriority::Guaranteed,
            seed: 0,
        }
    }
}

/// Fleet-level rollup: per-actor counters plus the broker's.
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    /// `(label, counters)` per actor, fleet order.
    pub actors: Vec<(String, ActorStats)>,
    pub broker: BrokerStats,
}

impl FleetStats {
    /// Bytes tenant actors hold right now, all tiers.
    pub fn held_bytes(&self) -> u64 {
        self.actors.iter().map(|(_, s)| s.held_bytes).sum()
    }

    /// Link traffic the actors injected (collectives + loads).
    pub fn traffic_bytes(&self) -> u64 {
        self.actors.iter().map(|(_, s)| s.traffic_bytes).sum()
    }

    /// Actor allocations denied or failed.
    pub fn denied(&self) -> u64 {
        self.actors.iter().map(|(_, s)| s.denied).sum()
    }
}

/// A node's co-tenant population: actors + broker, stepped together.
#[derive(Default)]
pub struct TenantFleet {
    actors: Vec<Box<dyn TenantActor>>,
    broker: PressureBroker,
    installed: bool,
}

impl TenantFleet {
    /// An empty fleet (`advance_to` == `HarvestRuntime::advance_to`).
    pub fn new() -> Self {
        Self::default()
    }

    /// Build the fleet a [`TenantMix`] describes for an `n_gpus`-GPU
    /// node with `hbm_bytes` per GPU. `seed_salt` decorrelates per-node
    /// fleets built from one mix (pass the node id). Actors that target
    /// a single GPU rotate over GPUs `1..n` — GPU 0 is the serving
    /// stack's compute GPU, whose arena harvest never touches.
    pub fn from_mix(mix: &TenantMix, n_gpus: usize, hbm_bytes: u64, seed_salt: u64) -> Self {
        let mut fleet = Self::new();
        if !mix.enabled {
            return fleet;
        }
        let seed = mix.seed ^ (seed_salt.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        for i in 0..mix.training {
            fleet.push(Box::new(TrainingActor::new(
                format!("train-{i}"),
                (0..n_gpus).collect(),
                mix.training_gib * GIB,
                mix.activation_gib * GIB,
                mix.host_gib * GIB,
                mix.collective_mib * MIB,
                (mix.step_period_us * 1_000).max(1),
            )));
        }
        let peer = |i: usize| if n_gpus > 1 { 1 + i % (n_gpus - 1) } else { 0 };
        for i in 0..mix.inference {
            fleet.push(Box::new(InferenceActor::new(
                format!("infer-{i}"),
                peer(i),
                hbm_bytes,
                mix.inference_target,
                256 * MIB,
                5_000_000, // 5 ms mean hold
                seed.wrapping_add(0x1000 + i as u64),
            )));
        }
        for i in 0..mix.batch {
            fleet.push(Box::new(BatchActor::new(
                format!("batch-{i}"),
                peer(i + mix.inference),
                mix.batch_gib * GIB,
                10_000_000, // 10 ms mean idle
                5_000_000,  // 5 ms mean hold
                mix.batch_priority,
                seed.wrapping_add(0x2000 + i as u64),
            )));
        }
        fleet
    }

    /// Add an actor (builder-style fleets for tests and benches).
    pub fn push(&mut self, actor: Box<dyn TenantActor>) {
        assert!(!self.installed, "add actors before the fleet first runs");
        self.actors.push(actor);
    }

    pub fn len(&self) -> usize {
        self.actors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actors.is_empty()
    }

    pub fn broker(&self) -> &PressureBroker {
        &self.broker
    }

    /// One-time actor setup (replay timelines, persistent footprints).
    /// Idempotent; `advance_to` calls it lazily.
    pub fn install(&mut self, hr: &mut HarvestRuntime) {
        if self.installed {
            return;
        }
        self.installed = true;
        for actor in &mut self.actors {
            let mut ctx = TenantCtx { hr, broker: &mut self.broker };
            actor.install(&mut ctx);
        }
    }

    /// Advance virtual time to `t`, dispatching every actor event on
    /// the way (earliest wake first, ties by actor index) and enforcing
    /// harvest pressure at each — the fleet-aware replacement for
    /// [`HarvestRuntime::advance_to`].
    pub fn advance_to(&mut self, hr: &mut HarvestRuntime, t: Ns) {
        self.install(hr);
        loop {
            let next = self
                .actors
                .iter()
                .enumerate()
                .filter_map(|(i, a)| a.next_wake().map(|w| (w, i)))
                .min()
                .filter(|&(w, _)| w <= t);
            let Some((wake, i)) = next else { break };
            // An actor created mid-run may want a past wake; run it now.
            let at = wake.max(hr.node.clock.now());
            hr.advance_to(at);
            crate::obs::trace::instant(
                crate::obs::trace::Subsystem::Tenant,
                "wake",
                at,
                &[("actor", i as u64)],
            );
            let mut ctx = TenantCtx { hr, broker: &mut self.broker };
            self.actors[i].step(at, &mut ctx);
            debug_assert!(
                self.actors[i].next_wake().is_none_or(|w| w > wake),
                "actor {} did not advance past {wake}",
                self.actors[i].label()
            );
        }
        hr.advance_to(t);
    }

    /// Current per-actor + broker counters.
    pub fn stats(&self) -> FleetStats {
        FleetStats {
            actors: self
                .actors
                .iter()
                .map(|a| (a.label().to_string(), a.stats()))
                .collect(),
            broker: self.broker.stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::{HarvestConfig, RevocationReason};
    use crate::memsim::{NodeSpec, SimNode, TenantLoad};
    use crate::util::rng::Rng;

    fn rt() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    #[test]
    fn empty_fleet_is_plain_advance() {
        let mut a = rt();
        let mut b = rt();
        let mut fleet = TenantFleet::new();
        a.advance_to(5_000_000);
        fleet.advance_to(&mut b, 5_000_000);
        assert_eq!(a.node.clock.now(), b.node.clock.now());
        assert_eq!(a.revocations.len(), b.revocations.len());
    }

    #[test]
    fn replay_actor_reproduces_timeline_pressure_bit_for_bit() {
        let load = {
            let mut rng = Rng::new(11);
            TenantLoad::generate(
                &mut rng,
                80 * GIB,
                0.6,
                crate::memsim::tenant::TenantChurn::default(),
                2_000_000_000,
            )
        };
        let run = |replay: bool| {
            let mut hr = rt();
            let mut fleet = TenantFleet::new();
            if replay {
                fleet.push(Box::new(super::super::ReplayActor::new(
                    "replay",
                    1,
                    load.clone(),
                )));
            } else {
                hr.node.set_tenant_load(1, load.clone());
            }
            let s = hr.open_session(crate::harvest::PayloadKind::Generic);
            let hints = crate::harvest::AllocHints {
                compute_gpu: Some(0),
                ..Default::default()
            };
            let mut revs = Vec::new();
            let mut leases = Vec::new();
            for step in 1..=40u64 {
                if let Ok(l) = s.alloc(
                    &mut hr,
                    2 * GIB,
                    crate::harvest::TierPreference::PEER_ONLY,
                    hints,
                ) {
                    leases.push(l);
                }
                fleet.advance_to(&mut hr, step * 50_000_000);
                for ev in s.drain_revocations(&mut hr) {
                    leases.retain(|l| l.id() != ev.lease);
                }
                revs.extend(hr.revocations.drain(..).map(|r| (r.at, r.handle.id)));
            }
            drop(leases);
            hr.sweep_leaked();
            revs
        };
        let replayed = run(true);
        assert!(!replayed.is_empty(), "pressure at 0.6 utilisation must revoke something");
        assert_eq!(replayed, run(false), "replay mode must be bit-for-bit");
    }

    #[test]
    fn from_mix_builds_and_runs() {
        let mix = TenantMix { enabled: true, ..Default::default() };
        let mut fleet = TenantFleet::from_mix(&mix, 2, 80 * GIB, 0);
        assert_eq!(fleet.len(), 3);
        let mut hr = rt();
        fleet.advance_to(&mut hr, 50_000_000);
        let stats = fleet.stats();
        assert!(stats.held_bytes() > 0, "training model footprint persists");
        assert!(stats.traffic_bytes() > 0, "collective traffic injected");
        assert!(stats.broker.allocs > 0);
        // disabled mix builds nothing
        assert!(TenantFleet::from_mix(&TenantMix::default(), 2, 80 * GIB, 0).is_empty());
    }

    #[test]
    fn tenant_burst_revokes_harvest_lease() {
        let mut hr = rt();
        let s = hr.open_session(crate::harvest::PayloadKind::Generic);
        let hints =
            crate::harvest::AllocHints { compute_gpu: Some(0), ..Default::default() };
        let lease = s
            .alloc(&mut hr, 70 * GIB, crate::harvest::TierPreference::PEER_ONLY, hints)
            .unwrap();
        let mut fleet = TenantFleet::new();
        fleet.push(Box::new(BatchActor::new(
            "batch-0",
            1,
            40 * GIB,
            1_000_000,
            5_000_000,
            TenantPriority::Guaranteed,
            7,
        )));
        fleet.advance_to(&mut hr, 100_000_000);
        assert!(!hr.is_live(lease.id()), "the burst must evict the lease");
        assert!(hr
            .revocations
            .iter()
            .any(|r| r.reason == RevocationReason::TenantPressure));
        assert!(fleet.broker().stats.lease_yields >= 1);
        drop(lease);
        hr.sweep_leaked();
    }

    const GIB: u64 = 1 << 30;
}
