//! The [`PressureBroker`]: mediates tenant allocation demands against
//! harvested leases.
//!
//! The paper's correctness invariant is that harvesting is *invisible*
//! to co-tenants: their allocations behave as if Harvest were not
//! there. The broker enforces exactly that. A tenant allocation first
//! tries the arena directly (free capacity); if it fails and the tenant
//! is [`TenantPriority::Guaranteed`], the broker makes harvest yield —
//! first waiting out in-flight migration reads whose budget already
//! left the tier ([`HarvestRuntime::drain_deferred_frees`]: pure
//! recovery, an allocator stall), then revoking or demoting leases
//! ([`HarvestRuntime::yield_to_tenant`] /
//! [`HarvestRuntime::yield_tier_to_tenant`]) — until the allocation
//! fits or harvest genuinely holds nothing there. Only then is the
//! tenant OOM, and that OOM is real: the arena is full of *other
//! tenants'* bytes.

use super::actor::{TenantPriority, TenantSegment};
use crate::harvest::{HarvestRuntime, MemoryTier};

/// A tenant allocation failure. After a guaranteed-priority failure no
/// revocable harvest lease remains on the tier — the pressure came from
/// other tenants, not from Harvest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantOom {
    pub tier: MemoryTier,
    pub requested: u64,
}

impl std::fmt::Display for TenantOom {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tenant OOM: {} bytes on {}", self.requested, self.tier)
    }
}

impl std::error::Error for TenantOom {}

/// Cumulative broker counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct BrokerStats {
    pub allocs: u64,
    pub alloc_bytes: u64,
    pub frees: u64,
    pub freed_bytes: u64,
    /// Harvest leases revoked/demoted to make a tenant allocation fit.
    pub lease_yields: u64,
    /// Times a tenant allocation had to wait out an in-flight
    /// migration's source read (deferred frees drained).
    pub inflight_waits: u64,
    /// Best-effort allocations denied (no eviction attempted).
    pub denied: u64,
    /// Guaranteed allocations that failed with no harvest lease left to
    /// revoke — genuine tenant-vs-tenant OOM.
    pub oom: u64,
    /// OOMs declared while harvest still held live bytes on the tier.
    /// Always 0 by construction ("tenants always win"); counted so the
    /// conservation property test can assert it directly.
    pub oom_with_harvest: u64,
}

impl BrokerStats {
    /// Register the broker counters into the unified metrics registry
    /// under `prefix` (e.g. `"tenants.broker"`).
    pub fn register(&self, reg: &mut crate::obs::MetricsRegistry, prefix: &str) {
        reg.counter(&format!("{prefix}.allocs"), self.allocs);
        reg.counter(&format!("{prefix}.alloc_bytes"), self.alloc_bytes);
        reg.counter(&format!("{prefix}.frees"), self.frees);
        reg.counter(&format!("{prefix}.freed_bytes"), self.freed_bytes);
        reg.counter(&format!("{prefix}.lease_yields"), self.lease_yields);
        reg.counter(&format!("{prefix}.inflight_waits"), self.inflight_waits);
        reg.counter(&format!("{prefix}.denied"), self.denied);
        reg.counter(&format!("{prefix}.oom"), self.oom);
        reg.counter(&format!("{prefix}.oom_with_harvest"), self.oom_with_harvest);
    }
}

/// Mediates tenant allocations against harvested leases (one per
/// [`super::TenantFleet`], i.e. per node).
///
/// Tenant segments are real arena segments; per-GPU held bytes live on
/// [`crate::memsim::node::Gpu::tenant_held`] (where the harvest
/// controller's pressure accounting reads them), host/CXL held bytes on
/// the broker itself (the arenas' `free_bytes` is what `place_tiered`
/// consults there).
#[derive(Debug, Default)]
pub struct PressureBroker {
    host_held: u64,
    cxl_held: u64,
    pub stats: BrokerStats,
}

impl PressureBroker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes tenant actors hold on `tier` through this broker's node.
    /// The SSD cold tier is harvest backing store, pressure-exempt by
    /// construction — tenants never allocate there, so it reports 0.
    pub fn held_on(&self, hr: &HarvestRuntime, tier: MemoryTier) -> u64 {
        match tier {
            MemoryTier::PeerHbm(g) => hr.node.gpus[g].tenant_held,
            MemoryTier::Host => self.host_held,
            MemoryTier::CxlMem => self.cxl_held,
            MemoryTier::LocalHbm | MemoryTier::Ssd => 0,
        }
    }

    /// Allocate `bytes` on `tier` for a tenant. Guaranteed priority
    /// makes harvest yield (revoke → demote → wait out in-flight
    /// migration reads) until the allocation fits or no harvest state
    /// remains on the tier; best-effort takes free capacity or is
    /// denied.
    pub fn alloc(
        &mut self,
        hr: &mut HarvestRuntime,
        tier: MemoryTier,
        bytes: u64,
        priority: TenantPriority,
    ) -> Result<TenantSegment, TenantOom> {
        assert!(bytes > 0, "zero-size tenant allocation");
        assert!(tier != MemoryTier::LocalHbm, "local HBM is not a tenant tier");
        assert!(
            tier != MemoryTier::Ssd,
            "the SSD cold tier is harvest backing store, not a tenant tier"
        );
        if tier == MemoryTier::CxlMem && !hr.node.has_cxl() {
            // No expander: a hard failure for a guaranteed tenant, a
            // plain denial for a best-effort one.
            if priority.evicts_harvest() {
                self.stats.oom += 1;
            } else {
                self.stats.denied += 1;
            }
            return Err(TenantOom { tier, requested: bytes });
        }
        loop {
            let arena = match tier {
                MemoryTier::PeerHbm(g) => &mut hr.node.gpus[g].hbm,
                MemoryTier::Host => &mut hr.node.host,
                MemoryTier::CxlMem => &mut hr.node.cxl,
                MemoryTier::LocalHbm | MemoryTier::Ssd => unreachable!(),
            };
            match arena.alloc(bytes) {
                Ok(alloc) => {
                    match tier {
                        MemoryTier::PeerHbm(g) => hr.node.gpus[g].tenant_held += bytes,
                        MemoryTier::Host => self.host_held += bytes,
                        MemoryTier::CxlMem => self.cxl_held += bytes,
                        MemoryTier::LocalHbm | MemoryTier::Ssd => unreachable!(),
                    }
                    self.stats.allocs += 1;
                    self.stats.alloc_bytes += bytes;
                    // The new footprint may push a peer under the
                    // configured reserve headroom: enforce now, so
                    // harvest yields when the tenant lands rather than
                    // at the next consumer call.
                    if tier.is_peer() {
                        hr.enforce_pressure();
                    }
                    return Ok(TenantSegment { tier, alloc, bytes });
                }
                Err(_) => {
                    if !priority.evicts_harvest() {
                        self.stats.denied += 1;
                        return Err(TenantOom { tier, requested: bytes });
                    }
                    // Prefer waiting out in-flight migration reads over
                    // evicting another lease: a pending source's budget
                    // has already left this tier, so draining it is pure
                    // recovery (an allocator stall), not new harvest
                    // loss. Without this order, demote_to_host would
                    // cascade — every demotion leaves its source pinned,
                    // so the retry keeps failing and evicts the next
                    // victim until nothing remains.
                    if hr.drain_deferred_frees(tier) > 0 {
                        self.stats.inflight_waits += 1;
                        continue;
                    }
                    if hr.yield_tier_to_tenant(tier) {
                        self.stats.lease_yields += 1;
                        continue;
                    }
                    self.stats.oom += 1;
                    if hr.live_bytes_on_tier(tier) > 0 {
                        self.stats.oom_with_harvest += 1;
                    }
                    return Err(TenantOom { tier, requested: bytes });
                }
            }
        }
    }

    /// Return a segment to its arena.
    pub fn free(&mut self, hr: &mut HarvestRuntime, seg: TenantSegment) {
        match seg.tier {
            MemoryTier::PeerHbm(g) => {
                hr.node.gpus[g].hbm.free(seg.alloc);
                hr.node.gpus[g].tenant_held -= seg.bytes;
            }
            MemoryTier::Host => {
                hr.node.host.free(seg.alloc);
                self.host_held -= seg.bytes;
            }
            MemoryTier::CxlMem => {
                hr.node.cxl.free(seg.alloc);
                self.cxl_held -= seg.bytes;
            }
            MemoryTier::LocalHbm | MemoryTier::Ssd => {
                unreachable!("not a tenant tier")
            }
        }
        self.stats.frees += 1;
        self.stats.freed_bytes += seg.bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::{
        AllocHints, HarvestConfig, PayloadKind, RevocationReason, TierPreference, Transfer,
    };
    use crate::memsim::{NodeSpec, SimNode};

    const GIB: u64 = 1 << 30;
    const MIB: u64 = 1 << 20;

    fn rt() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    fn hints() -> AllocHints {
        AllocHints { compute_gpu: Some(0), ..Default::default() }
    }

    #[test]
    fn tenant_alloc_occupies_real_arena_bytes() {
        let mut hr = rt();
        let mut b = PressureBroker::new();
        let seg = b
            .alloc(&mut hr, MemoryTier::PeerHbm(1), 10 * GIB, TenantPriority::Guaranteed)
            .unwrap();
        assert_eq!(hr.node.gpus[1].hbm.used(), 10 * GIB);
        assert_eq!(hr.node.gpus[1].tenant_held, 10 * GIB);
        assert_eq!(hr.node.harvestable_now(1), 70 * GIB);
        assert_eq!(b.held_on(&hr, MemoryTier::PeerHbm(1)), 10 * GIB);
        b.free(&mut hr, seg);
        assert_eq!(hr.node.gpus[1].hbm.used(), 0);
        assert_eq!(hr.node.gpus[1].tenant_held, 0);
    }

    #[test]
    fn guaranteed_tenant_evicts_harvest_leases() {
        let mut hr = rt();
        let s = hr.open_session(PayloadKind::Generic);
        // harvest fills most of the peer
        let leases: Vec<_> = (0..4)
            .map(|_| s.alloc(&mut hr, 19 * GIB, TierPreference::PEER_ONLY, hints()).unwrap())
            .collect();
        assert_eq!(hr.live_bytes_on(1), 76 * GIB);
        // a 10 GiB tenant burst does not fit in the 4 GiB slack: harvest
        // must yield exactly enough victims
        let mut b = PressureBroker::new();
        let seg = b
            .alloc(&mut hr, MemoryTier::PeerHbm(1), 10 * GIB, TenantPriority::Guaranteed)
            .unwrap();
        assert_eq!(seg.bytes, 10 * GIB);
        assert!(b.stats.lease_yields >= 1);
        assert!(hr.revocations.iter().all(|r| r.reason == RevocationReason::TenantPressure));
        assert!(hr.live_bytes_on(1) < 76 * GIB);
        // the evicted consumer hears about it through its session
        assert!(!s.drain_revocations(&mut hr).is_empty());
        b.free(&mut hr, seg);
        for l in leases {
            if hr.is_live(l.id()) {
                s.release(&mut hr, l).unwrap();
            }
        }
    }

    #[test]
    fn best_effort_tenant_is_denied_not_harvest() {
        let mut hr = rt();
        let s = hr.open_session(PayloadKind::Generic);
        let lease = s.alloc(&mut hr, 79 * GIB, TierPreference::PEER_ONLY, hints()).unwrap();
        let mut b = PressureBroker::new();
        let err = b
            .alloc(&mut hr, MemoryTier::PeerHbm(1), 10 * GIB, TenantPriority::BestEffort)
            .unwrap_err();
        assert_eq!(err.tier, MemoryTier::PeerHbm(1));
        assert_eq!(b.stats.denied, 1);
        assert!(hr.is_live(lease.id()), "best-effort tenants never evict");
        s.release(&mut hr, lease).unwrap();
    }

    #[test]
    fn tenant_waits_out_inflight_migration_reads() {
        // A demoted lease's source segment is pending-free until the
        // async copy completes; a guaranteed tenant needing those bytes
        // drains the copy instead of OOMing.
        let mut hr = rt();
        let s = hr.open_session(PayloadKind::Generic);
        let lease = s.alloc(&mut hr, 79 * GIB, TierPreference::PEER_ONLY, hints()).unwrap();
        Transfer::new().migrate(&lease, MemoryTier::Host).submit(&mut hr).unwrap();
        assert_eq!(hr.pending_free_bytes_on_tier(MemoryTier::PeerHbm(1)), 79 * GIB);
        let mut b = PressureBroker::new();
        let seg = b
            .alloc(&mut hr, MemoryTier::PeerHbm(1), 79 * GIB, TenantPriority::Guaranteed)
            .unwrap();
        assert_eq!(b.stats.inflight_waits, 1);
        assert_eq!(b.stats.oom, 0);
        assert_eq!(hr.pending_free_bytes_on_tier(MemoryTier::PeerHbm(1)), 0);
        b.free(&mut hr, seg);
        s.release(&mut hr, lease).unwrap();
    }

    #[test]
    fn host_pressure_evicts_host_leases_and_fails_pins() {
        // Small host arena so tenant pressure there is meaningful.
        let mut spec = NodeSpec::h100x2();
        spec.host_dram_bytes = 8 * GIB;
        let mut hr = HarvestRuntime::new(SimNode::new(spec), HarvestConfig::for_node(2));
        let s = hr.open_session(PayloadKind::Generic);
        let host_lease =
            s.alloc(&mut hr, 6 * GIB, TierPreference::Pinned(MemoryTier::Host), hints()).unwrap();
        let mut b = PressureBroker::new();
        // tenant claims the host tier; the harvest host lease yields
        let seg =
            b.alloc(&mut hr, MemoryTier::Host, 7 * GIB, TenantPriority::Guaranteed).unwrap();
        assert!(!hr.is_live(host_lease.id()), "host lease revoked for the tenant");
        assert_eq!(b.stats.lease_yields, 1);
        // and under that pressure a new host pin fails TierUnavailable
        let err = s
            .alloc(&mut hr, 4 * GIB, TierPreference::Pinned(MemoryTier::Host), hints())
            .unwrap_err();
        assert_eq!(
            err,
            crate::harvest::HarvestError::TierUnavailable { tier: MemoryTier::Host }
        );
        b.free(&mut hr, seg);
        drop(host_lease);
        hr.sweep_leaked();
    }

    #[test]
    #[should_panic(expected = "not a tenant tier")]
    fn ssd_cold_tier_is_pressure_exempt() {
        // Tenants never contend for the SSD arena: harvest's cold
        // backing store survives any burst by construction.
        let mut hr = HarvestRuntime::new(
            SimNode::new(NodeSpec::h100x2().with_ssd(GIB)),
            HarvestConfig::for_node(2),
        );
        let mut b = PressureBroker::new();
        assert_eq!(b.held_on(&hr, MemoryTier::Ssd), 0);
        let _ = b.alloc(&mut hr, MemoryTier::Ssd, MIB, TenantPriority::Guaranteed);
    }

    #[test]
    fn oom_only_when_no_harvest_left() {
        let mut hr = rt();
        let mut b = PressureBroker::new();
        // two tenants fill the GPU; a third fails with harvest holding
        // nothing — genuine tenant-vs-tenant OOM
        let a = b
            .alloc(&mut hr, MemoryTier::PeerHbm(1), 40 * GIB, TenantPriority::Guaranteed)
            .unwrap();
        let c = b
            .alloc(&mut hr, MemoryTier::PeerHbm(1), 40 * GIB, TenantPriority::Guaranteed)
            .unwrap();
        let err = b
            .alloc(&mut hr, MemoryTier::PeerHbm(1), GIB, TenantPriority::Guaranteed)
            .unwrap_err();
        assert_eq!(err.requested, GIB);
        assert_eq!(b.stats.oom, 1);
        assert_eq!(hr.live_bytes_on(1), 0);
        b.free(&mut hr, a);
        b.free(&mut hr, c);
    }
}
