//! The [`TenantActor`] trait and the context actors act through.
//!
//! An actor is an event-driven co-tenant workload on the shared
//! simulation clock: it names the next virtual time it wants to run
//! ([`TenantActor::next_wake`]), and when the [`super::TenantFleet`]
//! reaches that time it gets one [`TenantActor::step`] with a
//! [`TenantCtx`] — the capability to allocate/free real arena segments
//! (through the [`super::PressureBroker`], so harvest leases yield) and
//! to inject traffic onto the node's FIFO links.

use super::broker::{PressureBroker, TenantOom};
use crate::harvest::{HarvestRuntime, MemoryTier};
use crate::memsim::{CollectiveTraffic, DeviceId, Ns};

/// How hard a tenant allocation pushes when the arena is full.
///
/// ```
/// use harvest::tenantsim::TenantPriority;
/// // Guaranteed tenants evict harvest leases; best-effort ones don't.
/// assert!(TenantPriority::Guaranteed.evicts_harvest());
/// assert!(!TenantPriority::BestEffort.evicts_harvest());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TenantPriority {
    /// The paper's co-tenant: its allocation *must* succeed while any
    /// revocable harvest lease (or in-flight migration source) occupies
    /// the arena — the broker revokes/demotes/waits until it fits.
    #[default]
    Guaranteed,
    /// Opportunistic tenant (e.g. preemptible batch filler): takes only
    /// genuinely free capacity and is denied rather than evicting
    /// harvest state.
    BestEffort,
}

impl TenantPriority {
    /// Whether a failed allocation at this priority may revoke harvest
    /// leases to make room.
    pub fn evicts_harvest(&self) -> bool {
        matches!(self, TenantPriority::Guaranteed)
    }

    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "guaranteed" => Ok(TenantPriority::Guaranteed),
            "best-effort" | "besteffort" => Ok(TenantPriority::BestEffort),
            other => anyhow::bail!("unknown tenant priority `{other}` (guaranteed | best-effort)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TenantPriority::Guaranteed => "guaranteed",
            TenantPriority::BestEffort => "best-effort",
        }
    }
}

/// A real arena segment held by a tenant actor. Obtained from
/// [`TenantCtx::alloc`], returned with [`TenantCtx::free`]; the broker
/// keeps per-tier held-byte accounting in sync.
#[derive(Debug)]
pub struct TenantSegment {
    pub tier: MemoryTier,
    pub(crate) alloc: crate::memsim::AllocId,
    pub bytes: u64,
}

/// Cumulative per-actor activity counters (for reports and benches).
#[derive(Debug, Clone, Copy, Default)]
pub struct ActorStats {
    /// Steps executed.
    pub steps: u64,
    /// Bytes currently held across all tiers.
    pub held_bytes: u64,
    /// Cumulative bytes allocated.
    pub alloc_bytes: u64,
    /// Cumulative bytes freed.
    pub freed_bytes: u64,
    /// Allocations denied (best-effort) or failed (genuine OOM).
    pub denied: u64,
    /// Bytes of link traffic injected (collectives, H2D loads).
    pub traffic_bytes: u64,
}

/// What an actor can do during a step: broker-mediated allocation plus
/// direct traffic injection onto the node's links.
pub struct TenantCtx<'a> {
    pub hr: &'a mut HarvestRuntime,
    pub broker: &'a mut PressureBroker,
}

impl TenantCtx<'_> {
    /// Current virtual time.
    pub fn now(&self) -> Ns {
        self.hr.node.clock.now()
    }

    /// Allocate `bytes` of tier memory for a tenant. `PeerHbm(g)` here
    /// simply names GPU `g`'s arena — tenants are co-located, every GPU
    /// is "local" to them. See [`PressureBroker::alloc`].
    pub fn alloc(
        &mut self,
        tier: MemoryTier,
        bytes: u64,
        priority: TenantPriority,
    ) -> Result<TenantSegment, TenantOom> {
        self.broker.alloc(self.hr, tier, bytes, priority)
    }

    /// Return a segment to its arena.
    pub fn free(&mut self, seg: TenantSegment) {
        self.broker.free(self.hr, seg);
    }

    /// Schedule this collective's steps up to `until` onto the node's
    /// links (FIFO per direction — Harvest's own copies queue behind
    /// them, and vice versa). Returns the bytes injected.
    pub fn inject_collective(&mut self, c: &mut CollectiveTraffic, until: Ns) -> u64 {
        let before = c.bytes_injected;
        c.inject_until(&mut self.hr.node.topo, until);
        c.bytes_injected - before
    }

    /// Schedule one point-to-point transfer starting now (e.g. an
    /// inference tenant's host→GPU weight or KV load).
    pub fn schedule_copy(&mut self, src: DeviceId, dst: DeviceId, bytes: u64) {
        let now = self.now();
        self.hr.node.topo.schedule(src, dst, bytes, now);
    }
}

/// A closed-loop co-tenant workload on the simulation clock.
///
/// Contract: after [`TenantActor::step`] runs at time `t`, the actor's
/// [`TenantActor::next_wake`] must be strictly greater than `t` (or
/// `None`) — the fleet relies on this for progress.
pub trait TenantActor {
    /// Display label (e.g. `train-0`).
    fn label(&self) -> &str;

    /// One-time setup at fleet install: replay actors register their
    /// timeline, resident tenants grab their persistent footprint.
    fn install(&mut self, _ctx: &mut TenantCtx<'_>) {}

    /// The next virtual time this actor wants to run; `None` = passive.
    fn next_wake(&self) -> Option<Ns>;

    /// Run the actor at `now` (its wake time, possibly later if the
    /// fleet is catching up after an idle jump).
    fn step(&mut self, now: Ns, ctx: &mut TenantCtx<'_>);

    /// Cumulative activity counters.
    fn stats(&self) -> ActorStats;
}
