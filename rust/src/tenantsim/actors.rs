//! Concrete co-tenant actors: a training job, a second inference
//! service, bursty batch jobs, and the replay-mode wrapper over the
//! pre-generated [`TenantLoad`] timeline.
//!
//! All actors are deterministic given their seed and the fleet's step
//! order, so runs reproduce exactly.

use super::actor::{ActorStats, TenantActor, TenantCtx, TenantPriority, TenantSegment};
use crate::harvest::MemoryTier;
use crate::memsim::{CollectivePattern, CollectiveTraffic, DeviceId, Ns, TenantLoad};
use crate::util::rng::Rng;

fn take_all(stats: &mut ActorStats, ctx: &mut TenantCtx<'_>, segs: &mut Vec<TenantSegment>) {
    for seg in segs.drain(..) {
        stats.freed_bytes += seg.bytes;
        stats.held_bytes -= seg.bytes;
        ctx.free(seg);
    }
}

fn grab(
    stats: &mut ActorStats,
    ctx: &mut TenantCtx<'_>,
    tier: MemoryTier,
    bytes: u64,
    priority: TenantPriority,
) -> Option<TenantSegment> {
    match ctx.alloc(tier, bytes, priority) {
        Ok(seg) => {
            stats.alloc_bytes += bytes;
            stats.held_bytes += bytes;
            Some(seg)
        }
        Err(_) => {
            stats.denied += 1;
            None
        }
    }
}

/// A data-parallel training job: a persistent per-GPU model footprint,
/// an activation footprint that oscillates with the training step, a
/// host-DRAM staging buffer (optimizer state / checkpoints), and a
/// periodic ring all-reduce injected onto the same NVLink FIFOs the
/// harvest DMA engine uses — the §7 congestion caveat made concrete.
pub struct TrainingActor {
    label: String,
    gpus: Vec<usize>,
    model_bytes_per_gpu: u64,
    activation_bytes: u64,
    host_bytes: u64,
    step_period: Ns,
    collective: CollectiveTraffic,
    model: Vec<TenantSegment>,
    host_seg: Vec<TenantSegment>,
    activations: Vec<TenantSegment>,
    next: Ns,
    stats: ActorStats,
}

impl TrainingActor {
    /// A job training across `gpus` (ring order), holding
    /// `model_bytes_per_gpu` permanently on each, oscillating
    /// `activation_bytes` per GPU with the step cadence, staging
    /// `host_bytes` in host DRAM, and all-reducing
    /// `bytes_per_allreduce` per participant every `step_period`.
    pub fn new(
        label: impl Into<String>,
        gpus: Vec<usize>,
        model_bytes_per_gpu: u64,
        activation_bytes: u64,
        host_bytes: u64,
        bytes_per_allreduce: u64,
        step_period: Ns,
    ) -> Self {
        let collective = CollectiveTraffic::new(
            CollectivePattern::RingAllReduce,
            gpus.clone(),
            bytes_per_allreduce,
            step_period,
        );
        Self {
            label: label.into(),
            gpus,
            model_bytes_per_gpu,
            activation_bytes,
            host_bytes,
            step_period,
            collective,
            model: Vec::new(),
            host_seg: Vec::new(),
            activations: Vec::new(),
            next: 0,
            stats: ActorStats::default(),
        }
    }
}

impl TenantActor for TrainingActor {
    fn label(&self) -> &str {
        &self.label
    }

    fn install(&mut self, ctx: &mut TenantCtx<'_>) {
        let now = ctx.now();
        self.collective.skip_to(now);
        self.next = now;
        if self.model_bytes_per_gpu > 0 {
            for &g in &self.gpus {
                if let Some(seg) = grab(
                    &mut self.stats,
                    ctx,
                    MemoryTier::PeerHbm(g),
                    self.model_bytes_per_gpu,
                    TenantPriority::Guaranteed,
                ) {
                    self.model.push(seg);
                }
            }
        }
        if self.host_bytes > 0 {
            if let Some(seg) = grab(
                &mut self.stats,
                ctx,
                MemoryTier::Host,
                self.host_bytes,
                TenantPriority::Guaranteed,
            ) {
                self.host_seg.push(seg);
            }
        }
    }

    fn next_wake(&self) -> Option<Ns> {
        Some(self.next)
    }

    fn step(&mut self, now: Ns, ctx: &mut TenantCtx<'_>) {
        self.stats.steps += 1;
        // This step's gradient exchange: queued onto the shared links,
        // where harvest fetches will contend with it.
        self.stats.traffic_bytes +=
            ctx.inject_collective(&mut self.collective, now + self.step_period);
        // Activations build up during the forward pass and are released
        // after the backward pass: alternate steps alternate footprint.
        if self.activations.is_empty() {
            if self.activation_bytes > 0 {
                for &g in &self.gpus {
                    if let Some(seg) = grab(
                        &mut self.stats,
                        ctx,
                        MemoryTier::PeerHbm(g),
                        self.activation_bytes,
                        TenantPriority::Guaranteed,
                    ) {
                        self.activations.push(seg);
                    }
                }
            }
        } else {
            take_all(&mut self.stats, ctx, &mut self.activations);
        }
        self.next = now + self.step_period;
    }

    fn stats(&self) -> ActorStats {
        self.stats
    }
}

/// A second inference service co-located on one GPU: Poisson request
/// arrivals, each holding a KV-sized segment for a service-time-like
/// duration and pulling its bytes host→GPU over PCIe on admission.
/// Sized so the stationary mean footprint tracks `target_util` of the
/// GPU's capacity.
pub struct InferenceActor {
    label: String,
    gpu: usize,
    rng: Rng,
    mean_job_bytes: u64,
    mean_hold: Ns,
    mean_gap: Ns,
    priority: TenantPriority,
    /// (expiry, segment), unordered; scanned on wake.
    jobs: Vec<(Ns, TenantSegment)>,
    next_arrival: Ns,
    stats: ActorStats,
}

impl InferenceActor {
    pub fn new(
        label: impl Into<String>,
        gpu: usize,
        capacity: u64,
        target_util: f64,
        mean_job_bytes: u64,
        mean_hold: Ns,
        seed: u64,
    ) -> Self {
        let target_util = target_util.clamp(0.01, 1.0);
        // Little's law: held ≈ rate × hold × size; solve for the gap.
        let gap = mean_job_bytes as f64 * mean_hold as f64
            / (target_util * capacity as f64).max(1.0);
        Self {
            label: label.into(),
            gpu,
            rng: Rng::new(seed),
            mean_job_bytes,
            mean_hold,
            mean_gap: (gap as Ns).max(1),
            priority: TenantPriority::Guaranteed,
            jobs: Vec::new(),
            next_arrival: 0,
            stats: ActorStats::default(),
        }
    }
}

impl TenantActor for InferenceActor {
    fn label(&self) -> &str {
        &self.label
    }

    fn install(&mut self, ctx: &mut TenantCtx<'_>) {
        self.next_arrival = ctx.now();
    }

    fn next_wake(&self) -> Option<Ns> {
        let expiry = self.jobs.iter().map(|&(end, _)| end).min();
        Some(match expiry {
            Some(e) => e.min(self.next_arrival),
            None => self.next_arrival,
        })
    }

    fn step(&mut self, now: Ns, ctx: &mut TenantCtx<'_>) {
        self.stats.steps += 1;
        // Retire finished requests.
        let mut i = 0;
        while i < self.jobs.len() {
            if self.jobs[i].0 <= now {
                let (_, seg) = self.jobs.swap_remove(i);
                self.stats.freed_bytes += seg.bytes;
                self.stats.held_bytes -= seg.bytes;
                ctx.free(seg);
            } else {
                i += 1;
            }
        }
        // Admit the arrival that woke us (if it did).
        if now >= self.next_arrival {
            let scale = self.rng.lognormal(0.0, 0.5);
            let bytes = ((self.mean_job_bytes as f64 * scale) as u64).max(1 << 20);
            let tier = MemoryTier::PeerHbm(self.gpu);
            if let Some(seg) = grab(&mut self.stats, ctx, tier, bytes, self.priority) {
                // KV / weight ingress rides the host link.
                ctx.schedule_copy(DeviceId::Host, DeviceId::Gpu(self.gpu), bytes);
                self.stats.traffic_bytes += bytes;
                let hold = (self.rng.exp(1.0 / self.mean_hold as f64) as Ns).max(1);
                self.jobs.push((now + hold, seg));
            }
            let gap = (self.rng.exp(1.0 / self.mean_gap as f64) as Ns).max(1);
            self.next_arrival = now + gap;
        }
    }

    fn stats(&self) -> ActorStats {
        self.stats
    }
}

/// A bursty batch job: exponential off-periods, then a burst that grabs
/// one large segment, loads it host→GPU, holds it for an exponential
/// on-period and releases it. With [`TenantPriority::Guaranteed`] a
/// burst is exactly the paper's revocation trigger; with
/// [`TenantPriority::BestEffort`] it models a preemptible filler that
/// loses to Harvest instead.
pub struct BatchActor {
    label: String,
    gpu: usize,
    burst_bytes: u64,
    mean_idle: Ns,
    mean_hold: Ns,
    priority: TenantPriority,
    rng: Rng,
    holding: Option<TenantSegment>,
    next: Ns,
    stats: ActorStats,
}

impl BatchActor {
    pub fn new(
        label: impl Into<String>,
        gpu: usize,
        burst_bytes: u64,
        mean_idle: Ns,
        mean_hold: Ns,
        priority: TenantPriority,
        seed: u64,
    ) -> Self {
        Self {
            label: label.into(),
            gpu,
            burst_bytes,
            mean_idle,
            mean_hold,
            priority,
            rng: Rng::new(seed),
            holding: None,
            next: 0,
            stats: ActorStats::default(),
        }
    }
}

impl TenantActor for BatchActor {
    fn label(&self) -> &str {
        &self.label
    }

    fn install(&mut self, ctx: &mut TenantCtx<'_>) {
        // First burst after one idle period from install time.
        self.next = ctx.now() + (self.rng.exp(1.0 / self.mean_idle as f64) as Ns).max(1);
    }

    fn next_wake(&self) -> Option<Ns> {
        Some(self.next)
    }

    fn step(&mut self, now: Ns, ctx: &mut TenantCtx<'_>) {
        self.stats.steps += 1;
        if self.burst_bytes == 0 {
            self.next = now + self.mean_idle.max(1);
            return;
        }
        match self.holding.take() {
            Some(seg) => {
                self.stats.freed_bytes += seg.bytes;
                self.stats.held_bytes -= seg.bytes;
                ctx.free(seg);
                self.next = now + (self.rng.exp(1.0 / self.mean_idle as f64) as Ns).max(1);
            }
            None => {
                match grab(
                    &mut self.stats,
                    ctx,
                    MemoryTier::PeerHbm(self.gpu),
                    self.burst_bytes,
                    self.priority,
                ) {
                    Some(seg) => {
                        ctx.schedule_copy(DeviceId::Host, DeviceId::Gpu(self.gpu), seg.bytes);
                        self.stats.traffic_bytes += seg.bytes;
                        self.holding = Some(seg);
                        self.next =
                            now + (self.rng.exp(1.0 / self.mean_hold as f64) as Ns).max(1);
                    }
                    None => {
                        // denied (best-effort) or genuine OOM: back off
                        self.next =
                            now + (self.rng.exp(1.0 / self.mean_idle as f64) as Ns).max(1);
                    }
                }
            }
        }
    }

    fn stats(&self) -> ActorStats {
        self.stats
    }
}

/// Replay mode: the pre-generated [`TenantLoad`] timeline behind the
/// same trait. Installing it registers the timeline on the node —
/// exactly what pre-fleet code did with
/// [`crate::memsim::SimNode::set_tenant_load`] — and the actor then
/// stays passive, so runs reproduce PR-≤4 pressure sequences
/// bit-for-bit.
pub struct ReplayActor {
    label: String,
    gpu: usize,
    load: Option<TenantLoad>,
    stats: ActorStats,
}

impl ReplayActor {
    /// Replay `load` on GPU `gpu`. The timeline's capacity must match
    /// the GPU's HBM capacity (asserted at install).
    pub fn new(label: impl Into<String>, gpu: usize, load: TenantLoad) -> Self {
        Self { label: label.into(), gpu, load: Some(load), stats: ActorStats::default() }
    }
}

impl TenantActor for ReplayActor {
    fn label(&self) -> &str {
        &self.label
    }

    fn install(&mut self, ctx: &mut TenantCtx<'_>) {
        let load = self.load.take().expect("replay actor installs once");
        ctx.hr.node.set_tenant_load(self.gpu, load);
    }

    fn next_wake(&self) -> Option<Ns> {
        // The timeline drives pressure on its own: `advance_to` already
        // enforces at each of its change points. No steps needed.
        None
    }

    fn step(&mut self, _now: Ns, _ctx: &mut TenantCtx<'_>) {}

    fn stats(&self) -> ActorStats {
        self.stats
    }
}
