//! Closed-loop co-tenant workloads — the adversary Harvest harvests
//! *from*.
//!
//! The paper's premise (§2.1) is that co-tenants leave GPU memory idle
//! in bursts; everything before this module modelled them as a
//! pre-generated scalar timeline ([`crate::memsim::TenantLoad`]) that
//! could change a number but never fragment an arena, load a link, or
//! react to Harvest. This module makes tenants **first-class actors on
//! the simulation clock**:
//!
//! ```text
//!            TenantFleet::advance_to(hr, t)
//!   ┌──────────┬─────────────┬────────────┐
//!   │ Training │ Inference   │ Batch      │   TenantActor impls
//!   │ (ring    │ (KV churn,  │ (bursty    │   (+ Replay: the old
//!   │  all-    │  H2D loads) │  hogs)     │    timeline, verbatim)
//!   │  reduce) │             │            │
//!   └────┬─────┴──────┬──────┴─────┬──────┘
//!        │ alloc/free │ collective │ traffic
//!        ▼            ▼            ▼
//!   ┌─────────────────────────────────────┐      alloc fails?
//!   │            PressureBroker           │──► HarvestRuntime::
//!   │  (tenants always win: revoke or     │    yield_to_tenant /
//!   │   demote harvest leases to fit)     │    yield_tier_to_tenant
//!   └────┬────────────────────────────┬───┘
//!        ▼ real segments              ▼ FIFO link traffic
//!   per-GPU / host / CXL arenas   Topology (shared with Harvest DMA)
//! ```
//!
//! * Actors allocate and free **real segments** in the per-GPU HBM
//!   arenas (and the host/CXL arenas), so the harvest controller sees
//!   genuine fragmentation and capacity pressure, and `place_tiered`
//!   sees genuine tier occupancy.
//! * Actors inject their collective / copy traffic onto the **same
//!   [`crate::memsim::Topology`] FIFO links** the DMA engine uses, so a
//!   training job's ring all-reduce measurably queues Harvest's peer
//!   fetches (the §7 NVLink-congestion caveat, now exercised).
//! * The [`PressureBroker`] preserves the paper's correctness
//!   invariant — *tenants always win*: a guaranteed-priority tenant
//!   allocation that does not fit revokes (or, under
//!   `demote_to_host`, demotes) harvest leases until it does.
//! * [`ReplayActor`] wraps the old [`crate::memsim::TenantLoad`]
//!   timeline behind the same [`TenantActor`] trait, bit-for-bit, so
//!   existing benches stay reproducible.
//!
//! The [`TenantFleet`] is stepped from [`crate::server::SimEngine`]'s
//! run loop and from each [`crate::cluster::ClusterNode`] step
//! (per-node fleets → heterogeneous per-node pressure), configured via
//! the `[tenants]` TOML section ([`TenantMix`]).

pub mod actor;
pub mod actors;
pub mod broker;
pub mod fleet;

pub use actor::{ActorStats, TenantActor, TenantCtx, TenantPriority, TenantSegment};
pub use actors::{BatchActor, InferenceActor, ReplayActor, TrainingActor};
pub use broker::{BrokerStats, PressureBroker, TenantOom};
pub use fleet::{FleetStats, TenantFleet, TenantMix};
