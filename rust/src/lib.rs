//! # Harvest — opportunistic peer-to-peer GPU caching for LLM inference
//!
//! Reproduction of *"Harvest: Opportunistic Peer-to-Peer GPU Caching for
//! LLM Inference"* (Gopal & Kaffes, 2026) as a three-layer Rust + JAX +
//! Pallas serving framework.
//!
//! Harvest treats spare HBM on NVLink-connected peer GPUs as a
//! *best-effort, revocable* cache tier for LLM inference state — MoE
//! expert weights and paged KV-cache blocks — replacing slow PCIe
//! host-DRAM fetches with fast peer-to-peer GPU copies. Correctness never
//! depends on the peer tier: every cached object is either backed by an
//! authoritative host copy or is lossy and reconstructible.
//!
//! ## Crate layout
//!
//! | module | role |
//! |---|---|
//! | [`memsim`] | calibrated multi-GPU node simulation: HBM/host/CXL/SSD arenas, NVLink/PCIe/CXL/NVMe interconnect model, inter-node NIC fabric, virtual clock, async DMA, tenant pressure |
//! | [`coldtier`] | the SSD cold tier: fixed-size-page `Pager` over the byte-addressed SSD arena, watermark-driven write-back `Evictor`, and the modeled KV `Compressor` (ratio + decode-side decompression cost) behind the compress → demote → drop pressure ladder |
//! | [`tenantsim`] | closed-loop co-tenant workloads: a `TenantActor` trait (training / inference / batch actors + replay-mode timeline) allocating real arena segments and injecting collective traffic, mediated by a `PressureBroker` that makes harvest leases yield — tenants always win |
//! | [`cluster`] | scale-out serving: N simulated nodes behind a pluggable request router (round-robin / least-loaded / prefix-affinity / harvest-priced), RDMA/Ethernet node fabric, cross-node prefix-KV migration, per-node + aggregate metrics rollups |
//! | [`control`] | SLO control plane: per-node feedback admission (occupancy + tenant pressure + queueing stability, hysteresis watermarks), harvest-priced router scoring, `[slo]` targets tracked by a sliding `SloMonitor` |
//! | [`harvest`] | the paper's contribution behind a tier-aware lease API: `MemoryTier` + `TierPreference` on every allocation, sessions with RAII `Lease`s that carry their resident tier, vectored all-or-nothing `alloc_many`, pull-model revocation events with `Dropped`/`Demoted` actions, the unified `Transfer` builder (populate/fetch/migrate), cross-tier placement policies (`place_tiered`), deadline-aware prefetch planning (`prefetch`), MIG isolation (the paper's raw `harvest_alloc`/`harvest_free`/`harvest_register_cb` survive as deprecated shims) |
//! | [`moe`] | MoE serving path: Table-1 model registry, routing simulator, expert residency map + rebalancer, CGOPipe-style pipeline |
//! | [`kv`] | paged KV cache: blocks, unified block table, `KvOffloadManager`, per-device `OffloadingHandler`, eviction policies |
//! | [`server`] | serving coordinator: requests, continuous batcher, FCFS + completely-fair schedulers, engine, metrics |
//! | [`obs`] | observability plane: virtual-time span tracer (Chrome/Perfetto export), unified `MetricsRegistry` snapshot tree, wall-clock stepper phase profiler, SLO flight recorder — zero-overhead when off, provably inert to the simulation |
//! | [`runtime`] | PJRT bridge: load AOT `artifacts/*.hlo.txt` (lowered from JAX/Pallas) and execute on the request path |
//! | [`trace`] | Alibaba-gpu-v2020-like cluster trace synthesis (Fig. 2) |
//! | [`config`] | TOML config system + deployment presets |
//! | [`util`] | deterministic RNG, distributions, stats/histograms |
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! request path is pure Rust via the `xla` crate's PJRT CPU client.

pub mod cluster;
pub mod coldtier;
pub mod config;
pub mod control;
pub mod harvest;
pub mod kv;
pub mod memsim;
pub mod moe;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod tenantsim;
pub mod trace;
pub mod util;


