//! MoE serving with Harvest expert offload (paper §4).
//!
//! * [`config`] — the Table-1 model registry (Mixtral-8x7B, Phi-3.5-MoE,
//!   Phi-tiny-MoE, Qwen2-MoE) with architecture-accurate geometry: expert
//!   byte sizes (the Fig. 3 chunk sizes) and per-token FLOP counts (the
//!   Fig. 5/6 compute model).
//! * [`router`] — skewed, drifting expert-activation simulator (§4.2:
//!   "expert access patterns are highly skewed ... this skew is dynamic").
//! * [`residency`] — the expert residency map (§4.3): local HBM / peer
//!   HBM / host DRAM per (layer, expert), with the fall-back order the
//!   rebalancer maintains.
//! * [`rebalancer`] — applies the Harvest API to expert weights: migrates
//!   host-resident experts into peer HBM when capacity appears, and
//!   invalidates residency entries on revocation.
//! * [`pipeline`] — CGOPipe-style micro-batched decode pipeline
//!   (MoE-Lightning's execution strategy, which Harvest extends): expert
//!   weight fetches for micro-batch *i+1* overlap compute for *i*.
//!   The baseline fetches from host over PCIe; Harvest serves hits from
//!   peer HBM over NVLink.

pub mod config;
pub mod pipeline;
pub mod rebalancer;
pub mod residency;
pub mod router;

pub use config::{find_kv_model, find_moe_model, KvModel, MoeModel, KV_MODELS, MOE_MODELS};
pub use pipeline::{CgoPipe, DecodeCostModel, PipelineStats};
pub use rebalancer::ExpertRebalancer;
pub use residency::{ExpertKey, ExpertResidency, ResidencyMap};
pub use router::{RouterSim, RoutingStats};
