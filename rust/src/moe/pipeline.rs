//! CGOPipe-style micro-batched decode pipeline (§4.3–§4.5).
//!
//! MoE-Lightning's CGOPipe partitions the batch into micro-batches and
//! overlaps expert-weight transfers for micro-batch *i+1* with compute
//! for micro-batch *i*; attention runs on the CPU. Harvest does not
//! modify routing, batching, CPU-side attention or pipeline structure —
//! it only adds peer GPUs as a tier for offloaded expert weights (§4.3).
//!
//! [`CgoPipe::decode_pass`] reproduces this: per layer, the distinct
//! experts each micro-batch needs (from the routing simulator) are
//! fetched in order on the appropriate link — peer HBM over NVLink when
//! Harvest has a live cache entry, host DRAM over PCIe otherwise — while
//! the compute timeline advances micro-batch by micro-batch. A
//! micro-batch's FFN cannot start before its experts are resident
//! ("an entire expert's parameters must be resident in GPU memory before
//! its feed-forward computation can execute"). Link FIFO contention and
//! per-transfer base latencies come from `memsim`; compute time comes
//! from [`DecodeCostModel`] (FLOPs on the GPU + calibrated CPU-attention
//! time per token).
//!
//! The paper's evaluation setup (§4.4): µ = 324 tokens, b = 14
//! micro-batches, N = 4,536 tokens per decode step, `--max-new-tokens=32`,
//! prompts drawn MTBench-like, 5 trials with 50-token warmup — all
//! defaults here.

use super::config::MoeModel;
use super::rebalancer::{ExpertRebalancer, FetchSource};
use super::residency::ExpertKey;
use super::router::RouterSim;
use crate::harvest::HarvestRuntime;
use crate::memsim::Ns;

/// Compute-side cost model.
#[derive(Debug, Clone, Copy)]
pub struct DecodeCostModel {
    /// Effective GPU FLOPs/s for decode GEMMs (H100 bf16 ≈ 990 TFLOP/s
    /// peak; decode GEMMs at µ=324 run well below peak MFU).
    pub eff_flops: f64,
    /// Fixed per-micro-batch overhead (kernel launches, CPU↔GPU sync).
    pub per_microbatch_overhead_ns: Ns,
}

impl Default for DecodeCostModel {
    fn default() -> Self {
        Self { eff_flops: 400e12, per_microbatch_overhead_ns: 200_000 }
    }
}

impl DecodeCostModel {
    /// Time for one micro-batch's compute in one layer: CPU attention
    /// (per token) + expert FFN GEMMs (top-k per token) + overhead.
    pub fn microbatch_ns(&self, model: &MoeModel, tokens: usize) -> Ns {
        let attn = model.cpu_attn_ns_per_token * tokens as u64;
        let ffn_flops =
            tokens as f64 * model.top_k as f64 * model.flops_per_token_per_expert();
        let ffn = (ffn_flops / self.eff_flops * 1e9) as Ns;
        attn + ffn + self.per_microbatch_overhead_ns
    }
}

/// Which tier offloaded experts are served from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadTier {
    /// Baseline CGOPipe: host DRAM over PCIe.
    Cpu,
    /// Harvest: peer HBM over NVLink when cached, host fallback.
    Harvest,
}

/// Per-pass statistics.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    pub tokens: u64,
    pub pass_ns: Ns,
    pub compute_ns: Ns,
    /// Time compute sat waiting for expert transfers.
    pub stall_ns: Ns,
    pub fetches_local: u64,
    pub fetches_peer: u64,
    pub fetches_host: u64,
    pub bytes_from_peer: u64,
    pub bytes_from_host: u64,
    /// Experts predictively promoted to peer HBM during this pass (only
    /// non-zero under [`CgoPipe::decode_pass_prefetched`]).
    pub prefetch_promotions: u64,
}

impl PipelineStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.pass_ns == 0 {
            return 0.0;
        }
        self.tokens as f64 / (self.pass_ns as f64 / 1e9)
    }

    pub fn merge(&mut self, other: &PipelineStats) {
        self.tokens += other.tokens;
        self.pass_ns += other.pass_ns;
        self.compute_ns += other.compute_ns;
        self.stall_ns += other.stall_ns;
        self.fetches_local += other.fetches_local;
        self.fetches_peer += other.fetches_peer;
        self.fetches_host += other.fetches_host;
        self.bytes_from_peer += other.bytes_from_peer;
        self.bytes_from_host += other.bytes_from_host;
        self.prefetch_promotions += other.prefetch_promotions;
    }
}

/// The pipeline driver.
pub struct CgoPipe {
    pub model: &'static MoeModel,
    pub micro_batch_tokens: usize,
    pub n_micro_batches: usize,
    pub cost: DecodeCostModel,
}

impl CgoPipe {
    /// Paper defaults: µ=324, b=14 (§4.4).
    pub fn paper_setup(model: &'static MoeModel) -> Self {
        Self {
            model,
            micro_batch_tokens: 324,
            n_micro_batches: 14,
            cost: DecodeCostModel::default(),
        }
    }

    pub fn batch_tokens(&self) -> u64 {
        (self.micro_batch_tokens * self.n_micro_batches) as u64
    }

    /// Run one decode pass (every sequence advances one token). Virtual
    /// time advances to the pass end.
    pub fn decode_pass(
        &self,
        router: &mut RouterSim,
        reb: &mut ExpertRebalancer,
        hr: &mut HarvestRuntime,
        tier: OffloadTier,
    ) -> PipelineStats {
        self.run_pass(router, reb, hr, tier, false)
    }

    /// [`CgoPipe::decode_pass`] plus the predictive prefetch pipeline:
    /// while layer *L*'s micro-batches compute, the rebalancer promotes
    /// the experts the router predicts for layer *L+1* into peer HBM
    /// (host→peer populates, which share no link with the demand expert
    /// fetches), with the predicted start of that layer as the deadline.
    /// Requires the rebalancer to be built
    /// [`ExpertRebalancer::with_prefetch`]; otherwise identical to
    /// [`CgoPipe::decode_pass`].
    pub fn decode_pass_prefetched(
        &self,
        router: &mut RouterSim,
        reb: &mut ExpertRebalancer,
        hr: &mut HarvestRuntime,
        tier: OffloadTier,
    ) -> PipelineStats {
        self.run_pass(router, reb, hr, tier, true)
    }

    fn run_pass(
        &self,
        router: &mut RouterSim,
        reb: &mut ExpertRebalancer,
        hr: &mut HarvestRuntime,
        tier: OffloadTier,
        prefetch: bool,
    ) -> PipelineStats {
        let mut stats = PipelineStats { tokens: self.batch_tokens(), ..Default::default() };
        // Tick boundary: drain revocation events accumulated since the
        // last pass so the whole pass sees one consistent residency view.
        reb.sync(hr);
        let pass_start = hr.node.clock.now();
        let layer_compute_ns = self.cost.microbatch_ns(self.model, self.micro_batch_tokens)
            * self.n_micro_batches as u64;
        let mut compute_cursor = pass_start;
        for layer in 0..self.model.n_layers as usize {
            if prefetch
                && reb.prefetch_enabled()
                && matches!(tier, OffloadTier::Harvest)
                && layer + 1 < self.model.n_layers as usize
            {
                // Predictive promotion for the *next* layer, overlapped
                // with this layer's compute. Deadline: the earliest that
                // layer's first micro-batch can start.
                let next = layer + 1;
                let n_hot = (self.model.n_experts as usize / 4).max(self.model.top_k as usize);
                let keys: Vec<ExpertKey> = router
                    .predict_activations(next, n_hot)
                    .into_iter()
                    .map(|e| ExpertKey { layer: next as u32, expert: e as u32 })
                    .collect();
                let deadline = compute_cursor + layer_compute_ns;
                let promoted = reb.prefetch_experts(hr, &keys, deadline);
                stats.prefetch_promotions += promoted as u64;
            }
            // Routing for the whole layer is known up front (gating runs
            // on the CPU from the previous layer's activations), so
            // transfers for later micro-batches overlap earlier compute —
            // the CGOPipe schedule.
            let needed_sets: Vec<Vec<usize>> = (0..self.n_micro_batches)
                .map(|_| router.route_microbatch(layer, self.micro_batch_tokens))
                .collect();
            for needed in needed_sets {
                // 1. Fetch this micro-batch's non-local experts (async,
                //    FIFO on the link; earliest start = link availability).
                let mut ready_at = compute_cursor;
                for expert in needed {
                    let key = ExpertKey { layer: layer as u32, expert: expert as u32 };
                    let is_local = reb.residency().is_local(key);
                    if is_local {
                        stats.fetches_local += 1;
                        continue;
                    }
                    let (src, ev) = match tier {
                        OffloadTier::Harvest => reb.fetch_expert(hr, key),
                        OffloadTier::Cpu => {
                            // Baseline: always serve offloaded experts
                            // from host DRAM over PCIe — through the
                            // rebalancer's host-tier staging lease, so
                            // even baseline traffic is monitor-visible.
                            let ev = reb.fetch_expert_host(hr, key);
                            (FetchSource::Host, Some(ev))
                        }
                    };
                    match src {
                        FetchSource::Local => stats.fetches_local += 1,
                        FetchSource::Peer => {
                            stats.fetches_peer += 1;
                            stats.bytes_from_peer += self.model.expert_bytes();
                        }
                        FetchSource::Host => {
                            stats.fetches_host += 1;
                            stats.bytes_from_host += self.model.expert_bytes();
                        }
                    }
                    if let Some(ev) = ev {
                        ready_at = ready_at.max(ev.end);
                    }
                }
                // 2. Compute waits for residency, then runs.
                let c = self.cost.microbatch_ns(self.model, self.micro_batch_tokens);
                let start = compute_cursor.max(ready_at);
                stats.stall_ns += start - compute_cursor;
                stats.compute_ns += c;
                compute_cursor = start + c;
            }
        }
        hr.node.clock.advance_to(compute_cursor);
        stats.pass_ns = compute_cursor - pass_start;
        stats
    }

    /// Run `n_passes` decode passes and merge the stats (the paper
    /// averages 5 trials of 32 new tokens after a 50-token warmup).
    pub fn decode_many(
        &self,
        router: &mut RouterSim,
        reb: &mut ExpertRebalancer,
        hr: &mut HarvestRuntime,
        tier: OffloadTier,
        n_passes: usize,
    ) -> PipelineStats {
        let mut total = PipelineStats::default();
        for _ in 0..n_passes {
            let s = self.decode_pass(router, reb, hr, tier);
            total.merge(&s);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::HarvestConfig;
    use crate::memsim::{NodeSpec, SimNode};
    use crate::moe::config::find_moe_model;

    fn setup(
        name: &str,
        offload: f64,
    ) -> (CgoPipe, RouterSim, ExpertRebalancer, HarvestRuntime) {
        let model = find_moe_model(name).unwrap();
        let hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let pipe = CgoPipe::paper_setup(model);
        let router = RouterSim::new(model, model.n_layers as usize, 7);
        let reb = ExpertRebalancer::new(model, 0, offload);
        (pipe, router, reb, hr)
    }

    #[test]
    fn no_offload_has_no_transfers() {
        let (pipe, mut router, mut reb, mut hr) = setup("qwen", 0.0);
        let s = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
        assert_eq!(s.fetches_host + s.fetches_peer, 0);
        assert_eq!(s.stall_ns, 0);
        assert_eq!(s.tokens, 4536);
        assert!(s.pass_ns > 0);
    }

    #[test]
    fn harvest_beats_cpu_offload_at_50pct() {
        for name in ["mixtral", "phi-3.5"] {
            let (pipe, mut router, mut reb, mut hr) = setup(name, 0.5);
            reb.rebalance(&mut hr, usize::MAX);
            let h = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
            let c = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Cpu);
            assert!(
                h.tokens_per_sec() > c.tokens_per_sec(),
                "{name}: harvest {:.0} <= cpu {:.0}",
                h.tokens_per_sec(),
                c.tokens_per_sec()
            );
            assert!(h.fetches_peer > 0);
            assert_eq!(c.fetches_peer, 0);
        }
    }

    #[test]
    fn fig5_improvement_band() {
        // Fig. 5: improvements range from ~48% to over 110% at 50%
        // offload; allow a generous band on the simulator.
        let (pipe, mut router, mut reb, mut hr) = setup("phi-3.5", 0.5);
        reb.rebalance(&mut hr, usize::MAX);
        let h = pipe.decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Harvest, 3);
        let c = pipe.decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Cpu, 3);
        let improvement = h.tokens_per_sec() / c.tokens_per_sec();
        assert!(
            (1.3..=3.0).contains(&improvement),
            "phi-3.5 improvement {improvement:.2} out of band"
        );
    }

    #[test]
    fn stall_time_reflects_transfer_bound_baseline() {
        let (pipe, mut router, mut reb, mut hr) = setup("mixtral", 1.0);
        let c = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Cpu);
        assert!(c.stall_ns > 0, "full CPU offload must stall");
        let (pipe, mut router, mut reb, mut hr) = setup("mixtral", 0.0);
        let l = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Cpu);
        assert_eq!(l.stall_ns, 0, "fully local never stalls");
    }

    #[test]
    fn pass_advances_virtual_clock() {
        let (pipe, mut router, mut reb, mut hr) = setup("phi-tiny", 0.0);
        let t0 = hr.node.clock.now();
        let s = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
        assert_eq!(hr.node.clock.now(), t0 + s.pass_ns);
    }

    #[test]
    fn throughput_in_plausible_absolute_range() {
        // Calibration sanity: Qwen2 baseline (0% offload) should land in
        // the several-hundred-to-low-thousands tok/s range like the
        // paper's ~975 tok/s (absolute numbers are calibrated, not
        // measured — see EXPERIMENTS.md).
        let (pipe, mut router, mut reb, mut hr) = setup("qwen", 0.0);
        let s = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Cpu);
        let tps = s.tokens_per_sec();
        assert!((300.0..4000.0).contains(&tps), "qwen baseline {tps:.0} tok/s");
    }

    #[test]
    fn prefetched_pass_promotes_predicted_experts_and_serves_from_peer() {
        let model = find_moe_model("phi-tiny").unwrap();
        let hr_new = || {
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
        };
        // Everything starts host-resident; no upfront rebalance. The
        // prefetched pass must promote predicted-hot experts on its own.
        let pipe = CgoPipe::paper_setup(model);
        let mut hr = hr_new();
        let mut router = RouterSim::new(model, model.n_layers as usize, 7);
        let mut reb = ExpertRebalancer::new(model, 0, 1.0)
            .with_prefetch(crate::harvest::PrefetchConfig::default());
        let p = pipe.decode_pass_prefetched(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
        assert!(p.prefetch_promotions > 0, "predictive promotion must happen");
        assert!(p.fetches_peer > 0, "promoted experts serve later layers from peer");
        let pf = reb.prefetch_stats().unwrap();
        assert!(pf.issued >= p.prefetch_promotions);
        assert!(pf.hits > 0, "{pf:?}");

        // And it beats the plain (reactive, host-only) pass.
        let mut hr2 = hr_new();
        let mut router2 = RouterSim::new(model, model.n_layers as usize, 7);
        let mut reb2 = ExpertRebalancer::new(model, 0, 1.0);
        let plain = pipe.decode_pass(&mut router2, &mut reb2, &mut hr2, OffloadTier::Harvest);
        assert_eq!(plain.fetches_peer, 0, "no promotion without prefetch");
        assert!(
            p.fetches_host < plain.fetches_host,
            "prefetch {} host fetches !< plain {}",
            p.fetches_host,
            plain.fetches_host
        );
    }

    #[test]
    fn prefetched_pass_without_planner_matches_plain_pass() {
        let (pipe, mut router, mut reb, mut hr) = setup("phi-tiny", 0.5);
        let a = pipe.decode_pass_prefetched(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
        assert_eq!(a.prefetch_promotions, 0, "no planner, no promotions");
        let (pipe2, mut router2, mut reb2, mut hr2) = setup("phi-tiny", 0.5);
        let b = pipe2.decode_pass(&mut router2, &mut reb2, &mut hr2, OffloadTier::Harvest);
        assert_eq!(a.pass_ns, b.pass_ns, "identical without a planner");
        assert_eq!(a.fetches_host, b.fetches_host);
    }

    #[test]
    fn merge_accumulates() {
        let (pipe, mut router, mut reb, mut hr) = setup("phi-tiny", 0.25);
        let a = pipe.decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Cpu, 2);
        assert_eq!(a.tokens, 2 * 4536);
    }
}
