//! Model registry: the paper's evaluated architectures.
//!
//! Table 1 of the paper:
//!
//! | Model        | Params (B) | Active (B) | Experts | Active Exp. |
//! |--------------|-----------|------------|---------|-------------|
//! | Mixtral-8x7B | 47.0      | 13.0       | 8       | 2           |
//! | Phi-3.5-MoE  | 60.8      | 6.6        | 16      | 2           |
//! | Phi-tiny-MoE | 3.8       | 1.1        | 16      | 2           |
//! | Qwen2-MoE    | 14.3      | 2.7        | 64      | 4           |
//!
//! Geometries below are taken from the public model cards where
//! available and otherwise estimated to match the Table-1 parameter
//! counts; expert byte sizes derived from them are the chunk sizes of
//! Fig. 3 and the transfer costs of Figs. 5/6. The KV-cache models of
//! §5.3 (DeepSeek-V3, Mistral-Large-3-675B, Kimi-K2) appear as
//! [`KvModel`]s with per-token KV byte footprints for Fig. 7.

/// FP16 bytes per parameter.
pub const FP16: u64 = 2;

/// An MoE architecture, with everything the simulators need.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MoeModel {
    pub name: &'static str,
    pub total_params_b: f64,
    pub active_params_b: f64,
    pub n_layers: u64,
    pub n_experts: u64,
    /// Experts activated per token (top-k).
    pub top_k: u64,
    pub d_model: u64,
    /// Per-expert FFN hidden size.
    pub d_ff_expert: u64,
    /// Routing skew exponent observed for this family (higher = more
    /// reuse; Phi-3.5's fewer experts + small fan-out give it higher
    /// temporal locality than Qwen2 — §4.5's explanation for Fig. 5).
    pub routing_zipf_s: f64,
    /// Calibrated CPU-side attention + framework time per token per layer
    /// (ns) in the MoE-Lightning execution model (attention runs on the
    /// CPU; see §4.3). Fit so the CGOPipe pipeline reproduces the paper's
    /// Fig. 5 per-model improvement band on this simulator — DESIGN.md
    /// §Calibration.
    pub cpu_attn_ns_per_token: u64,
}

impl MoeModel {
    /// FP16 bytes of ONE expert in ONE layer (3 matrices: gate/up/down —
    /// SwiGLU FFN). This is the Fig. 3 chunk size for this model.
    pub fn expert_bytes(&self) -> u64 {
        3 * self.d_model * self.d_ff_expert * FP16
    }

    /// Total expert bytes across all layers and experts.
    pub fn total_expert_bytes(&self) -> u64 {
        self.n_layers * self.n_experts * self.expert_bytes()
    }

    /// FLOPs to run one token through one expert's FFN (3 matmuls,
    /// multiply-add = 2 FLOPs).
    pub fn flops_per_token_per_expert(&self) -> f64 {
        2.0 * 3.0 * (self.d_model * self.d_ff_expert) as f64
    }

    /// FLOPs per token per layer for the non-expert (attention + router)
    /// part at decode. Approximation: 4 dense d×d projections.
    pub fn attn_flops_per_token(&self) -> f64 {
        2.0 * 4.0 * (self.d_model * self.d_model) as f64
    }

    /// Total decode FLOPs per token (all layers, top-k experts active).
    pub fn decode_flops_per_token(&self) -> f64 {
        self.n_layers as f64
            * (self.attn_flops_per_token()
                + self.top_k as f64 * self.flops_per_token_per_expert())
    }
}

/// Table-1 registry, in the paper's row order.
pub const MOE_MODELS: &[MoeModel] = &[
    // Mixtral-8x7B: d=4096, d_ff=14336, 32 layers (public config).
    MoeModel {
        name: "Mixtral-8x7B",
        total_params_b: 47.0,
        active_params_b: 13.0,
        n_layers: 32,
        n_experts: 8,
        top_k: 2,
        d_model: 4096,
        d_ff_expert: 14336,
        routing_zipf_s: 1.0,
        cpu_attn_ns_per_token: 52300,
    },
    // Phi-3.5-MoE: d=4096, d_ff=6400, 32 layers (public config).
    MoeModel {
        name: "Phi-3.5-MoE",
        total_params_b: 60.8,
        active_params_b: 6.6,
        n_layers: 32,
        n_experts: 16,
        top_k: 2,
        d_model: 4096,
        d_ff_expert: 6400,
        routing_zipf_s: 1.25,
        cpu_attn_ns_per_token: 28800,
    },
    // Phi-tiny-MoE: geometry estimated to hit 3.8B total / 1.1B active.
    MoeModel {
        name: "Phi-tiny-MoE",
        total_params_b: 3.8,
        active_params_b: 1.1,
        n_layers: 24,
        n_experts: 16,
        top_k: 2,
        d_model: 1024,
        d_ff_expert: 2816,
        routing_zipf_s: 1.25,
        cpu_attn_ns_per_token: 4100,
    },
    // Qwen2-MoE (Qwen1.5-MoE-A2.7B lineage): d=2048, 64 fine-grained
    // experts of d_ff=1408, top-4, 24 layers.
    MoeModel {
        name: "Qwen2-MoE",
        total_params_b: 14.3,
        active_params_b: 2.7,
        n_layers: 24,
        n_experts: 64,
        top_k: 4,
        d_model: 2048,
        d_ff_expert: 1408,
        routing_zipf_s: 0.8,
        cpu_attn_ns_per_token: 17600,
    },
];

/// Look up a Table-1 model by (case-insensitive prefix of) name.
pub fn find_moe_model(name: &str) -> Option<&'static MoeModel> {
    let needle = name.to_ascii_lowercase();
    MOE_MODELS.iter().find(|m| m.name.to_ascii_lowercase().starts_with(&needle))
}

/// A model evaluated in the KV-offload study (§5.3 / Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvModel {
    pub name: &'static str,
    pub n_layers: u64,
    /// KV bytes appended per token per layer at FP16.
    pub kv_bytes_per_token_per_layer: u64,
    /// Active parameters (B) — drives the recompute cost model (§5.1).
    pub active_params_b: f64,
}

impl KvModel {
    /// KV bytes per token across all layers (the per-"KV cache entry"
    /// footprint of §5.3).
    pub fn kv_bytes_per_token(&self) -> u64 {
        self.n_layers * self.kv_bytes_per_token_per_layer
    }
}

/// §5.3 registry.
///
/// * DeepSeek-V3 and Kimi-K2 use multi-head latent attention (MLA): the
///   compressed KV is 512 + 64 (rope) dims per layer → 576 × 2 B.
/// * Mistral-Large-3-675B (2026) has no public card on this image;
///   estimated as GQA with 8 KV heads × 128 dims over 96 layers —
///   DESIGN.md records the substitution.
pub const KV_MODELS: &[KvModel] = &[
    KvModel {
        name: "DeepSeek-V3",
        n_layers: 61,
        kv_bytes_per_token_per_layer: 576 * FP16,
        active_params_b: 37.0,
    },
    KvModel {
        name: "Mistral-Large-3-675B",
        n_layers: 96,
        kv_bytes_per_token_per_layer: 8 * 128 * 2 * FP16,
        active_params_b: 41.0, // MoE active-parameter estimate (no card)
    },
    KvModel {
        name: "Kimi-K2",
        n_layers: 61,
        kv_bytes_per_token_per_layer: 576 * FP16,
        active_params_b: 32.0,
    },
];

pub fn find_kv_model(name: &str) -> Option<&'static KvModel> {
    let needle = name.to_ascii_lowercase();
    KV_MODELS.iter().find(|m| m.name.to_ascii_lowercase().starts_with(&needle))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: u64 = 1 << 20;

    #[test]
    fn registry_matches_table1() {
        assert_eq!(MOE_MODELS.len(), 4);
        let mixtral = find_moe_model("mixtral").unwrap();
        assert_eq!(mixtral.n_experts, 8);
        assert_eq!(mixtral.top_k, 2);
        let qwen = find_moe_model("qwen").unwrap();
        assert_eq!(qwen.n_experts, 64);
        assert_eq!(qwen.top_k, 4);
        let phi = find_moe_model("phi-3.5").unwrap();
        assert_eq!(phi.n_experts, 16);
    }

    #[test]
    fn expert_bytes_span_fig3_range() {
        // Fig. 3 maps chunk sizes to expert sizes: Phi-tiny smallest,
        // Mixtral largest (~20x ratio).
        let tiny = find_moe_model("phi-tiny").unwrap().expert_bytes();
        let mixtral = find_moe_model("mixtral").unwrap().expert_bytes();
        assert!(tiny > 10 * MIB && tiny < 25 * MIB, "tiny={}", tiny / MIB);
        assert!(mixtral > 300 * MIB && mixtral < 400 * MIB, "mixtral={}", mixtral / MIB);
    }

    #[test]
    fn expert_param_totals_consistent_with_table1() {
        // Expert params must be most of (but less than) total params.
        for m in MOE_MODELS {
            let expert_params =
                (m.total_expert_bytes() / FP16) as f64 / 1e9;
            assert!(
                expert_params < m.total_params_b,
                "{}: experts {expert_params:.1}B >= total {}B",
                m.name,
                m.total_params_b
            );
            assert!(
                expert_params > 0.6 * m.total_params_b,
                "{}: experts {expert_params:.1}B too small vs total {}B",
                m.name,
                m.total_params_b
            );
        }
    }

    #[test]
    fn active_flops_ordering_matches_active_params() {
        // Models with more active params must cost more FLOPs per token.
        let by = |n: &str| find_moe_model(n).unwrap();
        assert!(by("mixtral").decode_flops_per_token() > by("phi-3.5").decode_flops_per_token());
        assert!(by("phi-3.5").decode_flops_per_token() > by("qwen").decode_flops_per_token());
        assert!(by("qwen").decode_flops_per_token() > by("phi-tiny").decode_flops_per_token());
    }

    #[test]
    fn kv_models_present_with_sane_footprints() {
        assert_eq!(KV_MODELS.len(), 3);
        let dsv3 = find_kv_model("deepseek").unwrap();
        // MLA: ~70 KB/token
        let per_tok = dsv3.kv_bytes_per_token();
        assert!((60_000..90_000).contains(&per_tok), "{per_tok}");
        let mistral = find_kv_model("mistral").unwrap();
        assert!(mistral.kv_bytes_per_token() > dsv3.kv_bytes_per_token());
    }
}
