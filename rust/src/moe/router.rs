//! Expert-routing simulator (§4.2).
//!
//! Expert access is "highly skewed and exhibits temporal locality:
//! certain experts are frequently activated, while others remain unused.
//! Crucially, this skew is dynamic" — hotspots shift as query mix drifts.
//!
//! [`RouterSim`] models exactly that: per layer, token routing follows a
//! Zipf(s) popularity law over a *permutation* of the experts; the
//! permutation drifts over time (random adjacent swaps every
//! `drift_interval` tokens), shifting hotspots unpredictably while
//! preserving the marginal skew. For the tiny end-to-end model the real
//! gating output from the PJRT runtime is used instead — this simulator
//! covers the paper-scale models whose weights don't exist here.

use crate::moe::config::MoeModel;
use crate::util::rng::{Rng, Zipf};

/// Aggregate routing statistics over a window.
#[derive(Debug, Clone, Default)]
pub struct RoutingStats {
    pub tokens: u64,
    /// Activation count per expert (layer-summed).
    pub activations: Vec<u64>,
}

impl RoutingStats {
    /// Fraction of activations landing on the top `n` experts.
    pub fn top_n_share(&self, n: usize) -> f64 {
        let mut counts = self.activations.clone();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        counts.iter().take(n).sum::<u64>() as f64 / total as f64
    }
}

/// Per-layer drifting-Zipf router.
#[derive(Debug, Clone)]
pub struct RouterSim {
    n_experts: usize,
    top_k: usize,
    zipf: Zipf,
    /// rank -> expert id, per layer.
    perms: Vec<Vec<usize>>,
    drift_interval: u64,
    tokens_since_drift: u64,
    rng: Rng,
    pub stats: RoutingStats,
}

impl RouterSim {
    pub fn new(model: &MoeModel, n_layers_simulated: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let n = model.n_experts as usize;
        let perms = (0..n_layers_simulated).map(|_| rng.permutation(n)).collect();
        Self {
            n_experts: n,
            top_k: model.top_k as usize,
            zipf: Zipf::new(n, model.routing_zipf_s),
            perms,
            drift_interval: 4096,
            tokens_since_drift: 0,
            rng,
            stats: RoutingStats { tokens: 0, activations: vec![0; n] },
        }
    }

    pub fn with_drift_interval(mut self, tokens: u64) -> Self {
        self.drift_interval = tokens.max(1);
        self
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn top_k(&self) -> usize {
        self.top_k
    }

    /// Route one token at `layer`: distinct top-k expert ids.
    pub fn route_token(&mut self, layer: usize) -> Vec<usize> {
        let mut picked = Vec::with_capacity(self.top_k);
        self.route_token_into(layer, &mut picked);
        picked
    }

    /// Allocation-free variant: clears `picked` and fills it with the
    /// token's distinct top-k experts (the `route_microbatch` hot path —
    /// see EXPERIMENTS.md §Perf).
    pub fn route_token_into(&mut self, layer: usize, picked: &mut Vec<usize>) {
        picked.clear();
        // Rejection-sample distinct ranks, then map through the drifting
        // permutation.
        let mut guard = 0;
        while picked.len() < self.top_k {
            let rank = self.zipf.sample(&mut self.rng);
            let expert = self.perms[layer][rank];
            if !picked.contains(&expert) {
                picked.push(expert);
            }
            guard += 1;
            if guard > 1000 {
                // Pathological skew: fill with the first unused experts.
                for e in self.perms[layer].iter() {
                    if picked.len() == self.top_k {
                        break;
                    }
                    if !picked.contains(e) {
                        picked.push(*e);
                    }
                }
            }
        }
        for &e in picked.iter() {
            self.stats.activations[e] += 1;
        }
        self.stats.tokens += 1;
        self.tokens_since_drift += 1;
        if self.tokens_since_drift >= self.drift_interval {
            self.drift();
            self.tokens_since_drift = 0;
        }
    }

    /// Route a micro-batch of `n_tokens` at `layer`; returns the set of
    /// *distinct* experts activated (what must be resident before the
    /// expert FFN can run — CGOPipe pages at expert granularity).
    pub fn route_microbatch(&mut self, layer: usize, n_tokens: usize) -> Vec<usize> {
        let mut needed = vec![false; self.n_experts];
        let mut scratch = Vec::with_capacity(self.top_k);
        for _ in 0..n_tokens {
            self.route_token_into(layer, &mut scratch);
            for &e in &scratch {
                needed[e] = true;
            }
        }
        (0..self.n_experts).filter(|&e| needed[e]).collect()
    }

    /// Predict the `n` experts most likely to activate at `layer` under
    /// the *current* drifted permutation: Zipf mass decreases with rank,
    /// so rank order *is* the probability order. Pure prediction — no
    /// sampling, no drift, no stats — making it safe for the prefetch
    /// pipeline ([`crate::harvest::prefetch`]) to consult mid-pass: the
    /// expert rebalancer promotes these to peer HBM ahead of the layer
    /// that needs them. Mispredictions (drift between prediction and
    /// use) cost wasted prefetch bandwidth, never correctness.
    pub fn predict_activations(&self, layer: usize, n: usize) -> Vec<usize> {
        self.perms[layer].iter().copied().take(n.min(self.n_experts)).collect()
    }

    /// Shift hotspots: a few adjacent swaps in each layer's permutation
    /// (gradual drift, as observed across query-mix changes).
    fn drift(&mut self) {
        for layer in 0..self.perms.len() {
            for _ in 0..(self.n_experts / 8).max(1) {
                let i = self.rng.below(self.n_experts as u64 - 1) as usize;
                self.perms[layer].swap(i, i + 1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::config::find_moe_model;

    #[test]
    fn routes_are_distinct_and_in_range() {
        let m = find_moe_model("qwen").unwrap();
        let mut r = RouterSim::new(m, 4, 1);
        for _ in 0..200 {
            let picks = r.route_token(0);
            assert_eq!(picks.len(), 4);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 4, "distinct experts");
            assert!(picks.iter().all(|&e| e < 64));
        }
    }

    #[test]
    fn skew_is_visible() {
        let m = find_moe_model("phi-3.5").unwrap();
        let mut r = RouterSim::new(m, 1, 2);
        for _ in 0..20_000 {
            r.route_token(0);
        }
        // top-4 of 16 experts should take well over the uniform 25% share
        let share = r.stats.top_n_share(4);
        assert!(share > 0.5, "share={share}");
    }

    #[test]
    fn qwen_larger_working_set_than_phi() {
        // §4.5: "Qwen2-MoE activates a larger number of distinct experts
        // per token, increasing expert working-set churn."
        let route_distinct = |name: &str, tokens: usize| {
            let m = find_moe_model(name).unwrap();
            let mut r = RouterSim::new(m, 1, 3);
            r.route_microbatch(0, tokens).len()
        };
        let phi = route_distinct("phi-3.5", 324);
        let qwen = route_distinct("qwen", 324);
        assert!(qwen > 2 * phi, "qwen working set {qwen} vs phi {phi}");
        // And per-activation concentration is higher for Phi (zipf skew).
        let share = |name: &str| {
            let m = find_moe_model(name).unwrap();
            let mut r = RouterSim::new(m, 1, 3);
            for _ in 0..5_000 {
                r.route_token(0);
            }
            r.stats.top_n_share((m.n_experts / 4) as usize)
        };
        assert!(share("phi-3.5") > share("qwen"));
    }

    #[test]
    fn drift_changes_hotspots() {
        let m = find_moe_model("phi-3.5").unwrap();
        let mut r = RouterSim::new(m, 1, 4).with_drift_interval(100);
        let before = r.perms[0].clone();
        for _ in 0..1_000 {
            r.route_token(0);
        }
        assert_ne!(before, r.perms[0], "permutation drifted");
    }

    #[test]
    fn microbatch_needed_set_reasonable() {
        let m = find_moe_model("mixtral").unwrap();
        let mut r = RouterSim::new(m, 1, 5);
        let needed = r.route_microbatch(0, 324);
        // 324 tokens x top-2 of 8 experts: all or nearly all experts hit
        assert!(needed.len() >= 6, "needed={needed:?}");
        assert!(needed.windows(2).all(|w| w[0] < w[1]), "sorted distinct");
    }

    #[test]
    fn predicted_hot_experts_capture_actual_skew() {
        let m = find_moe_model("phi-3.5").unwrap();
        let mut r = RouterSim::new(m, 1, 11).with_drift_interval(1_000_000); // no drift
        let predicted: Vec<usize> = r.predict_activations(0, 4);
        assert_eq!(predicted.len(), 4);
        for _ in 0..5_000 {
            r.route_token(0);
        }
        // The predicted top-4 of 16 experts must take far more than the
        // uniform 25% share of actual activations.
        let total: u64 = r.stats.activations.iter().sum();
        let hot: u64 = predicted.iter().map(|&e| r.stats.activations[e]).sum();
        let share = hot as f64 / total as f64;
        assert!(share > 0.4, "predicted-hot share {share:.2} barely beats uniform");
    }

    #[test]
    fn predict_activations_is_pure_and_bounded() {
        let m = find_moe_model("mixtral").unwrap();
        let r = RouterSim::new(m, 2, 3);
        let a = r.predict_activations(1, 100);
        assert_eq!(a.len(), 8, "clamped to n_experts");
        assert_eq!(a, r.predict_activations(1, 100), "pure");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8, "a permutation prefix has no duplicates");
    }

    #[test]
    fn deterministic_for_seed() {
        let m = find_moe_model("mixtral").unwrap();
        let mut a = RouterSim::new(m, 2, 9);
        let mut b = RouterSim::new(m, 2, 9);
        for l in [0usize, 1, 0] {
            assert_eq!(a.route_microbatch(l, 32), b.route_microbatch(l, 32));
        }
    }
}
