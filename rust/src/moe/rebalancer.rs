//! The Expert Rebalancer (§4.3) — applies the Harvest API to MoE weights.
//!
//! "At server start, a user-defined subset of experts is loaded into
//! local HBM, while the remaining experts reside in host DRAM. As peer
//! memory becomes available, the rebalancer allocates peer GPU memory
//! and migrates selected expert weights into peer HBM. ... If a peer
//! allocation is revoked, the rebalancer invalidates the corresponding
//! residency entry, and future invocations automatically fall back to
//! pinned host DRAM."
//!
//! Tiered edition: the "pinned host DRAM" the paper assumes is itself a
//! first-class tier now — offloaded expert weights live in **host-tier
//! staging leases** (`TierPreference::Pinned(Host)`, allocated lazily on
//! first use), so every host fetch is a lease-addressed `Transfer` the
//! `PeerMonitor` sees, exactly like peer fetches. Peer promotion
//! allocates with `TierPreference::PEER_ONLY` (promoting expert weights
//! to a *slower* tier would be a pessimisation, so the preference says
//! so).
//!
//! Revocations arrive as pull-model events on the rebalancer's
//! [`HarvestSession`]; [`ExpertRebalancer::sync`] drains them at tick
//! boundaries (pipeline pass start, rebalance rounds, fetches) and
//! repairs the residency map. Expert leases are host-backed, so the
//! controller never demotes them — a `Demoted` event is handled
//! defensively by releasing the (now redundant) host-tier copy.

use super::config::MoeModel;
use super::residency::{ExpertKey, ExpertResidency, ResidencyMap};
use crate::harvest::api::{AllocHints, Durability, LeaseId, MemoryTier, TierPreference};
use crate::harvest::events::RevocationAction;
use crate::harvest::prefetch::{PrefetchConfig, PrefetchPlanner, PrefetchStats};
use crate::harvest::session::{HarvestSession, Lease, Transfer};
use crate::harvest::{HarvestRuntime, PayloadKind};
use crate::memsim::{CopyEvent, DeviceId, Ns};
use std::collections::BTreeMap;

/// Where an expert fetch was served from (metrics / Fig. 5 attribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchSource {
    Local,
    Peer,
    Host,
}

/// The rebalancer. Owns the residency map, the peer leases backing every
/// peer-cached expert, and the host-tier staging leases backing the
/// offloaded working set.
pub struct ExpertRebalancer {
    pub model: &'static MoeModel,
    map: ResidencyMap,
    compute_gpu: usize,
    session: Option<HarvestSession>,
    /// Live peer leases; the map's `PeerHbm` entries mirror this exactly.
    leases: BTreeMap<LeaseId, Lease>,
    /// Host-tier staging leases for offloaded experts, allocated lazily
    /// at first fetch (the weights were loaded at server start; staging
    /// allocation itself moves no bytes). These make host traffic
    /// monitor-visible and host capacity accountable.
    staging: BTreeMap<ExpertKey, Lease>,
    /// Deadline-aware predictive promotion (enabled via
    /// [`ExpertRebalancer::with_prefetch`]).
    planner: Option<PrefetchPlanner>,
    /// Leases created by predictive prefetch: lease → (deadline, used?).
    /// First use settles the planner ledger against the *deadline* (the
    /// pipeline tracks compute on a cursor ahead of the virtual clock,
    /// so clock-now would misread every promotion as late); revocation
    /// before first use is waste.
    prefetched: BTreeMap<LeaseId, (Ns, bool)>,
    /// Cumulative migration/fetch statistics.
    pub migrations: u64,
    pub migration_failures: u64,
    revocations_observed: u64,
}

impl ExpertRebalancer {
    /// `offload_fraction` of each layer's experts start host-resident
    /// (the Fig. 6 x-axis); the rest are pinned in local HBM.
    pub fn new(model: &'static MoeModel, compute_gpu: usize, offload_fraction: f64) -> Self {
        let n_local = ((1.0 - offload_fraction.clamp(0.0, 1.0)) * model.n_experts as f64).round()
            as u32;
        let map = ResidencyMap::init(model.n_layers as u32, model.n_experts as u32, n_local);
        Self {
            model,
            map,
            compute_gpu,
            session: None,
            leases: BTreeMap::new(),
            staging: BTreeMap::new(),
            planner: None,
            prefetched: BTreeMap::new(),
            migrations: 0,
            migration_failures: 0,
            revocations_observed: 0,
        }
    }

    /// Enable deadline-aware predictive promotion: the pipeline can then
    /// call [`ExpertRebalancer::prefetch_experts`] with the router's
    /// predicted activations.
    pub fn with_prefetch(mut self, cfg: PrefetchConfig) -> Self {
        self.planner = Some(PrefetchPlanner::new(cfg));
        self
    }

    pub fn prefetch_enabled(&self) -> bool {
        self.planner.is_some()
    }

    /// The prefetch outcome ledger (None when prefetch is disabled).
    pub fn prefetch_stats(&self) -> Option<&PrefetchStats> {
        self.planner.as_ref().map(|p| p.stats())
    }

    pub fn residency(&self) -> &ResidencyMap {
        &self.map
    }

    pub fn compute_gpu(&self) -> usize {
        self.compute_gpu
    }

    /// Peer revocations observed via the event queue so far.
    pub fn revocations_observed(&self) -> u64 {
        self.revocations_observed
    }

    fn session(&mut self, hr: &mut HarvestRuntime) -> HarvestSession {
        *self
            .session
            .get_or_insert_with(|| HarvestSession::open(hr, PayloadKind::ExpertWeights))
    }

    fn peer_hints(&self) -> AllocHints {
        AllocHints {
            compute_gpu: Some(self.compute_gpu),
            durability: Durability::HostBacked,
            ..Default::default()
        }
    }

    /// Drain pending revocation events and invalidate the corresponding
    /// residency entries (fall back to pinned host DRAM). Called by
    /// every entry point; the pipeline also calls it once per decode
    /// pass so the whole tick sees one consistent residency view.
    pub fn sync(&mut self, hr: &mut HarvestRuntime) {
        let Some(session) = self.session else { return };
        for ev in session.drain_revocations(hr) {
            match ev.action {
                RevocationAction::Dropped => {
                    self.leases.remove(&ev.lease);
                }
                RevocationAction::Demoted { .. } => {
                    // Expert leases are host-backed, so the controller
                    // never demotes them in practice; defensively, a
                    // host-tier copy of a pinned-host expert is redundant
                    // — release it and fall back like a drop.
                    if let Some(lease) = self.leases.remove(&ev.lease) {
                        let _ = session.release(hr, lease);
                    }
                }
            }
            self.map.invalidate_handle(ev.lease);
            self.revocations_observed += 1;
            if self.prefetched.remove(&ev.lease).is_some() {
                // A predictively promoted expert revoked (whether or not
                // it ever served a fetch); if it never did, the planner
                // still holds its in-flight entry and counts the waste.
                if let Some(p) = self.planner.as_mut() {
                    p.mark_canceled(ev.lease.0);
                }
            }
        }
    }

    /// Migrate up to `max_migrations` host-resident experts into peer HBM
    /// (host → peer populates; the host copy stays authoritative).
    /// Returns how many were promoted. Stops at the first capacity
    /// rejection.
    pub fn rebalance(&mut self, hr: &mut HarvestRuntime, max_migrations: usize) -> usize {
        self.sync(hr);
        let candidates: Vec<ExpertKey> =
            self.map.host_resident().take(max_migrations).collect();
        let session = self.session(hr);
        let hints = self.peer_hints();
        let mut promoted = 0;
        for key in candidates {
            let lease = match session.alloc(
                hr,
                self.model.expert_bytes(),
                TierPreference::PEER_ONLY,
                hints,
            ) {
                Ok(l) => l,
                Err(_) => {
                    self.migration_failures += 1;
                    break; // peers full: stop this round
                }
            };
            // Populate the cache: host -> peer (stays off the critical
            // path; CGOPipe compute continues meanwhile).
            Transfer::new()
                .populate(&lease, DeviceId::Host)
                .submit(hr)
                .expect("fresh lease");
            let peer = lease.peer().expect("peer-only preference");
            let ok = self.map.promote_to_peer(key, lease.id(), peer);
            debug_assert!(ok);
            self.leases.insert(lease.id(), lease);
            promoted += 1;
            self.migrations += 1;
        }
        promoted
    }

    /// Predictively promote `predicted` experts (the router's
    /// [`crate::moe::router::RouterSim::predict_activations`]) from host
    /// DRAM into peer HBM, deadline-aware: each host→peer populate is a
    /// background transfer that must complete by `deadline` (the
    /// predicted start of the layer that needs them) and yields instead
    /// of queueing behind demand traffic. Unlike
    /// [`ExpertRebalancer::rebalance`], which promotes host-resident
    /// experts in index order, this promotes exactly what the router
    /// expects to fire — predictive, not reactive. Returns how many
    /// were promoted.
    pub fn prefetch_experts(
        &mut self,
        hr: &mut HarvestRuntime,
        predicted: &[ExpertKey],
        deadline: Ns,
    ) -> usize {
        self.sync(hr);
        if self.planner.is_none() {
            return 0;
        }
        let bytes = self.model.expert_bytes();
        let session = self.session(hr);
        let hints = self.peer_hints();
        let mut promoted = 0;
        for &key in predicted {
            if !matches!(self.map.get(key), ExpertResidency::Host) {
                continue; // local or already peer-cached
            }
            // The placement policy picks the peer, which determines the
            // populate link — so allocate first, then ask the planner.
            let Ok(lease) = session.alloc(hr, bytes, TierPreference::PEER_ONLY, hints) else {
                self.migration_failures += 1;
                break; // peers full: stop this round
            };
            let peer = lease.peer().expect("peer-only preference");
            let (src, dst) = (DeviceId::Host, DeviceId::Gpu(peer));
            // Contiguous populate (expert weights are one segment).
            let admitted = self
                .planner
                .as_mut()
                .unwrap()
                .admit(&hr.node.topo, src, dst, bytes, None, deadline);
            if !admitted {
                // Busy link or unmeetable deadline on *this* peer's
                // populate link: undo the allocation and try the next
                // predicted expert — the policy may place it on another
                // peer whose link is idle.
                let _ = session.release(hr, lease);
                continue;
            }
            let report = Transfer::new()
                .background()
                .populate(&lease, DeviceId::Host)
                .submit(hr)
                .expect("fresh lease");
            let ok = self.map.promote_to_peer(key, lease.id(), peer);
            debug_assert!(ok);
            let planner = self.planner.as_mut().unwrap();
            planner.record_issued(lease.id().0, bytes, report.end, deadline);
            planner.mark_link_busy(src, dst, report.end);
            self.prefetched.insert(lease.id(), (deadline, false));
            self.leases.insert(lease.id(), lease);
            promoted += 1;
            self.migrations += 1;
        }
        promoted
    }

    /// Serve an expert from its host-tier staging lease (the §4.3
    /// fallback path, and the CGOPipe host-offload baseline). The
    /// staging lease is allocated on first use — pinning the weights'
    /// host DRAM in the harvest accounting — and the fetch is a
    /// lease-addressed PCIe copy the monitor records as host demand.
    pub fn fetch_expert_host(&mut self, hr: &mut HarvestRuntime, key: ExpertKey) -> CopyEvent {
        let session = self.session(hr);
        let bytes = self.model.expert_bytes();
        if !self.staging.contains_key(&key) {
            let hints = AllocHints {
                compute_gpu: Some(self.compute_gpu),
                durability: Durability::HostBacked,
                ..Default::default()
            };
            let lease = session
                .alloc(hr, bytes, TierPreference::Pinned(MemoryTier::Host), hints)
                .expect("host DRAM holds the offloaded working set");
            self.staging.insert(key, lease);
        }
        let lease = self.staging.get(&key).expect("just ensured");
        let report = Transfer::new()
            .fetch(lease, self.compute_gpu)
            .submit(hr)
            .expect("host staging leases are never revoked");
        report.events[0]
    }

    /// Serve one expert for the FFN of `key` on the compute GPU. Returns
    /// the tier it came from and the async copy event (None for local).
    ///
    /// Upon a miss the runtime does **not** automatically fetch the
    /// weights to peer HBM (§4.3) — host misses go straight to the
    /// compute GPU over PCIe, exactly like the CGOPipe baseline.
    pub fn fetch_expert(
        &mut self,
        hr: &mut HarvestRuntime,
        key: ExpertKey,
    ) -> (FetchSource, Option<CopyEvent>) {
        self.sync(hr);
        let residency = self.map.get(key);
        match residency {
            ExpertResidency::LocalHbm => (FetchSource::Local, None),
            ExpertResidency::PeerHbm { handle, .. } => {
                // Post-sync a PeerHbm entry should always have a live
                // lease; a failed submit means a revocation raced in
                // anyway, so invalidate and fall back to host.
                let served = self.leases.get(&handle).and_then(|lease| {
                    Transfer::new().fetch(lease, self.compute_gpu).submit(hr).ok()
                });
                match served {
                    Some(report) => {
                        // First use of a predictively promoted expert:
                        // settle the prefetch ledger — a hit if the
                        // populate completed by the deadline it was
                        // promised for.
                        if let Some((deadline, used)) = self.prefetched.get_mut(&handle) {
                            if !*used {
                                *used = true;
                                let deadline = *deadline;
                                if let Some(p) = self.planner.as_mut() {
                                    p.mark_used(handle.0, deadline);
                                }
                            }
                        }
                        (FetchSource::Peer, Some(report.events[0]))
                    }
                    None => {
                        self.leases.remove(&handle);
                        self.map.invalidate_handle(handle);
                        // Mirror the sync path: a predictively promoted
                        // expert dying here must settle the planner's
                        // in-flight entry as waste, or it would occupy a
                        // max_inflight slot forever.
                        if self.prefetched.remove(&handle).is_some() {
                            if let Some(p) = self.planner.as_mut() {
                                p.mark_canceled(handle.0);
                            }
                        }
                        let ev = self.fetch_expert_host(hr, key);
                        (FetchSource::Host, Some(ev))
                    }
                }
            }
            ExpertResidency::Host => {
                let ev = self.fetch_expert_host(hr, key);
                (FetchSource::Host, Some(ev))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::{HarvestConfig, RevocationReason};
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::{NodeSpec, SimNode};
    use crate::moe::config::find_moe_model;

    const GIB: u64 = 1 << 30;

    fn runtime() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    #[test]
    fn rebalance_promotes_host_experts() {
        let mut hr = runtime();
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 0.5);
        let (_l0, p0, h0) = reb.residency().counts();
        assert_eq!(p0, 0);
        let promoted = reb.rebalance(&mut hr, 16);
        assert_eq!(promoted, 16);
        let (_l, p, h) = reb.residency().counts();
        assert_eq!(p, 16);
        assert_eq!(h, h0 - 16);
        reb.residency().check_invariants().unwrap();
        // bytes actually landed on the peer
        assert_eq!(hr.live_bytes_on(1), 16 * model.expert_bytes());
    }

    #[test]
    fn rebalance_stops_at_capacity() {
        let mut hr = runtime();
        // Peer almost full: only ~2 Mixtral experts (352 MiB each) fit.
        hr.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 79 * GIB));
        let model = find_moe_model("mixtral").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        let promoted = reb.rebalance(&mut hr, 64);
        assert!(promoted >= 1 && promoted <= 3, "promoted={promoted}");
        assert_eq!(reb.migration_failures, 1);
    }

    #[test]
    fn fetch_tiers_and_sources() {
        let mut hr = runtime();
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 0.5);
        reb.rebalance(&mut hr, 4);
        // expert 0 is local (offload 0.5 -> experts 0..8 local)
        let (src, ev) = reb.fetch_expert(&mut hr, ExpertKey { layer: 0, expert: 0 });
        assert_eq!(src, FetchSource::Local);
        assert!(ev.is_none());
        // expert 8 was promoted to peer by the first rebalance round
        let (src, ev) = reb.fetch_expert(&mut hr, ExpertKey { layer: 0, expert: 8 });
        assert_eq!(src, FetchSource::Peer);
        let ev = ev.unwrap();
        assert_eq!(ev.src, DeviceId::Gpu(1));
        // expert 15 of layer 23 is still host resident
        let (src, ev) = reb.fetch_expert(&mut hr, ExpertKey { layer: 23, expert: 15 });
        assert_eq!(src, FetchSource::Host);
        assert_eq!(ev.unwrap().src, DeviceId::Host);
    }

    #[test]
    fn host_fetches_are_staged_leases_and_monitored() {
        let mut hr = runtime();
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        let key = ExpertKey { layer: 0, expert: 3 };
        let (src, _) = reb.fetch_expert(&mut hr, key);
        assert_eq!(src, FetchSource::Host);
        // the staging lease pins the host bytes in harvest accounting
        assert_eq!(hr.live_bytes_on_tier(MemoryTier::Host), model.expert_bytes());
        // and the PCIe fetch is demand traffic on the host tier slot
        assert_eq!(
            hr.monitor().demand_bytes_on_tier(MemoryTier::Host),
            model.expert_bytes()
        );
        // a second fetch reuses the staging lease (no second allocation)
        let (_, _) = reb.fetch_expert(&mut hr, key);
        assert_eq!(hr.live_bytes_on_tier(MemoryTier::Host), model.expert_bytes());
        assert_eq!(
            hr.monitor().demand_bytes_on_tier(MemoryTier::Host),
            2 * model.expert_bytes()
        );
    }

    #[test]
    fn peer_fetch_faster_than_host_fetch() {
        let mut hr = runtime();
        let model = find_moe_model("mixtral").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        reb.rebalance(&mut hr, 1);
        let (_, peer_ev) =
            reb.fetch_expert(&mut hr, ExpertKey { layer: 0, expert: 0 });
        let (_, host_ev) =
            reb.fetch_expert(&mut hr, ExpertKey { layer: 0, expert: 1 });
        let p = peer_ev.unwrap().duration();
        let h = host_ev.unwrap().duration();
        let ratio = h as f64 / p as f64;
        assert!(ratio > 7.0, "expected Fig.3-band speedup, got {ratio}");
    }

    #[test]
    fn revocation_invalidates_residency_and_falls_back() {
        let mut hr = runtime();
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        reb.rebalance(&mut hr, 8);
        let (_, p, _) = reb.residency().counts();
        assert_eq!(p, 8);
        // revoke everything on the peer; the events become visible at the
        // next sync (here explicit, normally the pass-start drain)
        hr.revoke_peer(1, RevocationReason::TenantPressure);
        reb.sync(&mut hr);
        assert_eq!(reb.revocations_observed(), 8);
        let (_, p, h) = reb.residency().counts();
        assert_eq!(p, 0);
        assert_eq!(h as u64, model.n_layers * model.n_experts);
        reb.residency().check_invariants().unwrap();
        // fetches now come from host
        let (src, _) = reb.fetch_expert(&mut hr, ExpertKey { layer: 0, expert: 0 });
        assert_eq!(src, FetchSource::Host);
    }

    #[test]
    fn fetch_syncs_implicitly_after_revocation() {
        let mut hr = runtime();
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        reb.rebalance(&mut hr, 4);
        hr.revoke_peer(1, RevocationReason::ExternalReclaim);
        // no explicit sync: fetch_expert drains first, so it must not
        // try the dead peer entry
        let (src, _) = reb.fetch_expert(&mut hr, ExpertKey { layer: 0, expert: 0 });
        assert_eq!(src, FetchSource::Host);
        assert_eq!(reb.revocations_observed(), 4);
        reb.residency().check_invariants().unwrap();
    }

    #[test]
    fn prefetch_promotes_exactly_the_predicted_experts() {
        let mut hr = runtime();
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0)
            .with_prefetch(crate::harvest::PrefetchConfig::default());
        let predicted = [
            ExpertKey { layer: 3, expert: 5 },
            ExpertKey { layer: 3, expert: 9 },
            ExpertKey { layer: 7, expert: 1 },
        ];
        let deadline = hr.node.clock.now() + 100_000_000;
        let promoted = reb.prefetch_experts(&mut hr, &predicted, deadline);
        assert_eq!(promoted, 3);
        for key in predicted {
            assert!(
                matches!(reb.residency().get(key), ExpertResidency::PeerHbm { .. }),
                "{key:?} not promoted"
            );
        }
        // prediction-driven: nothing else moved
        assert_eq!(reb.residency().counts().1, 3);
        assert_eq!(reb.prefetch_stats().unwrap().issued, 3);
        // first fetch settles the ledger as a hit once the populate is done
        hr.advance_to(deadline);
        let (src, _) = reb.fetch_expert(&mut hr, predicted[0]);
        assert_eq!(src, FetchSource::Peer);
        assert_eq!(reb.prefetch_stats().unwrap().hits, 1);
        reb.residency().check_invariants().unwrap();
    }

    #[test]
    fn prefetch_yields_to_busy_populate_link() {
        let mut hr = runtime();
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0)
            .with_prefetch(crate::harvest::PrefetchConfig::default());
        // demand traffic owns the host->peer link
        hr.node.copy(DeviceId::Host, DeviceId::Gpu(1), 1 << 30, None);
        let predicted = [ExpertKey { layer: 0, expert: 0 }];
        let promoted = reb.prefetch_experts(&mut hr, &predicted, u64::MAX);
        assert_eq!(promoted, 0, "must yield to demand traffic");
        assert_eq!(reb.prefetch_stats().unwrap().yielded, 1);
        assert_eq!(hr.live_bytes_on(1), 0, "yielded prefetch leaves no allocation behind");
        reb.residency().check_invariants().unwrap();
    }

    #[test]
    fn revoked_unused_prefetch_counts_as_waste() {
        let mut hr = runtime();
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0)
            .with_prefetch(crate::harvest::PrefetchConfig::default());
        let predicted = [ExpertKey { layer: 0, expert: 0 }, ExpertKey { layer: 0, expert: 1 }];
        reb.prefetch_experts(&mut hr, &predicted, hr.node.clock.now() + 100_000_000);
        hr.revoke_peer(1, RevocationReason::TenantPressure);
        reb.sync(&mut hr);
        let pf = reb.prefetch_stats().unwrap();
        assert_eq!(pf.wasted, 2, "never-used promotions revoked -> waste");
        assert_eq!(pf.bytes_wasted, 2 * model.expert_bytes());
        assert_eq!(reb.residency().counts().1, 0);
        // fallback is host, as for any revocation
        let (src, _) = reb.fetch_expert(&mut hr, predicted[0]);
        assert_eq!(src, FetchSource::Host);
        reb.residency().check_invariants().unwrap();
    }

    #[test]
    fn tenant_pressure_mid_run_revokes_and_rebalancer_recovers() {
        let mut hr = runtime();
        hr.node.set_tenant_load(
            1,
            TenantLoad::from_steps(
                80 * GIB,
                vec![(0, 0), (1_000_000, 80 * GIB), (2_000_000, 10 * GIB)],
            ),
        );
        let model = find_moe_model("phi-tiny").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        reb.rebalance(&mut hr, 32);
        assert_eq!(reb.residency().counts().1, 32);
        // pressure spike revokes everything
        hr.advance_to(1_500_000);
        reb.sync(&mut hr);
        assert_eq!(reb.residency().counts().1, 0);
        // pressure clears; rebalancer re-promotes
        hr.advance_to(2_500_000);
        let promoted = reb.rebalance(&mut hr, 8);
        assert_eq!(promoted, 8);
        assert_eq!(reb.residency().counts().1, 8);
    }
}
