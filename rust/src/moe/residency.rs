//! Expert residency map (§4.3).
//!
//! "Expert placement is tracked using an expert residency map that
//! records, for each expert, whether it resides in local HBM, peer HBM,
//! or host DRAM." Peer entries are a *cache* layered over the host copy
//! (experts are [`Durability::HostBacked`]); local entries are pinned at
//! server start. On revocation the rebalancer invalidates the peer entry
//! and lookups fall back to pinned host DRAM automatically.

use crate::harvest::api::LeaseId;
use std::collections::BTreeMap;

/// (layer, expert) key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ExpertKey {
    pub layer: u32,
    pub expert: u32,
}

/// Where an expert's weights can be served from, fastest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpertResidency {
    /// Pinned in the compute GPU's HBM — no transfer needed.
    LocalHbm,
    /// Cached in peer HBM under a live harvest handle (host copy remains
    /// authoritative).
    PeerHbm { handle: LeaseId, peer: usize },
    /// Host DRAM only (the authoritative copy).
    Host,
}

/// The map. Every expert always has an implicit authoritative host copy;
/// this structure tracks the *fastest currently valid* tier.
#[derive(Debug, Clone, Default)]
pub struct ResidencyMap {
    entries: BTreeMap<ExpertKey, ExpertResidency>,
    /// Reverse index: harvest handle -> expert (for revocation callbacks).
    by_handle: BTreeMap<LeaseId, ExpertKey>,
}

impl ResidencyMap {
    pub fn new() -> Self {
        Self::default()
    }

    /// Initialise all experts of a model: the first `n_local` experts of
    /// every layer pinned locally (a user-defined subset per §4.3), the
    /// rest host-resident.
    pub fn init(n_layers: u32, n_experts: u32, n_local: u32) -> Self {
        let mut m = Self::new();
        for layer in 0..n_layers {
            for expert in 0..n_experts {
                let key = ExpertKey { layer, expert };
                let res =
                    if expert < n_local { ExpertResidency::LocalHbm } else { ExpertResidency::Host };
                m.entries.insert(key, res);
            }
        }
        m
    }

    pub fn get(&self, key: ExpertKey) -> ExpertResidency {
        self.entries.get(&key).copied().unwrap_or(ExpertResidency::Host)
    }

    pub fn is_local(&self, key: ExpertKey) -> bool {
        matches!(self.get(key), ExpertResidency::LocalHbm)
    }

    /// Promote a host-resident expert into the peer cache. Local experts
    /// are never demoted to peer (that would be a slowdown).
    pub fn promote_to_peer(&mut self, key: ExpertKey, handle: LeaseId, peer: usize) -> bool {
        match self.get(key) {
            ExpertResidency::Host => {
                self.entries.insert(key, ExpertResidency::PeerHbm { handle, peer });
                self.by_handle.insert(handle, key);
                true
            }
            _ => false,
        }
    }

    /// Invalidate the peer entry for `handle` (revocation callback path);
    /// the expert falls back to host. Returns the expert, if any.
    pub fn invalidate_handle(&mut self, handle: LeaseId) -> Option<ExpertKey> {
        let key = self.by_handle.remove(&handle)?;
        debug_assert!(matches!(self.get(key), ExpertResidency::PeerHbm { .. }));
        self.entries.insert(key, ExpertResidency::Host);
        Some(key)
    }

    /// All experts currently cached on a peer.
    pub fn peer_cached(&self) -> impl Iterator<Item = (ExpertKey, LeaseId, usize)> + '_ {
        self.entries.iter().filter_map(|(&k, &r)| match r {
            ExpertResidency::PeerHbm { handle, peer } => Some((k, handle, peer)),
            _ => None,
        })
    }

    /// Experts currently host-resident (candidates for promotion).
    pub fn host_resident(&self) -> impl Iterator<Item = ExpertKey> + '_ {
        self.entries.iter().filter_map(|(&k, &r)| match r {
            ExpertResidency::Host => Some(k),
            _ => None,
        })
    }

    pub fn counts(&self) -> (usize, usize, usize) {
        let mut local = 0;
        let mut peer = 0;
        let mut host = 0;
        for r in self.entries.values() {
            match r {
                ExpertResidency::LocalHbm => local += 1,
                ExpertResidency::PeerHbm { .. } => peer += 1,
                ExpertResidency::Host => host += 1,
            }
        }
        (local, peer, host)
    }

    /// Consistency: every by_handle entry points at a PeerHbm entry with
    /// the same handle, and vice versa. Property-tested.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (&h, &k) in &self.by_handle {
            match self.get(k) {
                ExpertResidency::PeerHbm { handle, .. } if handle == h => {}
                other => return Err(format!("by_handle {h:?} -> {k:?} but entry is {other:?}")),
            }
        }
        for (&k, &r) in &self.entries {
            if let ExpertResidency::PeerHbm { handle, .. } = r {
                if self.by_handle.get(&handle) != Some(&k) {
                    return Err(format!("peer entry {k:?} missing reverse index"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(layer: u32, expert: u32) -> ExpertKey {
        ExpertKey { layer, expert }
    }

    #[test]
    fn init_splits_local_and_host() {
        let m = ResidencyMap::init(2, 8, 3);
        let (local, peer, host) = m.counts();
        assert_eq!((local, peer, host), (6, 0, 10));
        assert!(m.is_local(key(0, 0)));
        assert!(m.is_local(key(1, 2)));
        assert_eq!(m.get(key(0, 3)), ExpertResidency::Host);
    }

    #[test]
    fn promote_and_invalidate_roundtrip() {
        let mut m = ResidencyMap::init(1, 4, 1);
        let h = LeaseId(42);
        assert!(m.promote_to_peer(key(0, 2), h, 1));
        assert_eq!(m.get(key(0, 2)), ExpertResidency::PeerHbm { handle: h, peer: 1 });
        m.check_invariants().unwrap();
        assert_eq!(m.invalidate_handle(h), Some(key(0, 2)));
        assert_eq!(m.get(key(0, 2)), ExpertResidency::Host);
        m.check_invariants().unwrap();
        // second invalidation is a no-op
        assert_eq!(m.invalidate_handle(h), None);
    }

    #[test]
    fn local_experts_never_promoted() {
        let mut m = ResidencyMap::init(1, 4, 2);
        assert!(!m.promote_to_peer(key(0, 0), LeaseId(1), 1));
        assert!(m.is_local(key(0, 0)));
    }

    #[test]
    fn double_promotion_rejected() {
        let mut m = ResidencyMap::init(1, 4, 0);
        assert!(m.promote_to_peer(key(0, 1), LeaseId(1), 1));
        assert!(!m.promote_to_peer(key(0, 1), LeaseId(2), 1), "already peer-cached");
        m.check_invariants().unwrap();
    }

    #[test]
    fn iterators_enumerate_tiers() {
        let mut m = ResidencyMap::init(1, 4, 1);
        m.promote_to_peer(key(0, 1), LeaseId(9), 1);
        let cached: Vec<_> = m.peer_cached().collect();
        assert_eq!(cached, vec![(key(0, 1), LeaseId(9), 1)]);
        let host: Vec<_> = m.host_resident().collect();
        assert_eq!(host, vec![key(0, 2), key(0, 3)]);
    }
}
