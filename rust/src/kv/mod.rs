//! Paged KV cache with Harvest offload (paper §5).
//!
//! Extends a vLLM-style paged KV manager with the paper's §5.2 design:
//!
//! * [`block`] — logical KV blocks (fixed token granularity) + metadata.
//! * [`block_table`] — the *unified KV block table* mapping logical block
//!   ids to their current residency across local HBM, peer GPU memory,
//!   or host DRAM (plus `Dropped` for lossy-revoked blocks awaiting
//!   recomputation).
//! * [`eviction`] — pluggable eviction policies (LRU/FIFO/LFU) and the
//!   §8 sliding-window policy switcher that monitors hit rate and
//!   hot-swaps policies.
//! * [`manager`] — the `KvOffloadManager`: decides when blocks are
//!   offloaded/reloaded/evicted, and the per-device `OffloadingHandler`
//!   that executes the data movement (scattered DMA batched into ~4 MiB
//!   descriptors).
//! * [`recompute`] — the recompute-vs-fetch decision (§5.1: "it can be
//!   more efficient to recompute the KV cache instead of fetching it").
//!
//! Unlike MoE weights, KV state is treated as **lossy** on the peer tier
//! (§5.2): revocation drops the block and the table entry falls to
//! `Dropped`; the next access recomputes it (or reloads from host if the
//! block was host-materialised at eviction time).

pub mod block;
pub mod block_table;
pub mod eviction;
pub mod manager;
pub mod recompute;

pub use block::{BlockId, KvBlockMeta, SeqId};
pub use block_table::{BlockResidency, UnifiedBlockTable};
pub use eviction::{EvictionPolicy, Fifo, Lfu, Lru, PolicySwitcher};
pub use manager::{KvConfig, KvOffloadManager, KvStats, OffloadingHandler, PlannedPrefetch};
pub use recompute::RecomputeModel;
