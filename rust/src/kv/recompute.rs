//! Recompute-vs-fetch (§5.1: "In extreme cases, it can be more efficient
//! to recompute the KV cache instead of fetching it from the slow path
//! after offloading" — the KVPR observation).
//!
//! Recomputing a dropped block means re-running prefill for its tokens:
//! cost ≈ 2 × active-params FLOPs per token. The decision compares that
//! against the estimated transfer latency of the candidate tier.

use crate::memsim::Ns;

/// Cost model for KV recomputation.
#[derive(Debug, Clone, Copy)]
pub struct RecomputeModel {
    /// Active parameters of the serving model (decode path), in units of
    /// parameters (not billions).
    pub active_params: f64,
    /// Effective prefill FLOPs/s (prefill GEMMs batch well; higher MFU
    /// than decode).
    pub eff_flops: f64,
}

impl RecomputeModel {
    pub fn new(active_params_b: f64) -> Self {
        Self { active_params: active_params_b * 1e9, eff_flops: 600e12 }
    }

    /// Time to recompute KV for `tokens` tokens (forward pass ≈ 2 FLOPs
    /// per parameter per token).
    pub fn recompute_ns(&self, tokens: u64) -> Ns {
        let flops = 2.0 * self.active_params * tokens as f64;
        (flops / self.eff_flops * 1e9) as Ns
    }

    /// §5.2: "triggering a fallback to host DRAM or recomputation when
    /// more efficient". True if recomputing `tokens` beats a transfer
    /// estimated at `fetch_ns`.
    pub fn prefer_recompute(&self, tokens: u64, fetch_ns: Ns) -> bool {
        self.recompute_ns(tokens) < fetch_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::interconnect::LinkModel;

    #[test]
    fn recompute_scales_with_tokens() {
        let m = RecomputeModel::new(37.0); // DeepSeek-V3-class active
        assert!(m.recompute_ns(32) > m.recompute_ns(16));
        // 1 token ≈ 2*37e9/600e12 s ≈ 123 µs
        let one = m.recompute_ns(1);
        assert!((100_000..150_000).contains(&one), "{one}");
    }

    #[test]
    fn small_blocks_prefer_recompute_over_pcie_only_when_cheap() {
        let m = RecomputeModel::new(2.7); // Qwen2-MoE-class active
        let pcie = LinkModel::pcie5_host();
        // a 16-token block of a small model: recompute ~144µs
        let fetch = pcie.latency(16 * 70_000); // ~1.1 MB block
        assert!(m.prefer_recompute(16, fetch) == (m.recompute_ns(16) < fetch));
        // huge fetches always lose to recompute for small models
        assert!(m.prefer_recompute(16, pcie.latency(1 << 30)));
    }

    #[test]
    fn fetch_preferred_for_big_models_fast_links() {
        let m = RecomputeModel::new(675.0); // Mistral-Large-3-class
        let nv = LinkModel::nvlink_h100();
        let fetch = nv.latency(16 * 393_216);
        assert!(!m.prefer_recompute(16, fetch), "NVLink fetch beats recomputing 675B model");
    }
}
