//! `KvOffloadManager` + per-device `OffloadingHandler` (§5.2).
//!
//! "We introduce a KVOffloadManager into vLLM's KV manager, which serves
//! as a pluggable control interface for implementing Harvest's
//! policy-driven allocation, migration, and revocation semantics. ...
//! For each device, Harvest extends vLLM with an OffloadingHandler
//! responsible for executing data movement operations."
//!
//! Flow:
//! * Decode appends tokens; full local pool ⇒ the eviction policy picks
//!   victims and the handler migrates them out — to peer HBM via a
//!   vectored `alloc_many` lease when available (Harvest mode), else to
//!   host DRAM (vanilla-vLLM mode). Multi-block admission is
//!   all-or-nothing: one policy consultation per batch, and a partial
//!   placement failure rolls back to the host path for the whole batch.
//! * Decode touching a non-local block issues a reload through the
//!   handler: peer → NVLink, host → PCIe, `Dropped` → recompute (or
//!   whichever is cheaper per [`RecomputeModel`]).
//! * Peer revocations arrive as pull-model events: every public entry
//!   point first drains the manager's session queue ([`KvOffloadManager::sync`])
//!   and drops lossy blocks via the unified table — the §5.2 callback
//!   semantics without any shared mutable state (the pre-lease design
//!   needed reference-counted interior mutability so push callbacks
//!   could reach the table from inside the runtime).

use super::block::{BlockId, SeqId};
use super::block_table::{BlockResidency, UnifiedBlockTable};
use super::eviction::{EvictionPolicy, Lru};
use super::recompute::RecomputeModel;
use crate::harvest::api::{AllocHints, Durability, LeaseId};
use crate::harvest::session::{HarvestSession, Lease, Transfer};
use crate::harvest::{HarvestRuntime, PayloadKind};
use crate::memsim::{DeviceId, Ns};
use crate::moe::config::KvModel;
use std::collections::BTreeMap;

/// DMA descriptor granularity for KV reloads: blocks are batched into
/// chunks of this size (scattered block copies cannot use one huge
/// contiguous DMA; ~4 MiB descriptors reproduce the Fig. 7 GPU:CPU
/// latency ratio band — see DESIGN.md §Calibration).
pub const RELOAD_CHUNK_BYTES: u64 = 4 * 1024 * 1024;

/// Configuration of the KV offload manager.
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    pub model: &'static KvModel,
    /// Tokens per logical block (vLLM default 16).
    pub block_tokens: u32,
    /// Local KV pool capacity, in blocks.
    pub local_capacity_blocks: usize,
    /// Harvest mode: evict to peer HBM when possible. Off = vanilla vLLM
    /// (evict to host only) — the Fig. 7 baseline.
    pub use_harvest: bool,
    /// Also materialise a host copy when evicting to peer (durable mode;
    /// default off — §5.2 treats peer KV as lossy).
    pub host_backed_peer: bool,
}

impl KvConfig {
    pub fn block_bytes(&self) -> u64 {
        self.block_tokens as u64 * self.model.kv_bytes_per_token()
    }
}

/// Cumulative statistics.
#[derive(Debug, Clone, Default)]
pub struct KvStats {
    pub appends: u64,
    pub local_hits: u64,
    pub peer_reloads: u64,
    pub host_reloads: u64,
    pub recomputes: u64,
    pub evictions_to_peer: u64,
    pub evictions_to_host: u64,
    pub peer_alloc_failures: u64,
    pub revocation_drops: u64,
    pub bytes_from_peer: u64,
    pub bytes_from_host: u64,
    pub reload_ns: Ns,
    pub recompute_ns: Ns,
}

impl KvStats {
    pub fn reloads(&self) -> u64 {
        self.peer_reloads + self.host_reloads + self.recomputes
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.local_hits + self.reloads();
        if total == 0 {
            0.0
        } else {
            self.local_hits as f64 / total as f64
        }
    }
}

/// Executes data movement for one device pair (§5.2). Thin by design:
/// policy lives in the manager; the handler only knows how to move KV
/// bytes (batched into [`RELOAD_CHUNK_BYTES`] descriptors through the
/// unified [`Transfer`] builder).
#[derive(Debug, Clone, Copy)]
pub struct OffloadingHandler {
    pub compute_gpu: usize,
}

impl OffloadingHandler {
    /// Transfer `bytes` of KV between tiers; returns the copy event.
    pub fn transfer(
        &self,
        hr: &mut HarvestRuntime,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
    ) -> crate::memsim::CopyEvent {
        let report = Transfer::new()
            .chunked(RELOAD_CHUNK_BYTES)
            .raw(src, dst, bytes)
            .submit(hr)
            .expect("raw transfers cannot go stale");
        report.events[0]
    }
}

/// The manager. Owns its block table and eviction policy directly — the
/// pull-model event API needs no shared state with the runtime.
pub struct KvOffloadManager {
    pub cfg: KvConfig,
    table: UnifiedBlockTable,
    policy: Box<dyn EvictionPolicy>,
    handler: OffloadingHandler,
    recompute: RecomputeModel,
    /// Session opened lazily on first runtime interaction (the manager
    /// is constructed before it ever sees the runtime).
    session: Option<HarvestSession>,
    /// Live peer leases, keyed by id; the table's `Peer` entries mirror
    /// this map exactly.
    leases: BTreeMap<LeaseId, Lease>,
    pub stats: KvStats,
}

impl KvOffloadManager {
    pub fn new(cfg: KvConfig, compute_gpu: usize) -> Self {
        Self::with_policy(cfg, compute_gpu, Box::new(Lru::new()))
    }

    pub fn with_policy(
        cfg: KvConfig,
        compute_gpu: usize,
        policy: Box<dyn EvictionPolicy>,
    ) -> Self {
        Self {
            cfg,
            table: UnifiedBlockTable::new(),
            policy,
            handler: OffloadingHandler { compute_gpu },
            recompute: RecomputeModel::new(cfg.model.active_params_b),
            session: None,
            leases: BTreeMap::new(),
            stats: KvStats::default(),
        }
    }

    pub fn table(&self) -> &UnifiedBlockTable {
        &self.table
    }

    pub fn local_blocks(&self) -> usize {
        self.policy.len()
    }

    fn session(&mut self, hr: &mut HarvestRuntime) -> HarvestSession {
        *self
            .session
            .get_or_insert_with(|| HarvestSession::open(hr, PayloadKind::KvBlock))
    }

    /// Drain pending revocation events and repair the block table: the
    /// tick-boundary pull that replaces the old push callbacks. Every
    /// public entry point calls this first, so the manager's view is
    /// current before it makes placement decisions; tests and engines
    /// may also call it directly after advancing virtual time.
    pub fn sync(&mut self, hr: &mut HarvestRuntime) {
        let Some(session) = self.session else { return };
        for ev in session.drain_revocations(hr) {
            // The runtime already drained DMA, invalidated the placement
            // and freed the bytes; we only repair our own indexes.
            self.leases.remove(&ev.lease);
            self.stats.revocation_drops += 1;
            if ev.durability == Durability::HostBacked {
                // A host copy exists: fall back to it.
                if let Some(b) = self.table.drop_by_handle(ev.lease) {
                    self.table.set_residency(b, BlockResidency::Host);
                }
            } else {
                self.table.drop_by_handle(ev.lease);
            }
        }
    }

    /// Append one token to `seq`, paging in a new block when the last one
    /// fills. May evict under pressure. Returns the block written.
    pub fn append_token(&mut self, hr: &mut HarvestRuntime, seq: SeqId) -> BlockId {
        self.sync(hr);
        self.stats.appends += 1;
        let now = hr.node.clock.now();
        let last = self.table.seq_blocks(seq).last().copied().and_then(|id| {
            let m = self.table.meta(id)?;
            (m.tokens < self.cfg.block_tokens).then_some(id)
        });
        let id = match last {
            // The tail block must be local to be appended to.
            Some(id) if self.table.residency(id) == Some(BlockResidency::Local) => id,
            Some(id) => {
                self.ensure_local(hr, id);
                id
            }
            None => {
                self.make_room(hr, 1);
                let id = self.table.new_block(seq, now);
                self.policy.insert(id, now);
                id
            }
        };
        let m = self.table.meta_mut(id).expect("live block");
        m.tokens += 1;
        m.touch(now);
        self.policy.touch(id, now);
        id
    }

    /// Decode touches every block of `seq`: reload anything non-local.
    /// Returns when the sequence is fully resident (virtual time may
    /// advance past reload DMA and recompute).
    pub fn access_seq(&mut self, hr: &mut HarvestRuntime, seq: SeqId) -> Ns {
        self.sync(hr);
        let ids: Vec<BlockId> = self.table.seq_blocks(seq).to_vec();
        let mut ready = hr.node.clock.now();
        for id in ids {
            ready = ready.max(self.access_block(hr, id));
        }
        hr.node.clock.advance_to(ready);
        ready
    }

    /// Touch one block; reload/recompute if non-local. Returns readiness.
    pub fn access_block(&mut self, hr: &mut HarvestRuntime, id: BlockId) -> Ns {
        self.sync(hr);
        let now = hr.node.clock.now();
        let res = self.table.residency(id).expect("live block");
        let ready = match res {
            BlockResidency::Local => {
                self.stats.local_hits += 1;
                now
            }
            _ => self.ensure_local(hr, id),
        };
        self.policy.touch(id, hr.node.clock.now());
        if let Some(m) = self.table.meta_mut(id) {
            m.touch(hr.node.clock.now());
        }
        ready
    }

    /// Bring a block into the local pool (reload or recompute), evicting
    /// to make room first. Returns the readiness time.
    fn ensure_local(&mut self, hr: &mut HarvestRuntime, id: BlockId) -> Ns {
        self.make_room(hr, 1);
        let res = self.table.residency(id).expect("live block");
        let bytes = self.cfg.block_bytes();
        let ready = match res {
            BlockResidency::Local => hr.node.clock.now(),
            BlockResidency::Peer { handle, .. } => {
                // Post-sync, every Peer entry is backed by a live lease.
                let lease = self.leases.remove(&handle).expect("peer block has live lease");
                let session = self.session.expect("lease implies session");
                let report = Transfer::new()
                    .chunked(RELOAD_CHUNK_BYTES)
                    .fetch(&lease, self.handler.compute_gpu)
                    .submit(hr)
                    .expect("live lease");
                // The peer copy is consumed: release the lease (ordered
                // free; drains the fetch we just tagged).
                session.release(hr, lease).expect("live lease");
                self.stats.peer_reloads += 1;
                self.stats.bytes_from_peer += bytes;
                self.stats.reload_ns += report.events[0].duration();
                report.end
            }
            BlockResidency::Host => {
                let ev = self.handler.transfer(
                    hr,
                    DeviceId::Host,
                    DeviceId::Gpu(self.handler.compute_gpu),
                    bytes,
                );
                self.stats.host_reloads += 1;
                self.stats.bytes_from_host += bytes;
                self.stats.reload_ns += ev.duration();
                ev.end
            }
            BlockResidency::Dropped => {
                // Recompute the block's tokens (prefill replay).
                let tokens = self.table.meta(id).map(|m| m.tokens).unwrap_or(0);
                let dur = self.recompute.recompute_ns(tokens as u64);
                self.stats.recomputes += 1;
                self.stats.recompute_ns += dur;
                hr.node.clock.now() + dur
            }
        };
        self.table.set_residency(id, BlockResidency::Local);
        self.policy.insert(id, hr.node.clock.now());
        ready
    }

    /// Evict until `headroom` local slots are free. Victims are gathered
    /// first and offloaded as one batch, so multi-block pressure costs
    /// one vectored admission instead of N scalar ones.
    fn make_room(&mut self, hr: &mut HarvestRuntime, headroom: usize) {
        let mut victims = Vec::new();
        while self.policy.len() + headroom > self.cfg.local_capacity_blocks {
            let Some(victim) = self.policy.victim() else { break };
            self.policy.remove(victim);
            victims.push(victim);
        }
        self.offload_batch(hr, victims);
    }

    /// Pre-admission hook: guarantee `blocks` free local slots (e.g.
    /// before prefilling a prompt), evicting one vectored batch if the
    /// pool is short. Clamped to the pool size.
    pub fn reserve_local(&mut self, hr: &mut HarvestRuntime, blocks: usize) {
        self.sync(hr);
        self.make_room(hr, blocks.min(self.cfg.local_capacity_blocks));
    }

    /// Migrate one local block out (§5.2 "workers similarly request block
    /// evictions, allowing handlers to migrate blocks out of local HBM").
    pub fn evict_block(&mut self, hr: &mut HarvestRuntime, id: BlockId) {
        self.sync(hr);
        debug_assert_eq!(self.table.residency(id), Some(BlockResidency::Local));
        self.policy.remove(id);
        self.offload_batch(hr, vec![id]);
    }

    /// Move `victims` (already detached from the eviction policy) out of
    /// local HBM: all-or-nothing into peer leases when Harvest is on and
    /// the batch fits, host DRAM otherwise.
    fn offload_batch(&mut self, hr: &mut HarvestRuntime, victims: Vec<BlockId>) {
        if victims.is_empty() {
            return;
        }
        let bytes = self.cfg.block_bytes();
        if self.cfg.use_harvest {
            let session = self.session(hr);
            let hints = AllocHints {
                compute_gpu: Some(self.handler.compute_gpu),
                durability: if self.cfg.host_backed_peer {
                    Durability::HostBacked
                } else {
                    Durability::Lossy
                },
                ..Default::default()
            };
            let sizes = vec![bytes; victims.len()];
            match session.alloc_many(hr, &sizes, hints) {
                Ok(leases) => {
                    // One batched-DMA submission: local -> peer for every
                    // victim (plus durable host copies if configured).
                    let mut batch = Transfer::new().chunked(RELOAD_CHUNK_BYTES);
                    for lease in &leases {
                        batch =
                            batch.populate(lease, DeviceId::Gpu(self.handler.compute_gpu));
                        if self.cfg.host_backed_peer {
                            batch = batch.raw(
                                DeviceId::Gpu(self.handler.compute_gpu),
                                DeviceId::Host,
                                bytes,
                            );
                        }
                    }
                    batch.submit(hr).expect("fresh leases");
                    for (id, lease) in victims.into_iter().zip(leases) {
                        self.table.set_residency(
                            id,
                            BlockResidency::Peer { handle: lease.id(), peer: lease.peer() },
                        );
                        self.leases.insert(lease.id(), lease);
                        self.stats.evictions_to_peer += 1;
                    }
                    return;
                }
                Err(_) => {
                    // All-or-nothing rollback: no element of the batch
                    // landed on a peer; every victim takes the host path.
                    self.stats.peer_alloc_failures += 1;
                }
            }
        }
        // Vanilla vLLM path: evict to host DRAM over PCIe.
        for id in victims {
            self.handler.transfer(
                hr,
                DeviceId::Gpu(self.handler.compute_gpu),
                DeviceId::Host,
                bytes,
            );
            self.table.set_residency(id, BlockResidency::Host);
            self.stats.evictions_to_host += 1;
        }
    }

    /// Finish a sequence: release all its blocks (and any peer leases).
    pub fn finish_seq(&mut self, hr: &mut HarvestRuntime, seq: SeqId) {
        self.sync(hr);
        let removed = self.table.remove_seq(seq);
        for (id, res) in removed {
            self.policy.remove(id);
            if let BlockResidency::Peer { handle, .. } = res {
                if let Some(lease) = self.leases.remove(&handle) {
                    let session = self.session.expect("lease implies session");
                    let _ = session.release(hr, lease);
                }
            }
        }
    }

    /// How many peer-revocation drops the event queue has delivered.
    pub fn drops_observed(&self) -> u64 {
        self.stats.revocation_drops
    }

    /// Consistency between policy membership, table residency, and the
    /// lease map.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.table.check_invariants()?;
        let local_in_table = self.table.count_by_residency().0;
        if local_in_table != self.policy.len() {
            return Err(format!(
                "policy tracks {} blocks, table says {} local",
                self.policy.len(),
                local_in_table
            ));
        }
        if self.policy.len() > self.cfg.local_capacity_blocks {
            return Err("local pool over capacity".into());
        }
        let peer_in_table = self.table.count_by_residency().1;
        if peer_in_table != self.leases.len() {
            return Err(format!(
                "table has {} peer blocks but manager holds {} leases",
                peer_in_table,
                self.leases.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::{HarvestConfig, MigConfig, RevocationReason};
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::{NodeSpec, SimNode};
    use crate::moe::config::find_kv_model;

    const GIB: u64 = 1 << 30;

    fn hr() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    fn cfg(use_harvest: bool, cap: usize) -> KvConfig {
        KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap,
            use_harvest,
            host_backed_peer: false,
        }
    }

    #[test]
    fn appends_fill_blocks_at_granularity() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 100), 0);
        let s = SeqId(1);
        for _ in 0..33 {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.table().seq_blocks(s).len(), 3, "33 tokens -> 3 blocks of 16");
        assert_eq!(kv.table().meta(kv.table().seq_blocks(s)[2]).unwrap().tokens, 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn eviction_to_peer_when_harvest_on() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(kv.stats.evictions_to_peer >= 2);
        assert_eq!(kv.stats.evictions_to_host, 0);
        let (_local, peer, host, dropped) = kv.table().count_by_residency();
        assert!(peer >= 2, "peer={peer} host={host} dropped={dropped}");
        kv.check_invariants().unwrap();
        // bytes actually moved GPU0 -> GPU1
        assert!(h.node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Gpu(1)) > 0);
    }

    #[test]
    fn eviction_to_host_when_harvest_off() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(false, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.evictions_to_host >= 2);
        assert!(h.node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Host) > 0);
    }

    #[test]
    fn reload_from_peer_faster_than_host() {
        let measure = |use_harvest: bool| {
            let mut h = hr();
            let mut kv = KvOffloadManager::new(cfg(use_harvest, 4), 0);
            let s = SeqId(1);
            for _ in 0..(16 * 6) {
                kv.append_token(&mut h, s);
            }
            // touch the first (evicted) block
            let first = kv.table().seq_blocks(s)[0];
            assert_ne!(kv.table().residency(first), Some(BlockResidency::Local));
            kv.access_block(&mut h, first);
            (kv.stats.clone(), kv, h)
        };
        let (harvest_stats, kv1, h1) = measure(true);
        let (host_stats, _, _) = measure(false);
        assert_eq!(harvest_stats.peer_reloads, 1);
        assert_eq!(host_stats.host_reloads, 1);
        assert!(
            harvest_stats.reload_ns < host_stats.reload_ns / 3,
            "peer reload {} should be much faster than host {}",
            harvest_stats.reload_ns,
            host_stats.reload_ns
        );
        kv1.check_invariants().unwrap();
        drop(h1);
    }

    #[test]
    fn revocation_drops_lossy_blocks_then_recompute() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        let peer_before = kv.table().count_by_residency().1;
        assert!(peer_before > 0);
        h.revoke_peer(1, RevocationReason::TenantPressure);
        // pull model: the drops become visible at the next sync
        kv.sync(&mut h);
        assert_eq!(kv.drops_observed() as usize, peer_before);
        assert_eq!(kv.stats.revocation_drops as usize, peer_before);
        let (_, peer, _, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, peer_before);
        // accessing a dropped block recomputes
        let first = kv.table().seq_blocks(s)[0];
        let before = kv.stats.recomputes;
        kv.access_block(&mut h, first);
        assert_eq!(kv.stats.recomputes, before + 1);
        assert!(kv.stats.recompute_ns > 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn revocation_visible_without_explicit_sync() {
        // Entry points sync implicitly: no manual call needed as long as
        // the manager is used at all after the revocation.
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        h.revoke_peer(1, RevocationReason::TenantPressure);
        kv.access_seq(&mut h, s); // syncs, then recomputes dropped blocks
        assert!(kv.stats.recomputes > 0);
        assert_eq!(kv.table().count_by_residency().1, 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn host_backed_peer_falls_back_to_host() {
        let mut h = hr();
        let mut c = cfg(true, 4);
        c.host_backed_peer = true;
        let mut kv = KvOffloadManager::new(c, 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        h.revoke_peer(1, RevocationReason::TenantPressure);
        kv.sync(&mut h);
        let (_, peer, host, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, 0, "durable blocks never drop");
        assert!(host >= 2);
    }

    #[test]
    fn full_peer_falls_back_to_host_eviction() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut h = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        h.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 80 * GIB));
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.peer_alloc_failures > 0);
        assert!(kv.stats.evictions_to_host > 0, "graceful fallback to vanilla path");
    }

    #[test]
    fn reserve_local_batches_eviction_all_or_nothing() {
        // Peer capped below the batch: the vectored admission must fail
        // as a whole (no partial peer placement) and every victim must
        // take the host path.
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hcfg = HarvestConfig::for_node(2);
        let c = cfg(true, 4);
        // room for exactly one block on the peer
        hcfg.mig[1] = MigConfig::CachePartition { bytes: c.block_bytes() + c.block_bytes() / 2 };
        let mut h = HarvestRuntime::new(node, hcfg);
        let mut kv = KvOffloadManager::new(c, 0);
        let s = SeqId(1);
        for _ in 0..(16 * 4) {
            kv.append_token(&mut h, s); // fills the pool, no eviction yet
        }
        assert_eq!(kv.stats.evictions_to_peer + kv.stats.evictions_to_host, 0);
        // need 3 free slots -> batch of 3 victims; only 1 would fit
        kv.reserve_local(&mut h, kv.cfg.local_capacity_blocks - 1);
        assert_eq!(kv.stats.evictions_to_peer, 0, "no partial placement");
        assert_eq!(kv.stats.evictions_to_host, 3, "whole batch rolled over to host");
        assert_eq!(h.live_bytes_on(1), 0, "rollback left nothing on the peer");
        assert_eq!(kv.stats.peer_alloc_failures, 1, "one vectored consultation");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_local_admits_batch_to_peer_when_it_fits() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 4) {
            kv.append_token(&mut h, s);
        }
        kv.reserve_local(&mut h, 3);
        assert_eq!(kv.stats.evictions_to_peer, 3, "one vectored batch of 3");
        assert_eq!(kv.stats.evictions_to_host, 0);
        assert_eq!(h.live_bytes_on(1), 3 * kv.cfg.block_bytes());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn finish_seq_releases_peer_leases() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(h.live_bytes_on(1) > 0);
        kv.finish_seq(&mut h, s);
        assert_eq!(h.live_bytes_on(1), 0, "harvest leases released");
        assert!(kv.table().is_empty());
        assert_eq!(kv.local_blocks(), 0);
    }

    #[test]
    fn access_seq_advances_clock_past_reloads() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 8) {
            kv.append_token(&mut h, s);
        }
        let t0 = h.node.clock.now();
        kv.access_seq(&mut h, s);
        assert!(h.node.clock.now() > t0, "reloads take time");
        // afterwards everything the pool can hold is local
        kv.check_invariants().unwrap();
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 3), 0);
        for seq in 0..4 {
            for _ in 0..(16 * 2) {
                kv.append_token(&mut h, SeqId(seq));
            }
        }
        assert!(kv.local_blocks() <= 3);
        kv.check_invariants().unwrap();
    }
}
