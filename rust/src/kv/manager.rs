//! `KvOffloadManager` + per-device `OffloadingHandler` (§5.2), tiered.
//!
//! "We introduce a KVOffloadManager into vLLM's KV manager, which serves
//! as a pluggable control interface for implementing Harvest's
//! policy-driven allocation, migration, and revocation semantics. ...
//! For each device, Harvest extends vLLM with an OffloadingHandler
//! responsible for executing data movement operations."
//!
//! Flow:
//! * Decode appends tokens; full local pool ⇒ the eviction policy picks
//!   victims and the manager migrates them out through **one vectored
//!   tier-aware lease batch**: under Harvest the placement policy scores
//!   peer HBM vs CXL vs host DRAM (`TierPreference::FastestAvailable`);
//!   vanilla-vLLM mode pins the batch to host
//!   (`TierPreference::Pinned(Host)`). Either way the bytes move through
//!   lease-addressed `Transfer`s, so *all* offload traffic — host
//!   included — is visible in the `PeerMonitor` with the demand/prefetch
//!   split preserved. Multi-block admission is all-or-nothing: one
//!   policy consultation per batch, one tier for the whole batch.
//! * Decode touching a non-local block issues a reload through the
//!   block's lease: peer → NVLink, CXL → the expander link, host → PCIe,
//!   SSD → NVMe staged through host, `Dropped` → recompute. A block the
//!   pressure ladder compressed in place ([`RevocationAction::Compressed`])
//!   additionally pays the modeled decode-side decompression cost
//!   ([`crate::coldtier::Compressor`]) before attention can read it.
//! * [`KvOffloadManager::age_idle_blocks`] walks idle leased blocks one
//!   rung down the cold-tier ladder (peer → host, host → compressed →
//!   SSD) so long-idle sessions surrender fast-tier capacity without
//!   ever becoming `Dropped` — the `tier_ladder` bench's driver.
//! * Revocations arrive as pull-model events: every public entry point
//!   first drains the manager's session queue ([`KvOffloadManager::sync`]).
//!   A [`RevocationAction::Dropped`] event drops lossy blocks (or falls
//!   back to their durable host-shadow lease); a
//!   [`RevocationAction::Demoted`] event means the controller already
//!   migrated the bytes peer→host — the manager only re-points the
//!   block's residency tier, no data was lost.
//! * The prefetch pipeline plans two kinds of background work: reloads
//!   (tier → local, ahead of the next decode step) and **promotions**
//!   (host/CXL → peer via `Transfer::migrate`, so blocks predicted
//!   further out wait on NVLink instead of PCIe when they finally
//!   reload).

use super::block::{BlockId, SeqId};
use super::block_table::{BlockResidency, UnifiedBlockTable};
use super::eviction::{EvictionPolicy, Lru};
use super::recompute::RecomputeModel;
use crate::harvest::api::{AllocHints, Durability, LeaseId, MemoryTier, TierPreference};
use crate::harvest::events::RevocationAction;
use crate::harvest::prefetch::{PrefetchConfig, PrefetchPlanner, PrefetchStats};
use crate::harvest::session::{HarvestSession, Lease, Transfer};
use crate::harvest::{HarvestRuntime, PayloadKind};
use crate::memsim::{DeviceId, Ns};
use crate::moe::config::KvModel;
use crate::obs::trace::{self, Subsystem};
use std::collections::{BTreeMap, BTreeSet};

/// DMA descriptor granularity for KV reloads: blocks are batched into
/// chunks of this size (scattered block copies cannot use one huge
/// contiguous DMA; ~4 MiB descriptors reproduce the Fig. 7 GPU:CPU
/// latency ratio band — see DESIGN.md §Calibration).
pub const RELOAD_CHUNK_BYTES: u64 = 4 * 1024 * 1024;

/// Decode-side reconstruction rate charged when a compressed KV block
/// reloads: ns per *original* byte (~4 GB/s — dequantize + token
/// scatter kernels; see [`crate::coldtier::Compressor`]).
pub const KV_DECOMPRESS_NS_PER_BYTE: f64 = 0.25;

/// Configuration of the KV offload manager.
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    pub model: &'static KvModel,
    /// Tokens per logical block (vLLM default 16).
    pub block_tokens: u32,
    /// Local KV pool capacity, in blocks.
    pub local_capacity_blocks: usize,
    /// Harvest mode: evict through the tier policy (peer HBM preferred,
    /// CXL/host spill). Off = vanilla vLLM (host-pinned leases only) —
    /// the Fig. 7 baseline.
    pub use_harvest: bool,
    /// Also materialise a durable host-shadow lease when evicting to
    /// peer (default off — §5.2 treats peer KV as lossy).
    pub host_backed_peer: bool,
}

impl KvConfig {
    pub fn block_bytes(&self) -> u64 {
        self.block_tokens as u64 * self.model.kv_bytes_per_token()
    }
}

/// Cumulative statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct KvStats {
    pub appends: u64,
    pub local_hits: u64,
    pub peer_reloads: u64,
    pub cxl_reloads: u64,
    pub host_reloads: u64,
    /// Reloads paged in from the SSD cold tier (staged through host).
    pub ssd_reloads: u64,
    pub recomputes: u64,
    pub evictions_to_peer: u64,
    pub evictions_to_cxl: u64,
    pub evictions_to_host: u64,
    /// Offload batches the tier policy landed directly on the SSD arena.
    pub evictions_to_ssd: u64,
    pub peer_alloc_failures: u64,
    pub revocation_drops: u64,
    /// Peer leases the controller demoted to host instead of dropping.
    pub demotions: u64,
    /// Background host/CXL→peer promotions issued.
    pub promotions: u64,
    /// Promoted blocks whose later reload actually rode the fast tier.
    pub promotion_hits: u64,
    /// Blocks compressed in place — by the controller's pressure ladder
    /// (`compress_before_demote`) or by [`KvOffloadManager::age_idle_blocks`].
    pub compressions: u64,
    pub bytes_from_peer: u64,
    pub bytes_from_cxl: u64,
    pub bytes_from_host: u64,
    pub bytes_from_ssd: u64,
    pub reload_ns: Ns,
    /// Per-source-tier split of `reload_ns`, so attribution can charge a
    /// reload stall to the tier that served it (always sums to
    /// `reload_ns`).
    pub reload_ns_peer: Ns,
    pub reload_ns_cxl: Ns,
    pub reload_ns_host: Ns,
    pub reload_ns_ssd: Ns,
    pub recompute_ns: Ns,
    /// Modeled decode-side reconstruction time charged when compressed
    /// blocks reload (see [`crate::coldtier::Compressor`]).
    pub decompress_ns: Ns,
}

impl KvStats {
    pub fn reloads(&self) -> u64 {
        self.peer_reloads + self.cxl_reloads + self.host_reloads + self.ssd_reloads
            + self.recomputes
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.local_hits + self.reloads();
        if total == 0 {
            0.0
        } else {
            self.local_hits as f64 / total as f64
        }
    }

    /// Register every counter into the unified metrics registry under
    /// `prefix` (e.g. `"kv"`).
    pub fn register(&self, reg: &mut crate::obs::MetricsRegistry, prefix: &str) {
        let c = [
            ("appends", self.appends),
            ("local_hits", self.local_hits),
            ("peer_reloads", self.peer_reloads),
            ("cxl_reloads", self.cxl_reloads),
            ("host_reloads", self.host_reloads),
            ("ssd_reloads", self.ssd_reloads),
            ("recomputes", self.recomputes),
            ("evictions_to_peer", self.evictions_to_peer),
            ("evictions_to_cxl", self.evictions_to_cxl),
            ("evictions_to_host", self.evictions_to_host),
            ("evictions_to_ssd", self.evictions_to_ssd),
            ("peer_alloc_failures", self.peer_alloc_failures),
            ("revocation_drops", self.revocation_drops),
            ("demotions", self.demotions),
            ("promotions", self.promotions),
            ("promotion_hits", self.promotion_hits),
            ("compressions", self.compressions),
            ("bytes_from_peer", self.bytes_from_peer),
            ("bytes_from_cxl", self.bytes_from_cxl),
            ("bytes_from_host", self.bytes_from_host),
            ("bytes_from_ssd", self.bytes_from_ssd),
            ("reload_ns", self.reload_ns),
            ("reload_ns_peer", self.reload_ns_peer),
            ("reload_ns_cxl", self.reload_ns_cxl),
            ("reload_ns_host", self.reload_ns_host),
            ("reload_ns_ssd", self.reload_ns_ssd),
            ("recompute_ns", self.recompute_ns),
            ("decompress_ns", self.decompress_ns),
        ];
        for (name, v) in c {
            reg.counter(&format!("{prefix}.{name}"), v);
        }
        reg.gauge(&format!("{prefix}.hit_rate"), self.hit_rate());
    }
}

/// Executes data movement for one device (§5.2). Thin by design: policy
/// lives in the manager; the handler only knows which compute GPU it
/// serves — every move is a lease-addressed [`Transfer`] batched into
/// [`RELOAD_CHUNK_BYTES`] descriptors.
#[derive(Debug, Clone, Copy)]
pub struct OffloadingHandler {
    pub compute_gpu: usize,
}

/// The manager. Owns its block table and eviction policy directly — the
/// pull-model event API needs no shared state with the runtime.
pub struct KvOffloadManager {
    pub cfg: KvConfig,
    table: UnifiedBlockTable,
    policy: Box<dyn EvictionPolicy>,
    handler: OffloadingHandler,
    recompute: RecomputeModel,
    /// Session opened lazily on first runtime interaction (the manager
    /// is constructed before it ever sees the runtime).
    session: Option<HarvestSession>,
    /// Live leases backing every `Leased` block, keyed by id; the
    /// table's `Leased` entries mirror this map exactly.
    leases: BTreeMap<LeaseId, Lease>,
    /// Durable host-shadow leases for peer-resident blocks
    /// (`host_backed_peer` mode): the authoritative copy a revocation
    /// falls back to. One per shadowed block.
    host_shadow: BTreeMap<BlockId, Lease>,
    /// Deadline-aware prefetch admission control + outcome ledger
    /// (enabled via [`KvOffloadManager::with_prefetch`]).
    planner: Option<PrefetchPlanner>,
    /// Blocks brought local by a background prefetch and not yet used:
    /// block → completion time of the background copy. A use before
    /// completion is a *late* (shortened) stall; eviction or sequence
    /// finish before use is *waste*.
    pending_prefetch: BTreeMap<BlockId, Ns>,
    /// Blocks whose lease is being background-migrated to peer HBM:
    /// block → completion time of the promotion copy.
    pending_promotions: BTreeMap<BlockId, Ns>,
    /// Blocks whose lease is compressed in place (by the controller's
    /// pressure ladder or by [`KvOffloadManager::age_idle_blocks`]):
    /// block → compression ratio percent. Their reload pays the modeled
    /// decompression cost; the tag clears when the block comes local.
    compressed: BTreeMap<BlockId, u32>,
    /// Source leases of issued prefetches, held until their background
    /// copy completes (lease, copy end). Releasing earlier would free
    /// tier memory an in-flight read still touches; releasing eagerly
    /// would block on the drain barrier. `sync` releases matured
    /// entries, when the drain is a guaranteed no-op.
    deferred_release: Vec<(Lease, Ns)>,
    pub stats: KvStats,
}

/// One candidate produced by [`KvOffloadManager::plan_prefetch`]: a
/// non-local block a predicted-to-decode sequence will touch. Plans are
/// snapshots — [`KvOffloadManager::submit_prefetch`] revalidates each
/// entry against current residency, so a revocation landing between plan
/// and submit is skipped, never read.
#[derive(Debug, Clone, Copy)]
pub struct PlannedPrefetch {
    pub block: BlockId,
    pub bytes: u64,
}

impl KvOffloadManager {
    pub fn new(cfg: KvConfig, compute_gpu: usize) -> Self {
        Self::with_policy(cfg, compute_gpu, Box::new(Lru::new()))
    }

    pub fn with_policy(
        cfg: KvConfig,
        compute_gpu: usize,
        policy: Box<dyn EvictionPolicy>,
    ) -> Self {
        Self {
            cfg,
            table: UnifiedBlockTable::new(),
            policy,
            handler: OffloadingHandler { compute_gpu },
            recompute: RecomputeModel::new(cfg.model.active_params_b),
            session: None,
            leases: BTreeMap::new(),
            host_shadow: BTreeMap::new(),
            planner: None,
            pending_prefetch: BTreeMap::new(),
            pending_promotions: BTreeMap::new(),
            compressed: BTreeMap::new(),
            deferred_release: Vec::new(),
            stats: KvStats::default(),
        }
    }

    /// Enable the deadline-aware prefetch pipeline: callers (the sim
    /// engine) can then [`KvOffloadManager::plan_prefetch`] /
    /// [`KvOffloadManager::submit_prefetch`] predicted sequences so their
    /// reloads overlap decode compute instead of stalling it, and
    /// [`KvOffloadManager::promote_blocks`] host-resident blocks toward
    /// peer HBM when capacity opens.
    pub fn with_prefetch(mut self, cfg: PrefetchConfig) -> Self {
        self.planner = Some(PrefetchPlanner::new(cfg));
        self
    }

    pub fn prefetch_enabled(&self) -> bool {
        self.planner.is_some()
    }

    /// The prefetch outcome ledger (None when prefetch is disabled).
    pub fn prefetch_stats(&self) -> Option<&PrefetchStats> {
        self.planner.as_ref().map(|p| p.stats())
    }

    pub fn table(&self) -> &UnifiedBlockTable {
        &self.table
    }

    pub fn local_blocks(&self) -> usize {
        self.policy.len()
    }

    fn session(&mut self, hr: &mut HarvestRuntime) -> HarvestSession {
        *self
            .session
            .get_or_insert_with(|| HarvestSession::open(hr, PayloadKind::KvBlock))
    }

    fn offload_hints(&self) -> AllocHints {
        AllocHints {
            compute_gpu: Some(self.handler.compute_gpu),
            durability: if self.cfg.host_backed_peer {
                Durability::HostBacked
            } else {
                Durability::Lossy
            },
            ..Default::default()
        }
    }

    /// Drain pending revocation events and repair the block table: the
    /// tick-boundary pull that replaces the old push callbacks. Every
    /// public entry point calls this first, so the manager's view is
    /// current before it makes placement decisions; tests and engines
    /// may also call it directly after advancing virtual time.
    pub fn sync(&mut self, hr: &mut HarvestRuntime) {
        let Some(session) = self.session else { return };
        // Release prefetch source leases whose background copy has
        // completed: the drain inside `release` is a no-op now, so this
        // never blocks. Leases revoked in the meantime release as a
        // harmless StaleLease error (the runtime already freed them,
        // after draining the tagged copy per §3.2).
        if !self.deferred_release.is_empty() {
            let now = hr.node.clock.now();
            let deferred = std::mem::take(&mut self.deferred_release);
            for (lease, ready) in deferred {
                if ready <= now {
                    let _ = session.release(hr, lease);
                } else {
                    self.deferred_release.push((lease, ready));
                }
            }
        }
        for ev in session.drain_revocations(hr) {
            match ev.action {
                RevocationAction::Demoted { to } => {
                    // The controller already migrated the bytes and the
                    // lease survived; we only re-point our residency tier.
                    self.stats.demotions += 1;
                    trace::instant_now(
                        Subsystem::Revocation,
                        "demoted",
                        &[("lease", ev.lease.0), ("to_tier", to.speed_rank() as u64)],
                    );
                    if let Some(b) = self.table.block_of_handle(ev.lease) {
                        self.pending_promotions.remove(&b);
                        self.table.set_residency(
                            b,
                            BlockResidency::Leased { handle: ev.lease, tier: to },
                        );
                    }
                }
                RevocationAction::Compressed { ratio } => {
                    // The lease survived in place, shrunk to `ratio`
                    // percent: residency is unchanged, but the block's
                    // next reload pays the decode-side reconstruction
                    // cost — tag it so `ensure_local` charges it.
                    self.stats.compressions += 1;
                    trace::instant_now(
                        Subsystem::Revocation,
                        "compressed",
                        &[("lease", ev.lease.0), ("ratio_pct", ratio as u64)],
                    );
                    if let Some(b) = self.table.block_of_handle(ev.lease) {
                        self.compressed.insert(b, ratio);
                    }
                }
                RevocationAction::Dropped => {
                    // The runtime already drained DMA, invalidated the
                    // placement and freed the bytes; we repair our indexes.
                    self.leases.remove(&ev.lease);
                    self.stats.revocation_drops += 1;
                    trace::instant_now(Subsystem::Revocation, "dropped", &[("lease", ev.lease.0)]);
                    if let Some(b) = self.table.drop_by_handle(ev.lease) {
                        self.pending_promotions.remove(&b);
                        self.compressed.remove(&b);
                        if ev.durability == Durability::HostBacked {
                            if let Some(shadow) = self.host_shadow.remove(&b) {
                                // The durable host-shadow lease takes over.
                                self.table.set_residency(
                                    b,
                                    BlockResidency::Leased {
                                        handle: shadow.id(),
                                        tier: shadow.tier(),
                                    },
                                );
                                self.leases.insert(shadow.id(), shadow);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Append one token to `seq`, paging in a new block when the last one
    /// fills. May evict under pressure. Returns the block written.
    pub fn append_token(&mut self, hr: &mut HarvestRuntime, seq: SeqId) -> BlockId {
        self.sync(hr);
        self.stats.appends += 1;
        let now = hr.node.clock.now();
        let last = self.table.seq_blocks(seq).last().copied().and_then(|id| {
            let m = self.table.meta(id)?;
            (m.tokens < self.cfg.block_tokens).then_some(id)
        });
        let id = match last {
            // The tail block must be local to be appended to.
            Some(id) if self.table.residency(id) == Some(BlockResidency::Local) => id,
            Some(id) => {
                self.ensure_local(hr, id);
                id
            }
            None => {
                self.make_room(hr, 1);
                let id = self.table.new_block(seq, now);
                self.policy.insert(id, now);
                id
            }
        };
        let m = self.table.meta_mut(id).expect("live block");
        m.tokens += 1;
        m.touch(now);
        self.policy.touch(id, now);
        id
    }

    /// Decode touches every block of `seq`: reload anything non-local.
    /// Returns when the sequence is fully resident (virtual time may
    /// advance past reload DMA and recompute).
    pub fn access_seq(&mut self, hr: &mut HarvestRuntime, seq: SeqId) -> Ns {
        self.sync(hr);
        let ids: Vec<BlockId> = self.table.seq_blocks(seq).to_vec();
        let mut ready = hr.node.clock.now();
        for id in ids {
            ready = ready.max(self.access_block(hr, id));
        }
        hr.node.clock.advance_to(ready);
        ready
    }

    /// Touch one block; reload/recompute if non-local. Returns readiness.
    pub fn access_block(&mut self, hr: &mut HarvestRuntime, id: BlockId) -> Ns {
        self.sync(hr);
        let now = hr.node.clock.now();
        let res = self.table.residency(id).expect("live block");
        let ready = match res {
            BlockResidency::Local => {
                self.stats.local_hits += 1;
                match self.pending_prefetch.remove(&id) {
                    // A prefetched block: on-time arrival means the whole
                    // reload left the critical path; a late arrival still
                    // shortens the stall to the residual copy time.
                    Some(ready_at) => {
                        if let Some(p) = self.planner.as_mut() {
                            p.mark_used(id.0, now);
                        }
                        ready_at.max(now)
                    }
                    None => now,
                }
            }
            _ => self.ensure_local(hr, id),
        };
        self.policy.touch(id, hr.node.clock.now());
        if let Some(m) = self.table.meta_mut(id) {
            m.touch(hr.node.clock.now());
        }
        ready
    }

    /// Bring a block into the local pool (reload or recompute), evicting
    /// to make room first. Returns the readiness time.
    fn ensure_local(&mut self, hr: &mut HarvestRuntime, id: BlockId) -> Ns {
        self.make_room(hr, 1);
        let res = self.table.residency(id).expect("live block");
        let bytes = self.cfg.block_bytes();
        let now = hr.node.clock.now();
        let ready = match res {
            BlockResidency::Local => now,
            BlockResidency::Leased { handle, .. } => {
                // Post-sync, every Leased entry is backed by a live lease.
                let lease = self.leases.remove(&handle).expect("leased block has live lease");
                let tier = lease.tier();
                let session = self.session.expect("lease implies session");
                // The copy that created this placement (spill populate or
                // promotion migrate) may still be writing it; a demand
                // fetch physically serializes behind that copy, so wait
                // it out — a demand-path stall, correctness over overlap
                // (the background path skips instead; see
                // [`KvOffloadManager::submit_prefetch`]).
                let placed_at = hr.node.dma.tag_busy_until(handle.0);
                if placed_at > hr.node.clock.now() {
                    hr.node.clock.advance_to(placed_at);
                }
                // A compressed copy moves fewer bytes but must be
                // reconstructed before attention can read it: look up
                // the tag before release frees the controller entry.
                let compression = hr.compression_of(handle);
                let report = Transfer::new()
                    .chunked(RELOAD_CHUNK_BYTES)
                    .fetch(&lease, self.handler.compute_gpu)
                    .submit(hr)
                    .expect("live lease");
                // The cached copy is consumed: release the lease (ordered
                // free; drains the fetch we just tagged).
                session.release(hr, lease).expect("live lease");
                let dur = report.events[0].duration();
                match tier {
                    MemoryTier::PeerHbm(_) => {
                        self.stats.peer_reloads += 1;
                        self.stats.bytes_from_peer += bytes;
                        self.stats.reload_ns_peer += dur;
                    }
                    MemoryTier::CxlMem => {
                        self.stats.cxl_reloads += 1;
                        self.stats.bytes_from_cxl += bytes;
                        self.stats.reload_ns_cxl += dur;
                    }
                    MemoryTier::Ssd => {
                        self.stats.ssd_reloads += 1;
                        self.stats.bytes_from_ssd += bytes;
                        self.stats.reload_ns_ssd += dur;
                    }
                    _ => {
                        self.stats.host_reloads += 1;
                        self.stats.bytes_from_host += bytes;
                        self.stats.reload_ns_host += dur;
                    }
                }
                self.stats.reload_ns += dur;
                let mut ready = report.end;
                if let Some(info) = compression {
                    let cost = crate::coldtier::Compressor::new(
                        info.ratio,
                        KV_DECOMPRESS_NS_PER_BYTE,
                    )
                    .decompress_cost_ns(info.original_size);
                    self.stats.decompress_ns += cost;
                    ready += cost;
                }
                self.compressed.remove(&id);
                // A pending promotion resolves here: the reload rode
                // whichever tier the migration reached in time.
                if let Some(p_ready) = self.pending_promotions.remove(&id) {
                    if p_ready <= now {
                        self.stats.promotion_hits += 1;
                    }
                    ready = ready.max(p_ready);
                }
                // The durable host shadow is no longer needed once local;
                // release it when its populate has matured.
                if let Some(shadow) = self.host_shadow.remove(&id) {
                    let matured = hr.node.dma.tag_busy_until(shadow.id().0);
                    self.deferred_release.push((shadow, matured));
                }
                ready
            }
            BlockResidency::Dropped => {
                // Recompute the block's tokens (prefill replay).
                let tokens = self.table.meta(id).map(|m| m.tokens).unwrap_or(0);
                let dur = self.recompute.recompute_ns(tokens as u64);
                self.stats.recomputes += 1;
                self.stats.recompute_ns += dur;
                now + dur
            }
        };
        self.table.set_residency(id, BlockResidency::Local);
        self.policy.insert(id, hr.node.clock.now());
        ready
    }

    /// Evict until `headroom` local slots are free. Victims are gathered
    /// first and offloaded as one batch, so multi-block pressure costs
    /// one vectored admission instead of N scalar ones.
    ///
    /// Blocks whose background prefetch copy is still in flight are
    /// skipped as victims while any alternative exists — spilling them
    /// would read local bytes the copy has not finished writing. If
    /// *only* such blocks remain, the oldest one's copy is waited out
    /// (a demand-path stall, correctness over overlap) and it is
    /// evicted normally.
    fn make_room(&mut self, hr: &mut HarvestRuntime, headroom: usize) {
        let now = hr.node.clock.now();
        let mut victims = Vec::new();
        let mut inflight: Vec<BlockId> = Vec::new();
        while self.policy.len() + inflight.len() + headroom > self.cfg.local_capacity_blocks {
            match self.policy.victim() {
                Some(victim) => {
                    self.policy.remove(victim);
                    if self.pending_prefetch.get(&victim).is_some_and(|&r| r > now) {
                        inflight.push(victim);
                        continue;
                    }
                    victims.push(victim);
                }
                None => {
                    let Some(victim) = inflight.pop() else { break };
                    let ready = self.pending_prefetch.get(&victim).copied().unwrap_or(now);
                    hr.node.clock.advance_to(ready);
                    victims.push(victim);
                }
            }
        }
        for id in inflight {
            self.policy.insert(id, now);
        }
        self.offload_batch(hr, victims);
    }

    /// Pre-admission hook: guarantee `blocks` free local slots (e.g.
    /// before prefilling a prompt), evicting one vectored batch if the
    /// pool is short. Clamped to the pool size.
    pub fn reserve_local(&mut self, hr: &mut HarvestRuntime, blocks: usize) {
        self.sync(hr);
        self.make_room(hr, blocks.min(self.cfg.local_capacity_blocks));
    }

    // -- deadline-aware prefetch ------------------------------------------

    /// Phase 1 of a prefetch round: name every non-local block the
    /// predicted `seqs` (from [`crate::server::scheduler::Scheduler::lookahead`])
    /// would have to reload, deduplicated, in prediction order. Moves
    /// nothing and issues nothing. `Dropped` blocks are excluded —
    /// recompute is not DMA and cannot be overlapped by this pipeline.
    pub fn plan_prefetch(
        &mut self,
        hr: &mut HarvestRuntime,
        seqs: &[SeqId],
    ) -> Vec<PlannedPrefetch> {
        self.sync(hr);
        if self.planner.is_none() {
            return Vec::new();
        }
        let bytes = self.cfg.block_bytes();
        let mut seen: BTreeSet<BlockId> = BTreeSet::new();
        let mut out = Vec::new();
        for &seq in seqs {
            for &id in self.table.seq_blocks(seq) {
                if !seen.insert(id) {
                    continue;
                }
                if matches!(self.table.residency(id), Some(BlockResidency::Leased { .. })) {
                    out.push(PlannedPrefetch { block: id, bytes });
                }
            }
        }
        out
    }

    /// Phase 2: issue the planned reloads that are still valid and that
    /// the planner admits, as background transfers completing by
    /// `deadline` (the start of the next decode step — the contract that
    /// keeps prefetch traffic from ever delaying a demand fetch).
    ///
    /// Every entry is revalidated against *current* residency first: a
    /// revocation arriving between plan and submit turned the block
    /// `Dropped` (or swapped it to its host shadow), so a stale lease is
    /// never read. Returns how many background reloads were issued.
    pub fn submit_prefetch(
        &mut self,
        hr: &mut HarvestRuntime,
        plan: &[PlannedPrefetch],
        deadline: Ns,
    ) -> usize {
        if self.planner.is_none() || plan.is_empty() {
            return 0;
        }
        // Fold in any revocations that raced in since the plan was made.
        self.sync(hr);
        let compute = self.handler.compute_gpu;
        let dst = DeviceId::Gpu(compute);
        let mut issued = 0;
        for p in plan {
            // Revalidate: the block may have been revoked (Dropped),
            // reloaded by a demand fetch (Local), or freed (None) since
            // the plan snapshot.
            let src = match self.table.residency(p.block) {
                Some(BlockResidency::Leased { handle, tier }) => {
                    if hr.node.dma.tag_busy_until(handle.0) > hr.node.clock.now() {
                        // The copy that created this tier placement (spill
                        // populate or promotion migrate) is itself still
                        // in flight: fetching now would read unwritten
                        // bytes, and releasing the lease would block on
                        // the drain barrier. Skip; the next round can
                        // pick it up.
                        self.planner.as_mut().unwrap().mark_stale_plan();
                        continue;
                    }
                    tier.device()
                }
                _ => {
                    self.planner.as_mut().unwrap().mark_stale_plan();
                    continue;
                }
            };
            // Admission before any movement: a yielded prefetch must not
            // trigger an eviction either. Admit against the scattered
            // cost the reload will actually pay.
            let admitted = self.planner.as_mut().unwrap().admit(
                &hr.node.topo,
                src,
                dst,
                p.bytes,
                Some(RELOAD_CHUNK_BYTES),
                deadline,
            );
            if !admitted {
                continue;
            }
            self.make_room(hr, 1);
            // make_room can only evict *local* blocks; `p.block` is not
            // local, so the source we validated above is untouched.
            let ready_at = match self.table.residency(p.block).expect("validated above") {
                BlockResidency::Leased { handle, .. } => {
                    let lease = self
                        .leases
                        .remove(&handle)
                        .expect("post-sync leased block has live lease");
                    match Transfer::new()
                        .chunked(RELOAD_CHUNK_BYTES)
                        .background()
                        .fetch(&lease, compute)
                        .submit(hr)
                    {
                        Ok(report) => {
                            // The cached copy is being consumed. The lease
                            // stays alive until the tagged background
                            // copy completes (its bytes must not be
                            // reallocated under an in-flight read);
                            // `sync` releases it once matured, when the
                            // drain barrier is a guaranteed no-op.
                            // Bandwidth is accounted in the planner's
                            // ledger only — KvStats' bytes_from_* stay
                            // demand-reload counters.
                            self.deferred_release.push((lease, report.end));
                            report.end
                        }
                        Err(_) => {
                            // Unreachable single-threaded (nothing revokes
                            // between the sync above and here), but fail
                            // closed: treat the lease as already dead.
                            self.table.drop_by_handle(handle);
                            drop(lease);
                            self.planner.as_mut().unwrap().mark_stale_plan();
                            continue;
                        }
                    }
                }
                _ => unreachable!("validated above"),
            };
            // A pending promotion resolves here: the prefetch rode
            // whichever tier the migration reached.
            if let Some(p_ready) = self.pending_promotions.remove(&p.block) {
                if p_ready <= hr.node.clock.now() {
                    self.stats.promotion_hits += 1;
                }
            }
            // The durable host shadow is no longer needed once local.
            if let Some(shadow) = self.host_shadow.remove(&p.block) {
                let matured = hr.node.dma.tag_busy_until(shadow.id().0);
                self.deferred_release.push((shadow, matured));
            }
            self.table.set_residency(p.block, BlockResidency::Local);
            self.policy.insert(p.block, hr.node.clock.now());
            self.pending_prefetch.insert(p.block, ready_at);
            let planner = self.planner.as_mut().unwrap();
            planner.record_issued(p.block.0, p.bytes, ready_at, deadline);
            planner.mark_link_busy(src, dst, ready_at);
            issued += 1;
        }
        issued
    }

    /// Plan + submit in one call — the engine's per-step hook.
    pub fn prefetch_seqs(
        &mut self,
        hr: &mut HarvestRuntime,
        seqs: &[SeqId],
        deadline: Ns,
    ) -> usize {
        let plan = self.plan_prefetch(hr, seqs);
        self.submit_prefetch(hr, &plan, deadline)
    }

    /// Background host/CXL → peer **promotion** for blocks of the
    /// predicted `seqs` that are not worth reloading to the local pool
    /// yet (they would evict hotter blocks) but will reload soon: their
    /// lease is migrated toward peer HBM under the same deadline-aware
    /// admission control, so the eventual reload rides NVLink instead of
    /// PCIe. The reverse of the controller's pressure demotion. Returns
    /// how many promotions were issued.
    pub fn promote_blocks(
        &mut self,
        hr: &mut HarvestRuntime,
        seqs: &[SeqId],
        deadline: Ns,
    ) -> usize {
        self.sync(hr);
        // Promotion targets peer HBM; the vanilla-vLLM baseline
        // (use_harvest off) must never touch that tier.
        if self.planner.is_none() || !self.cfg.use_harvest {
            return 0;
        }
        let bytes = self.cfg.block_bytes();
        let hints = self.offload_hints();
        let mut seen: BTreeSet<BlockId> = BTreeSet::new();
        let mut candidates: Vec<BlockId> = Vec::new();
        for &seq in seqs {
            for &id in self.table.seq_blocks(seq) {
                if seen.insert(id) {
                    candidates.push(id);
                }
            }
        }
        let mut promoted = 0;
        for id in candidates {
            let Some(BlockResidency::Leased { handle, tier }) = self.table.residency(id)
            else {
                continue;
            };
            if tier.is_peer() || self.pending_promotions.contains_key(&id) {
                continue;
            }
            if hr.node.dma.tag_busy_until(handle.0) > hr.node.clock.now() {
                continue; // spill copy still writing the source
            }
            // Ask the placement policy for a peer target; peers full
            // ends the round.
            let Ok(dest) =
                hr.select_placement(bytes, bytes, TierPreference::PEER_ONLY, hints)
            else {
                return promoted;
            };
            let (src, dst) = (tier.device(), dest.device());
            let admitted = self.planner.as_mut().unwrap().admit(
                &hr.node.topo,
                src,
                dst,
                bytes,
                Some(RELOAD_CHUNK_BYTES),
                deadline,
            );
            if !admitted {
                continue;
            }
            let lease = self.leases.get(&handle).expect("leased block has live lease");
            let Ok(report) = Transfer::new()
                .chunked(RELOAD_CHUNK_BYTES)
                .background()
                .migrate(lease, dest)
                .submit(hr)
            else {
                continue; // target filled up between select and submit
            };
            self.table.set_residency(id, BlockResidency::Leased { handle, tier: dest });
            self.pending_promotions.insert(id, report.end);
            let planner = self.planner.as_mut().unwrap();
            planner.mark_link_busy(src, dst, report.end);
            self.stats.promotions += 1;
            promoted += 1;
        }
        promoted
    }

    // -- cold-tier aging ladder -------------------------------------------

    /// One sweep of the cold-tier aging ladder (the `tier_ladder`
    /// bench's driver): every leased block idle for at least `idle_ns`
    /// steps one rung down —
    ///
    /// * peer HBM → host DRAM ([`Transfer::migrate`]),
    /// * uncompressed host/CXL → compressed in place
    ///   ([`Transfer::compress`] at `ratio_pct`),
    /// * compressed host/CXL → the SSD arena (when the node has one).
    ///
    /// Local blocks are untouched (the eviction policy owns them), as
    /// are blocks whose placement copy is still in flight. Migrations
    /// run as background transfers, so the sweep never advances the
    /// clock. Without the ladder the same idle blocks are dropped under
    /// pressure and recomputed on return; with it they page back in
    /// with zero recomputes, paying DMA plus the modeled decompression
    /// cost. Returns the number of rung transitions executed.
    ///
    /// # Panics
    ///
    /// Panics unless `1 <= ratio_pct <= 99` (the [`Transfer::compress`]
    /// contract).
    pub fn age_idle_blocks(
        &mut self,
        hr: &mut HarvestRuntime,
        idle_ns: Ns,
        ratio_pct: u32,
    ) -> usize {
        self.sync(hr);
        let now = hr.node.clock.now();
        let candidates: Vec<(BlockId, LeaseId, MemoryTier)> = self
            .table
            .leased_blocks()
            .filter(|(_, _, _, m)| now.saturating_sub(m.last_access) >= idle_ns)
            .map(|(id, handle, tier, _)| (id, handle, tier))
            .collect();
        let mut stepped = 0;
        for (id, handle, tier) in candidates {
            if self.pending_promotions.contains_key(&id)
                || hr.node.dma.tag_busy_until(handle.0) > now
            {
                continue; // the copy that placed it is still writing
            }
            let is_compressed = hr.compression_of(handle).is_some();
            let lease = self.leases.get(&handle).expect("leased block has live lease");
            let dest = match tier {
                MemoryTier::PeerHbm(_) => Some(MemoryTier::Host),
                MemoryTier::Host | MemoryTier::CxlMem if is_compressed => {
                    if hr.node.has_ssd() {
                        Some(MemoryTier::Ssd)
                    } else {
                        continue; // no cold tier below: already terminal
                    }
                }
                MemoryTier::Host | MemoryTier::CxlMem => None, // compress rung
                _ => continue, // SSD is the bottom of the ladder
            };
            match dest {
                Some(to) => {
                    if Transfer::new()
                        .chunked(RELOAD_CHUNK_BYTES)
                        .background()
                        .migrate(lease, to)
                        .submit(hr)
                        .is_err()
                    {
                        continue; // no capacity below: stay put this round
                    }
                    self.table
                        .set_residency(id, BlockResidency::Leased { handle, tier: to });
                    trace::instant(
                        Subsystem::ColdTier,
                        "age_demote",
                        now,
                        &[
                            ("block", id.0),
                            ("from_tier", tier.speed_rank() as u64),
                            ("to_tier", to.speed_rank() as u64),
                        ],
                    );
                }
                None => {
                    if Transfer::new().compress(lease, ratio_pct).submit(hr).is_err() {
                        continue;
                    }
                    self.compressed.insert(id, ratio_pct);
                    self.stats.compressions += 1;
                    trace::instant(
                        Subsystem::ColdTier,
                        "age_compress",
                        now,
                        &[("block", id.0), ("ratio_pct", ratio_pct as u64)],
                    );
                }
            }
            stepped += 1;
        }
        stepped
    }

    /// Blocks currently carrying a compression tag (their next reload
    /// pays the modeled decompression cost), with their ratio percent.
    pub fn compressed_blocks(&self) -> impl Iterator<Item = (BlockId, u32)> + '_ {
        self.compressed.iter().map(|(&id, &r)| (id, r))
    }

    /// Cancel pending prefetches for `seq` (scheduler preemption or
    /// cancellation): their blocks stay local, but the outcome ledger
    /// records the bandwidth as wasted if they are never used.
    pub fn cancel_prefetch_seq(&mut self, seq: SeqId) {
        let Some(planner) = self.planner.as_mut() else { return };
        for &id in self.table.seq_blocks(seq) {
            if self.pending_prefetch.remove(&id).is_some() {
                planner.mark_canceled(id.0);
            }
        }
    }

    /// Migrate one local block out (§5.2 "workers similarly request block
    /// evictions, allowing handlers to migrate blocks out of local HBM").
    pub fn evict_block(&mut self, hr: &mut HarvestRuntime, id: BlockId) {
        self.sync(hr);
        debug_assert_eq!(self.table.residency(id), Some(BlockResidency::Local));
        self.policy.remove(id);
        self.offload_batch(hr, vec![id]);
    }

    /// Move `victims` (already detached from the eviction policy) out of
    /// local HBM through one vectored tier-aware lease batch: the
    /// placement policy scores peer vs CXL vs host under Harvest
    /// (`FastestAvailable`), or pins host in vanilla mode. All-or-
    /// nothing: the whole batch lands on one tier.
    fn offload_batch(&mut self, hr: &mut HarvestRuntime, victims: Vec<BlockId>) {
        if victims.is_empty() {
            return;
        }
        // Evicting a block whose prefetch was never consumed: the
        // background bandwidth was wasted (misprediction or preemption).
        if let Some(planner) = self.planner.as_mut() {
            for id in &victims {
                if self.pending_prefetch.remove(id).is_some() {
                    planner.mark_canceled(id.0);
                }
            }
        }
        let bytes = self.cfg.block_bytes();
        let session = self.session(hr);
        let hints = self.offload_hints();
        let pref = if self.cfg.use_harvest {
            TierPreference::FastestAvailable
        } else {
            TierPreference::Pinned(MemoryTier::Host)
        };
        let sizes = vec![bytes; victims.len()];
        let Ok(leases) = session.alloc_many(hr, &sizes, pref, hints) else {
            // Even the host tier cannot take the batch (the modeled DRAM
            // arena is exhausted or fragmented) — where a real server
            // would backpressure. Degrade without aborting: the victims'
            // bytes are surrendered and the blocks fall to `Dropped`
            // (recomputed on next use), never a partial placement.
            if self.cfg.use_harvest {
                self.stats.peer_alloc_failures += 1;
            }
            for id in victims {
                self.table.set_residency(id, BlockResidency::Dropped);
            }
            return;
        };
        let tier = leases[0].tier();
        if self.cfg.use_harvest && !tier.is_peer() {
            // One vectored consultation spilled the whole batch off-peer.
            self.stats.peer_alloc_failures += 1;
        }
        // Durable host shadows ride along only for peer-resident copies
        // (a host-tier lease IS the host copy already); if the host
        // arena cannot hold them the batch simply stays shadow-less
        // (its durability then degrades to lossy on revocation).
        let shadows: Vec<Lease> = if self.cfg.host_backed_peer && tier.is_peer() {
            session
                .alloc_many(
                    hr,
                    &sizes,
                    TierPreference::Pinned(MemoryTier::Host),
                    AllocHints { durability: Durability::HostBacked, ..hints },
                )
                .unwrap_or_default()
        } else {
            Vec::new()
        };
        // One batched-DMA submission: local -> tier for every victim
        // (plus the durable host copies if configured).
        let src = DeviceId::Gpu(self.handler.compute_gpu);
        let mut batch = Transfer::new().chunked(RELOAD_CHUNK_BYTES);
        for lease in &leases {
            batch = batch.populate(lease, src);
        }
        for shadow in &shadows {
            batch = batch.populate(shadow, src);
        }
        batch.submit(hr).expect("fresh leases");
        let mut shadows = shadows.into_iter();
        for (id, lease) in victims.into_iter().zip(leases) {
            match tier {
                MemoryTier::PeerHbm(_) => self.stats.evictions_to_peer += 1,
                MemoryTier::CxlMem => self.stats.evictions_to_cxl += 1,
                MemoryTier::Ssd => self.stats.evictions_to_ssd += 1,
                _ => self.stats.evictions_to_host += 1,
            }
            self.table.set_residency(
                id,
                BlockResidency::Leased { handle: lease.id(), tier: lease.tier() },
            );
            self.leases.insert(lease.id(), lease);
            if let Some(shadow) = shadows.next() {
                self.host_shadow.insert(id, shadow);
            }
        }
    }

    /// Finish a sequence: release all its blocks (and any leases).
    pub fn finish_seq(&mut self, hr: &mut HarvestRuntime, seq: SeqId) {
        self.sync(hr);
        let removed = self.table.remove_seq(seq);
        for (id, res) in removed {
            self.policy.remove(id);
            self.pending_promotions.remove(&id);
            self.compressed.remove(&id);
            if self.pending_prefetch.remove(&id).is_some() {
                // Prefetched for a sequence that finished before using it.
                if let Some(p) = self.planner.as_mut() {
                    p.mark_canceled(id.0);
                }
            }
            if let BlockResidency::Leased { handle, .. } = res {
                if let Some(lease) = self.leases.remove(&handle) {
                    let session = self.session.expect("lease implies session");
                    let _ = session.release(hr, lease);
                }
            }
            if let Some(shadow) = self.host_shadow.remove(&id) {
                let session = self.session.expect("lease implies session");
                let _ = session.release(hr, shadow);
            }
        }
    }

    /// How many revocation drops the event queue has delivered.
    pub fn drops_observed(&self) -> u64 {
        self.stats.revocation_drops
    }

    /// Consistency between policy membership, table residency, the lease
    /// map, and the shadow/promotion side tables.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.table.check_invariants()?;
        let (local, peer, offgpu, _dropped) = self.table.count_by_residency();
        if local != self.policy.len() {
            return Err(format!(
                "policy tracks {} blocks, table says {local} local",
                self.policy.len()
            ));
        }
        if self.policy.len() > self.cfg.local_capacity_blocks {
            return Err("local pool over capacity".into());
        }
        if peer + offgpu != self.leases.len() {
            return Err(format!(
                "table has {} leased blocks but manager holds {} leases",
                peer + offgpu,
                self.leases.len()
            ));
        }
        for &id in self.pending_prefetch.keys() {
            if self.table.residency(id) != Some(BlockResidency::Local) {
                return Err(format!("pending prefetch for non-local block {id:?}"));
            }
        }
        for &id in self.pending_promotions.keys() {
            if !self.table.residency(id).map(|r| r.is_peer()).unwrap_or(false) {
                return Err(format!("pending promotion for non-peer block {id:?}"));
            }
        }
        for &id in self.host_shadow.keys() {
            if !self.table.residency(id).map(|r| r.is_peer()).unwrap_or(false) {
                return Err(format!("host shadow for non-peer block {id:?}"));
            }
        }
        for &id in self.compressed.keys() {
            if !matches!(self.table.residency(id), Some(BlockResidency::Leased { .. })) {
                return Err(format!("compression tag on non-leased block {id:?}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::{HarvestConfig, MigConfig, PrefetchConfig, RevocationReason};
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::{NodeSpec, SimNode};
    use crate::moe::config::find_kv_model;

    const GIB: u64 = 1 << 30;

    fn hr() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    fn cfg(use_harvest: bool, cap: usize) -> KvConfig {
        KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap,
            use_harvest,
            host_backed_peer: false,
        }
    }

    fn peer_count(kv: &KvOffloadManager) -> usize {
        kv.table().count_by_residency().1
    }

    #[test]
    fn appends_fill_blocks_at_granularity() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 100), 0);
        let s = SeqId(1);
        for _ in 0..33 {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.table().seq_blocks(s).len(), 3, "33 tokens -> 3 blocks of 16");
        assert_eq!(kv.table().meta(kv.table().seq_blocks(s)[2]).unwrap().tokens, 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn eviction_to_peer_when_harvest_on() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(kv.stats.evictions_to_peer >= 2);
        assert_eq!(kv.stats.evictions_to_host, 0);
        let (_local, peer, host, dropped) = kv.table().count_by_residency();
        assert!(peer >= 2, "peer={peer} host={host} dropped={dropped}");
        kv.check_invariants().unwrap();
        // bytes actually moved GPU0 -> GPU1
        assert!(h.node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Gpu(1)) > 0);
    }

    #[test]
    fn eviction_to_host_when_harvest_off() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(false, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.evictions_to_host >= 2);
        assert!(h.node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Host) > 0);
        // host traffic is lease-addressed now: the monitor sees it
        assert!(h.monitor().demand_bytes_on_tier(MemoryTier::Host) > 0);
        assert!(h.live_bytes_on_tier(MemoryTier::Host) > 0, "host copies are leases");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn eviction_spills_to_cxl_before_host_when_attached() {
        // Peer full + CXL attached: the tier policy lands the batch on
        // the expander (faster than host) rather than host DRAM.
        let node = SimNode::new(NodeSpec::h100x2().with_cxl(64 * GIB));
        let mut h = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        h.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 80 * GIB));
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.evictions_to_cxl >= 2, "{:?}", kv.stats);
        assert_eq!(kv.stats.evictions_to_host, 0);
        assert!(kv.stats.peer_alloc_failures > 0, "off-peer spill is counted");
        // reloads come from the expander
        let first = kv.table().seq_blocks(s)[0];
        kv.access_block(&mut h, first);
        assert_eq!(kv.stats.cxl_reloads, 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reload_from_peer_faster_than_host() {
        let measure = |use_harvest: bool| {
            let mut h = hr();
            let mut kv = KvOffloadManager::new(cfg(use_harvest, 4), 0);
            let s = SeqId(1);
            for _ in 0..(16 * 6) {
                kv.append_token(&mut h, s);
            }
            // touch the first (evicted) block
            let first = kv.table().seq_blocks(s)[0];
            assert_ne!(kv.table().residency(first), Some(BlockResidency::Local));
            kv.access_block(&mut h, first);
            (kv.stats.clone(), kv, h)
        };
        let (harvest_stats, kv1, h1) = measure(true);
        let (host_stats, _, _) = measure(false);
        assert_eq!(harvest_stats.peer_reloads, 1);
        assert_eq!(host_stats.host_reloads, 1);
        assert!(
            harvest_stats.reload_ns < host_stats.reload_ns / 3,
            "peer reload {} should be much faster than host {}",
            harvest_stats.reload_ns,
            host_stats.reload_ns
        );
        kv1.check_invariants().unwrap();
        drop(h1);
    }

    #[test]
    fn revocation_drops_lossy_blocks_then_recompute() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        let peer_before = peer_count(&kv);
        assert!(peer_before > 0);
        h.revoke_peer(1, RevocationReason::TenantPressure);
        // pull model: the drops become visible at the next sync
        kv.sync(&mut h);
        assert_eq!(kv.drops_observed() as usize, peer_before);
        assert_eq!(kv.stats.revocation_drops as usize, peer_before);
        let (_, peer, _, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, peer_before);
        // accessing a dropped block recomputes
        let first = kv.table().seq_blocks(s)[0];
        let before = kv.stats.recomputes;
        kv.access_block(&mut h, first);
        assert_eq!(kv.stats.recomputes, before + 1);
        assert!(kv.stats.recompute_ns > 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn revocation_visible_without_explicit_sync() {
        // Entry points sync implicitly: no manual call needed as long as
        // the manager is used at all after the revocation.
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        h.revoke_peer(1, RevocationReason::TenantPressure);
        kv.access_seq(&mut h, s); // syncs, then recomputes dropped blocks
        assert!(kv.stats.recomputes > 0);
        assert_eq!(peer_count(&kv), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn host_backed_peer_falls_back_to_shadow_lease() {
        let mut h = hr();
        let mut c = cfg(true, 4);
        c.host_backed_peer = true;
        let mut kv = KvOffloadManager::new(c, 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(
            h.live_bytes_on_tier(MemoryTier::Host) > 0,
            "durable shadows are host-tier leases"
        );
        h.revoke_peer(1, RevocationReason::TenantPressure);
        kv.sync(&mut h);
        let (_, peer, host, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, 0, "durable blocks never drop");
        assert!(host >= 2, "shadow leases took over");
        // and the shadow actually serves the reload over PCIe
        let first = kv.table().seq_blocks(s)[0];
        kv.access_block(&mut h, first);
        assert!(kv.stats.host_reloads >= 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn demotion_keeps_blocks_reloadable_without_recompute() {
        // Pressure with demote_to_host: lossy peer blocks migrate to
        // host-tier leases instead of dropping — the §5.2 lossy path
        // stops paying recompute for pressure spikes.
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hc = HarvestConfig::for_node(2);
        hc.demote_to_host = true;
        let mut h = HarvestRuntime::new(node, hc);
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        let peer_before = peer_count(&kv);
        assert!(peer_before > 0);
        let now = h.node.clock.now();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1_000, 80 * GIB)]),
        );
        h.advance_to(now + 2_000);
        kv.sync(&mut h);
        assert_eq!(kv.stats.demotions as usize, peer_before);
        assert_eq!(kv.stats.revocation_drops, 0);
        let (_, peer, offgpu, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, 0, "nothing dropped: data moved, not lost");
        assert_eq!(offgpu, peer_before);
        // reload comes from host, not recompute
        let first = kv.table().seq_blocks(s)[0];
        kv.access_block(&mut h, first);
        assert_eq!(kv.stats.recomputes, 0);
        assert!(kv.stats.host_reloads >= 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn full_peer_falls_back_to_host_eviction() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut h = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        h.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 80 * GIB));
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.peer_alloc_failures > 0);
        assert!(kv.stats.evictions_to_host > 0, "graceful fallback to the host tier");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_local_batches_eviction_all_or_nothing() {
        // Peer capped below the batch: the vectored tier consultation
        // must spill the batch as a whole (no partial peer placement)
        // onto the host tier.
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hcfg = HarvestConfig::for_node(2);
        let c = cfg(true, 4);
        // room for exactly one block on the peer
        hcfg.mig[1] = MigConfig::CachePartition { bytes: c.block_bytes() + c.block_bytes() / 2 };
        let mut h = HarvestRuntime::new(node, hcfg);
        let mut kv = KvOffloadManager::new(c, 0);
        let s = SeqId(1);
        for _ in 0..(16 * 4) {
            kv.append_token(&mut h, s); // fills the pool, no eviction yet
        }
        assert_eq!(kv.stats.evictions_to_peer + kv.stats.evictions_to_host, 0);
        // need 3 free slots -> batch of 3 victims; only 1 would fit
        kv.reserve_local(&mut h, kv.cfg.local_capacity_blocks - 1);
        assert_eq!(kv.stats.evictions_to_peer, 0, "no partial placement");
        assert_eq!(kv.stats.evictions_to_host, 3, "whole batch rolled over to host");
        assert_eq!(h.live_bytes_on(1), 0, "nothing stuck on the peer");
        assert_eq!(kv.stats.peer_alloc_failures, 1, "one vectored consultation");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_local_admits_batch_to_peer_when_it_fits() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 4) {
            kv.append_token(&mut h, s);
        }
        kv.reserve_local(&mut h, 3);
        assert_eq!(kv.stats.evictions_to_peer, 3, "one vectored batch of 3");
        assert_eq!(kv.stats.evictions_to_host, 0);
        assert_eq!(h.live_bytes_on(1), 3 * kv.cfg.block_bytes());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn finish_seq_releases_all_leases() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(h.live_bytes_on(1) > 0);
        kv.finish_seq(&mut h, s);
        assert_eq!(h.live_bytes_on(1), 0, "harvest leases released");
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Host), 0);
        assert!(kv.table().is_empty());
        assert_eq!(kv.local_blocks(), 0);
    }

    #[test]
    fn access_seq_advances_clock_past_reloads() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 8) {
            kv.append_token(&mut h, s);
        }
        let t0 = h.node.clock.now();
        kv.access_seq(&mut h, s);
        assert!(h.node.clock.now() > t0, "reloads take time");
        // afterwards everything the pool can hold is local
        kv.check_invariants().unwrap();
    }

    /// 6 blocks in an 8-slot pool with the first two explicitly evicted
    /// to peer: room to prefetch without evicting anything.
    fn prefetch_setup(h: &mut HarvestRuntime) -> (KvOffloadManager, SeqId, BlockId, BlockId) {
        let mut kv =
            KvOffloadManager::new(cfg(true, 8), 0).with_prefetch(PrefetchConfig::default());
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(h, s);
        }
        let b0 = kv.table().seq_blocks(s)[0];
        let b1 = kv.table().seq_blocks(s)[1];
        kv.evict_block(h, b0);
        kv.evict_block(h, b1);
        assert!(kv.table().residency(b0).unwrap().is_peer());
        assert!(kv.table().residency(b1).unwrap().is_peer());
        // let the spill DMA complete so nothing below waits on it
        h.advance_to(h.node.clock.now() + 10_000_000);
        (kv, s, b0, b1)
    }

    #[test]
    fn prefetch_overlaps_reload_off_critical_path() {
        let mut h = hr();
        let (mut kv, s, b0, b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        assert_eq!(plan.len(), 2, "both peer blocks planned");
        let t0 = h.node.clock.now();
        let deadline = t0 + 1_000_000;
        let issued = kv.submit_prefetch(&mut h, &plan, deadline);
        assert_eq!(issued, 2);
        assert_eq!(h.node.clock.now(), t0, "background prefetch must not advance the clock");
        assert_eq!(kv.table().residency(b0), Some(BlockResidency::Local));
        assert_eq!(kv.table().residency(b1), Some(BlockResidency::Local));
        kv.check_invariants().unwrap();
        // the consumed source leases stay alive until their copies end
        assert_eq!(h.live_bytes_on(1), 2 * kv.cfg.block_bytes(), "deferred release");
        // once the background copies complete, access is pure hit: no stall
        h.advance_to(deadline);
        let t1 = h.node.clock.now();
        kv.access_seq(&mut h, s);
        assert_eq!(h.node.clock.now(), t1, "prefetched blocks reload without stall");
        assert_eq!(h.live_bytes_on(1), 0, "matured source leases released at sync");
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.issued, 2);
        assert_eq!(pf.hits, 2);
        assert_eq!(pf.late, 0);
        assert_eq!(kv.stats.peer_reloads, 0, "no demand reload was needed");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn late_prefetch_is_counted_and_still_bounded_by_copy_end() {
        let mut h = hr();
        let (mut kv, s, _b0, _b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        let t0 = h.node.clock.now();
        kv.submit_prefetch(&mut h, &plan, t0 + 1_000_000);
        // consume immediately, before the background copies finish
        kv.access_seq(&mut h, s);
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.late, 2, "used before arrival");
        assert_eq!(pf.hits, 0);
        assert!(h.node.clock.now() > t0, "partial stall: wait out the residual copy");
        assert!(h.node.clock.now() <= t0 + 1_000_000);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn revocation_between_plan_and_submit_never_reads_stale_lease() {
        let mut h = hr();
        let (mut kv, s, b0, b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        assert_eq!(plan.len(), 2);
        // the race: peer revokes everything after the plan snapshot
        h.revoke_peer(1, RevocationReason::TenantPressure);
        let issued = kv.submit_prefetch(&mut h, &plan, u64::MAX);
        assert_eq!(issued, 0, "stale plan entries are skipped, not read");
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.stale_plans, 2);
        assert_eq!(pf.issued, 0);
        // lossy blocks dropped by the revocation stay dropped
        assert_eq!(kv.table().residency(b0), Some(BlockResidency::Dropped));
        assert_eq!(kv.table().residency(b1), Some(BlockResidency::Dropped));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn unused_prefetch_counts_as_waste() {
        let mut h = hr();
        let (mut kv, s, _b0, _b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        kv.submit_prefetch(&mut h, &plan, h.node.clock.now() + 1_000_000);
        // the sequence finishes before ever touching the prefetched blocks
        kv.finish_seq(&mut h, s);
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.wasted, 2);
        assert_eq!(pf.bytes_wasted, 2 * kv.cfg.block_bytes());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefetch_yields_to_demand_traffic_and_evicts_nothing() {
        let mut h = hr();
        let (mut kv, s, _b0, _b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        let local_before = kv.local_blocks();
        // demand traffic occupies the reload link (peer -> compute)
        h.node.copy(DeviceId::Gpu(1), DeviceId::Gpu(0), 256 * (1 << 20), None);
        let issued = kv.submit_prefetch(&mut h, &plan, u64::MAX);
        assert_eq!(issued, 0, "prefetch must never queue behind demand traffic");
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.yielded, 2);
        assert_eq!(kv.local_blocks(), local_before, "a yielded prefetch evicts nothing");
        kv.check_invariants().unwrap();
    }

    /// Harvest mode with the peer full for the first 1 ms: two blocks
    /// evicted in that window spill to host-tier leases, then the
    /// pressure clears and the peer opens up — the promotion setup.
    fn promotion_setup(h: &mut HarvestRuntime) -> (KvOffloadManager, SeqId, BlockId, BlockId) {
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 80 * GIB), (1_000_000, 0)]),
        );
        let mut kv =
            KvOffloadManager::new(cfg(true, 8), 0).with_prefetch(PrefetchConfig::default());
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(h, s);
        }
        let b0 = kv.table().seq_blocks(s)[0];
        let b1 = kv.table().seq_blocks(s)[1];
        kv.evict_block(h, b0);
        kv.evict_block(h, b1);
        assert_eq!(kv.table().residency(b0).unwrap().tier(), Some(MemoryTier::Host));
        assert_eq!(kv.table().residency(b1).unwrap().tier(), Some(MemoryTier::Host));
        // pressure clears; spill copies settle
        h.advance_to(h.node.clock.now() + 50_000_000);
        (kv, s, b0, b1)
    }

    #[test]
    fn promotion_migrates_host_blocks_to_peer_in_background() {
        // Blocks evicted to host while the peer was full get promoted
        // back toward peer HBM when the planner predicts their sequence
        // will decode and peer capacity has opened up.
        let mut h = hr();
        let (mut kv, s, b0, b1) = promotion_setup(&mut h);
        let t0 = h.node.clock.now();
        let promoted = kv.promote_blocks(&mut h, &[s], t0 + 10_000_000);
        assert_eq!(promoted, 2);
        assert_eq!(h.node.clock.now(), t0, "promotion is background work");
        assert!(kv.table().residency(b0).unwrap().is_peer(), "lease migrated to peer");
        assert!(kv.table().residency(b1).unwrap().is_peer());
        assert_eq!(kv.stats.promotions, 2);
        assert_eq!(h.live_bytes_on(1), 2 * kv.cfg.block_bytes());
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Host), 0, "host bytes released");
        kv.check_invariants().unwrap();
        // the eventual reload rides NVLink and counts a promotion hit
        h.advance_to(t0 + 10_000_000);
        kv.access_seq(&mut h, s);
        assert_eq!(kv.stats.peer_reloads, 2);
        assert_eq!(kv.stats.host_reloads, 0);
        assert_eq!(kv.stats.promotion_hits, 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn promotion_yields_when_link_busy_and_never_runs_for_vanilla() {
        let mut h = hr();
        let (mut kv, s, b0, _b1) = promotion_setup(&mut h);
        // demand traffic owns the host->peer link: promotion must yield
        h.node.copy(DeviceId::Host, DeviceId::Gpu(1), 1 << 30, None);
        let promoted = kv.promote_blocks(&mut h, &[s], u64::MAX);
        assert_eq!(promoted, 0);
        assert_eq!(kv.table().residency(b0).unwrap().tier(), Some(MemoryTier::Host));
        kv.check_invariants().unwrap();
        // and the vanilla-vLLM baseline never touches the peer tier
        let mut h2 = hr();
        let mut vanilla =
            KvOffloadManager::new(cfg(false, 8), 0).with_prefetch(PrefetchConfig::default());
        let s2 = SeqId(2);
        for _ in 0..(16 * 6) {
            vanilla.append_token(&mut h2, s2);
        }
        let v0 = vanilla.table().seq_blocks(s2)[0];
        vanilla.evict_block(&mut h2, v0);
        h2.advance_to(h2.node.clock.now() + 50_000_000);
        assert_eq!(vanilla.promote_blocks(&mut h2, &[s2], u64::MAX), 0);
        assert_eq!(
            vanilla.table().residency(v0).unwrap().tier(),
            Some(MemoryTier::Host),
            "use_harvest off: promotion must not move blocks to peer HBM"
        );
        assert_eq!(h2.live_bytes_on(1), 0);
        vanilla.check_invariants().unwrap();
    }

    #[test]
    fn age_ladder_steps_blocks_down_to_ssd_and_back_without_recompute() {
        let node = SimNode::new(NodeSpec::h100x2().with_ssd(64 * GIB));
        let mut h = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        let mut kv = KvOffloadManager::new(cfg(true, 8), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        let b0 = kv.table().seq_blocks(s)[0];
        kv.evict_block(&mut h, b0);
        assert!(kv.table().residency(b0).unwrap().is_peer());

        // Rung 1 (after the spill copy matures): peer -> host.
        h.advance_to(h.node.clock.now() + 50_000_000);
        assert_eq!(kv.age_idle_blocks(&mut h, 1_000_000, 50), 1);
        assert_eq!(kv.table().residency(b0).unwrap().tier(), Some(MemoryTier::Host));

        // Rung 2: compress in place — half the host bytes, no movement.
        h.advance_to(h.node.clock.now() + 50_000_000);
        assert_eq!(kv.age_idle_blocks(&mut h, 1_000_000, 50), 1);
        assert_eq!(kv.stats.compressions, 1);
        assert_eq!(kv.compressed_blocks().count(), 1);
        assert_eq!(
            h.live_bytes_on_tier(MemoryTier::Host),
            kv.cfg.block_bytes() * 50 / 100
        );

        // Rung 3: compressed host copy pages out to the SSD arena.
        h.advance_to(h.node.clock.now() + 50_000_000);
        assert_eq!(kv.age_idle_blocks(&mut h, 1_000_000, 50), 1);
        assert_eq!(kv.table().residency(b0).unwrap().tier(), Some(MemoryTier::Ssd));
        assert_eq!(h.pager().mapped_bytes(), h.node.ssd.used(), "page table balances");
        assert!(h.node.ssd.used() > 0);

        // Bottom of the ladder: nothing left to step.
        h.advance_to(h.node.clock.now() + 1_000_000_000);
        assert_eq!(kv.age_idle_blocks(&mut h, 1_000_000, 50), 0);
        kv.check_invariants().unwrap();

        // The way back: one staged SSD reload plus the modeled
        // decompression cost — and zero recomputes.
        kv.access_block(&mut h, b0);
        assert_eq!(kv.table().residency(b0), Some(BlockResidency::Local));
        assert_eq!(kv.stats.recomputes, 0);
        assert_eq!(kv.stats.ssd_reloads, 1);
        assert!(kv.stats.bytes_from_ssd > 0);
        assert!(kv.stats.decompress_ns > 0, "compressed copy pays reconstruction");
        assert_eq!(kv.compressed_blocks().count(), 0, "tag cleared on reload");
        assert_eq!(h.pager().mapped_bytes(), 0, "SSD pages released");
        assert_eq!(h.node.ssd.used(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn pressure_ladder_compresses_then_demotes_and_reload_pays_decompression() {
        // compress_before_demote: every peer victim is first shrunk in
        // place; the tenant wants *all* of HBM, so the shrunken copies
        // still demote to host — with their compression tags riding
        // along. Nothing is ever dropped.
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hc = HarvestConfig::for_node(2);
        hc.demote_to_host = true;
        hc.compress_before_demote = true;
        let mut h = HarvestRuntime::new(node, hc);
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        let peer_before = peer_count(&kv);
        assert!(peer_before > 0);
        let now = h.node.clock.now();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1_000, 80 * GIB)]),
        );
        h.advance_to(now + 2_000);
        kv.sync(&mut h);
        assert_eq!(kv.stats.compressions as usize, peer_before);
        assert_eq!(kv.stats.demotions as usize, peer_before);
        assert_eq!(kv.stats.revocation_drops, 0);
        assert_eq!(kv.compressed_blocks().count(), peer_before);
        // reload rides host DMA plus decompression — never recompute
        let first = kv.table().seq_blocks(s)[0];
        kv.access_block(&mut h, first);
        assert_eq!(kv.stats.recomputes, 0);
        assert!(kv.stats.host_reloads >= 1);
        assert!(kv.stats.decompress_ns > 0);
        assert_eq!(kv.compressed_blocks().count(), peer_before - 1, "tag cleared");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 3), 0);
        for seq in 0..4 {
            for _ in 0..(16 * 2) {
                kv.append_token(&mut h, SeqId(seq));
            }
        }
        assert!(kv.local_blocks() <= 3);
        kv.check_invariants().unwrap();
    }
}
