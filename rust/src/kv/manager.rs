//! `KvOffloadManager` + per-device `OffloadingHandler` (§5.2).
//!
//! "We introduce a KVOffloadManager into vLLM's KV manager, which serves
//! as a pluggable control interface for implementing Harvest's
//! policy-driven allocation, migration, and revocation semantics. ...
//! For each device, Harvest extends vLLM with an OffloadingHandler
//! responsible for executing data movement operations."
//!
//! Flow:
//! * Decode appends tokens; full local pool ⇒ the eviction policy picks
//!   victims and the handler migrates them out — to peer HBM via a
//!   vectored `alloc_many` lease when available (Harvest mode), else to
//!   host DRAM (vanilla-vLLM mode). Multi-block admission is
//!   all-or-nothing: one policy consultation per batch, and a partial
//!   placement failure rolls back to the host path for the whole batch.
//! * Decode touching a non-local block issues a reload through the
//!   handler: peer → NVLink, host → PCIe, `Dropped` → recompute (or
//!   whichever is cheaper per [`RecomputeModel`]).
//! * Peer revocations arrive as pull-model events: every public entry
//!   point first drains the manager's session queue ([`KvOffloadManager::sync`])
//!   and drops lossy blocks via the unified table — the §5.2 callback
//!   semantics without any shared mutable state (the pre-lease design
//!   needed reference-counted interior mutability so push callbacks
//!   could reach the table from inside the runtime).

use super::block::{BlockId, SeqId};
use super::block_table::{BlockResidency, UnifiedBlockTable};
use super::eviction::{EvictionPolicy, Lru};
use super::recompute::RecomputeModel;
use crate::harvest::api::{AllocHints, Durability, LeaseId};
use crate::harvest::prefetch::{PrefetchConfig, PrefetchPlanner, PrefetchStats};
use crate::harvest::session::{HarvestSession, Lease, Transfer};
use crate::harvest::{HarvestRuntime, PayloadKind};
use crate::memsim::{DeviceId, Ns};
use crate::moe::config::KvModel;
use std::collections::{BTreeMap, BTreeSet};

/// DMA descriptor granularity for KV reloads: blocks are batched into
/// chunks of this size (scattered block copies cannot use one huge
/// contiguous DMA; ~4 MiB descriptors reproduce the Fig. 7 GPU:CPU
/// latency ratio band — see DESIGN.md §Calibration).
pub const RELOAD_CHUNK_BYTES: u64 = 4 * 1024 * 1024;

/// Configuration of the KV offload manager.
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    pub model: &'static KvModel,
    /// Tokens per logical block (vLLM default 16).
    pub block_tokens: u32,
    /// Local KV pool capacity, in blocks.
    pub local_capacity_blocks: usize,
    /// Harvest mode: evict to peer HBM when possible. Off = vanilla vLLM
    /// (evict to host only) — the Fig. 7 baseline.
    pub use_harvest: bool,
    /// Also materialise a host copy when evicting to peer (durable mode;
    /// default off — §5.2 treats peer KV as lossy).
    pub host_backed_peer: bool,
}

impl KvConfig {
    pub fn block_bytes(&self) -> u64 {
        self.block_tokens as u64 * self.model.kv_bytes_per_token()
    }
}

/// Cumulative statistics.
#[derive(Debug, Clone, Default)]
pub struct KvStats {
    pub appends: u64,
    pub local_hits: u64,
    pub peer_reloads: u64,
    pub host_reloads: u64,
    pub recomputes: u64,
    pub evictions_to_peer: u64,
    pub evictions_to_host: u64,
    pub peer_alloc_failures: u64,
    pub revocation_drops: u64,
    pub bytes_from_peer: u64,
    pub bytes_from_host: u64,
    pub reload_ns: Ns,
    pub recompute_ns: Ns,
}

impl KvStats {
    pub fn reloads(&self) -> u64 {
        self.peer_reloads + self.host_reloads + self.recomputes
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.local_hits + self.reloads();
        if total == 0 {
            0.0
        } else {
            self.local_hits as f64 / total as f64
        }
    }
}

/// Executes data movement for one device pair (§5.2). Thin by design:
/// policy lives in the manager; the handler only knows how to move KV
/// bytes (batched into [`RELOAD_CHUNK_BYTES`] descriptors through the
/// unified [`Transfer`] builder).
#[derive(Debug, Clone, Copy)]
pub struct OffloadingHandler {
    pub compute_gpu: usize,
}

impl OffloadingHandler {
    /// Transfer `bytes` of KV between tiers; returns the copy event.
    pub fn transfer(
        &self,
        hr: &mut HarvestRuntime,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
    ) -> crate::memsim::CopyEvent {
        let report = Transfer::new()
            .chunked(RELOAD_CHUNK_BYTES)
            .raw(src, dst, bytes)
            .submit(hr)
            .expect("raw transfers cannot go stale");
        report.events[0]
    }
}

/// The manager. Owns its block table and eviction policy directly — the
/// pull-model event API needs no shared state with the runtime.
pub struct KvOffloadManager {
    pub cfg: KvConfig,
    table: UnifiedBlockTable,
    policy: Box<dyn EvictionPolicy>,
    handler: OffloadingHandler,
    recompute: RecomputeModel,
    /// Session opened lazily on first runtime interaction (the manager
    /// is constructed before it ever sees the runtime).
    session: Option<HarvestSession>,
    /// Live peer leases, keyed by id; the table's `Peer` entries mirror
    /// this map exactly.
    leases: BTreeMap<LeaseId, Lease>,
    /// Deadline-aware prefetch admission control + outcome ledger
    /// (enabled via [`KvOffloadManager::with_prefetch`]).
    planner: Option<PrefetchPlanner>,
    /// Blocks brought local by a background prefetch and not yet used:
    /// block → completion time of the background copy. A use before
    /// completion is a *late* (shortened) stall; eviction or sequence
    /// finish before use is *waste*.
    pending_prefetch: BTreeMap<BlockId, Ns>,
    /// Source leases of issued prefetches, held until their background
    /// copy completes (lease, copy end). Releasing earlier would free
    /// peer memory an in-flight read still touches; releasing eagerly
    /// would block on the drain barrier. `sync` releases matured
    /// entries, when the drain is a guaranteed no-op.
    deferred_release: Vec<(Lease, Ns)>,
    pub stats: KvStats,
}

/// One candidate produced by [`KvOffloadManager::plan_prefetch`]: a
/// non-local block a predicted-to-decode sequence will touch. Plans are
/// snapshots — [`KvOffloadManager::submit_prefetch`] revalidates each
/// entry against current residency, so a revocation landing between plan
/// and submit is skipped, never read.
#[derive(Debug, Clone, Copy)]
pub struct PlannedPrefetch {
    pub block: BlockId,
    pub bytes: u64,
}

impl KvOffloadManager {
    pub fn new(cfg: KvConfig, compute_gpu: usize) -> Self {
        Self::with_policy(cfg, compute_gpu, Box::new(Lru::new()))
    }

    pub fn with_policy(
        cfg: KvConfig,
        compute_gpu: usize,
        policy: Box<dyn EvictionPolicy>,
    ) -> Self {
        Self {
            cfg,
            table: UnifiedBlockTable::new(),
            policy,
            handler: OffloadingHandler { compute_gpu },
            recompute: RecomputeModel::new(cfg.model.active_params_b),
            session: None,
            leases: BTreeMap::new(),
            planner: None,
            pending_prefetch: BTreeMap::new(),
            deferred_release: Vec::new(),
            stats: KvStats::default(),
        }
    }

    /// Enable the deadline-aware prefetch pipeline: callers (the sim
    /// engine) can then [`KvOffloadManager::plan_prefetch`] /
    /// [`KvOffloadManager::submit_prefetch`] predicted sequences so their
    /// reloads overlap decode compute instead of stalling it.
    pub fn with_prefetch(mut self, cfg: PrefetchConfig) -> Self {
        self.planner = Some(PrefetchPlanner::new(cfg));
        self
    }

    pub fn prefetch_enabled(&self) -> bool {
        self.planner.is_some()
    }

    /// The prefetch outcome ledger (None when prefetch is disabled).
    pub fn prefetch_stats(&self) -> Option<&PrefetchStats> {
        self.planner.as_ref().map(|p| p.stats())
    }

    pub fn table(&self) -> &UnifiedBlockTable {
        &self.table
    }

    pub fn local_blocks(&self) -> usize {
        self.policy.len()
    }

    fn session(&mut self, hr: &mut HarvestRuntime) -> HarvestSession {
        *self
            .session
            .get_or_insert_with(|| HarvestSession::open(hr, PayloadKind::KvBlock))
    }

    /// Drain pending revocation events and repair the block table: the
    /// tick-boundary pull that replaces the old push callbacks. Every
    /// public entry point calls this first, so the manager's view is
    /// current before it makes placement decisions; tests and engines
    /// may also call it directly after advancing virtual time.
    pub fn sync(&mut self, hr: &mut HarvestRuntime) {
        let Some(session) = self.session else { return };
        // Release prefetch source leases whose background copy has
        // completed: the drain inside `release` is a no-op now, so this
        // never blocks. Leases revoked in the meantime release as a
        // harmless StaleLease error (the runtime already freed them,
        // after draining the tagged copy per §3.2).
        if !self.deferred_release.is_empty() {
            let now = hr.node.clock.now();
            let deferred = std::mem::take(&mut self.deferred_release);
            for (lease, ready) in deferred {
                if ready <= now {
                    let _ = session.release(hr, lease);
                } else {
                    self.deferred_release.push((lease, ready));
                }
            }
        }
        for ev in session.drain_revocations(hr) {
            // The runtime already drained DMA, invalidated the placement
            // and freed the bytes; we only repair our own indexes.
            self.leases.remove(&ev.lease);
            self.stats.revocation_drops += 1;
            if ev.durability == Durability::HostBacked {
                // A host copy exists: fall back to it.
                if let Some(b) = self.table.drop_by_handle(ev.lease) {
                    self.table.set_residency(b, BlockResidency::Host);
                }
            } else {
                self.table.drop_by_handle(ev.lease);
            }
        }
    }

    /// Append one token to `seq`, paging in a new block when the last one
    /// fills. May evict under pressure. Returns the block written.
    pub fn append_token(&mut self, hr: &mut HarvestRuntime, seq: SeqId) -> BlockId {
        self.sync(hr);
        self.stats.appends += 1;
        let now = hr.node.clock.now();
        let last = self.table.seq_blocks(seq).last().copied().and_then(|id| {
            let m = self.table.meta(id)?;
            (m.tokens < self.cfg.block_tokens).then_some(id)
        });
        let id = match last {
            // The tail block must be local to be appended to.
            Some(id) if self.table.residency(id) == Some(BlockResidency::Local) => id,
            Some(id) => {
                self.ensure_local(hr, id);
                id
            }
            None => {
                self.make_room(hr, 1);
                let id = self.table.new_block(seq, now);
                self.policy.insert(id, now);
                id
            }
        };
        let m = self.table.meta_mut(id).expect("live block");
        m.tokens += 1;
        m.touch(now);
        self.policy.touch(id, now);
        id
    }

    /// Decode touches every block of `seq`: reload anything non-local.
    /// Returns when the sequence is fully resident (virtual time may
    /// advance past reload DMA and recompute).
    pub fn access_seq(&mut self, hr: &mut HarvestRuntime, seq: SeqId) -> Ns {
        self.sync(hr);
        let ids: Vec<BlockId> = self.table.seq_blocks(seq).to_vec();
        let mut ready = hr.node.clock.now();
        for id in ids {
            ready = ready.max(self.access_block(hr, id));
        }
        hr.node.clock.advance_to(ready);
        ready
    }

    /// Touch one block; reload/recompute if non-local. Returns readiness.
    pub fn access_block(&mut self, hr: &mut HarvestRuntime, id: BlockId) -> Ns {
        self.sync(hr);
        let now = hr.node.clock.now();
        let res = self.table.residency(id).expect("live block");
        let ready = match res {
            BlockResidency::Local => {
                self.stats.local_hits += 1;
                match self.pending_prefetch.remove(&id) {
                    // A prefetched block: on-time arrival means the whole
                    // reload left the critical path; a late arrival still
                    // shortens the stall to the residual copy time.
                    Some(ready_at) => {
                        if let Some(p) = self.planner.as_mut() {
                            p.mark_used(id.0, now);
                        }
                        ready_at.max(now)
                    }
                    None => now,
                }
            }
            _ => self.ensure_local(hr, id),
        };
        self.policy.touch(id, hr.node.clock.now());
        if let Some(m) = self.table.meta_mut(id) {
            m.touch(hr.node.clock.now());
        }
        ready
    }

    /// Bring a block into the local pool (reload or recompute), evicting
    /// to make room first. Returns the readiness time.
    fn ensure_local(&mut self, hr: &mut HarvestRuntime, id: BlockId) -> Ns {
        self.make_room(hr, 1);
        let res = self.table.residency(id).expect("live block");
        let bytes = self.cfg.block_bytes();
        let ready = match res {
            BlockResidency::Local => hr.node.clock.now(),
            BlockResidency::Peer { handle, .. } => {
                // Post-sync, every Peer entry is backed by a live lease.
                let lease = self.leases.remove(&handle).expect("peer block has live lease");
                let session = self.session.expect("lease implies session");
                let report = Transfer::new()
                    .chunked(RELOAD_CHUNK_BYTES)
                    .fetch(&lease, self.handler.compute_gpu)
                    .submit(hr)
                    .expect("live lease");
                // The peer copy is consumed: release the lease (ordered
                // free; drains the fetch we just tagged).
                session.release(hr, lease).expect("live lease");
                self.stats.peer_reloads += 1;
                self.stats.bytes_from_peer += bytes;
                self.stats.reload_ns += report.events[0].duration();
                report.end
            }
            BlockResidency::Host => {
                let ev = self.handler.transfer(
                    hr,
                    DeviceId::Host,
                    DeviceId::Gpu(self.handler.compute_gpu),
                    bytes,
                );
                self.stats.host_reloads += 1;
                self.stats.bytes_from_host += bytes;
                self.stats.reload_ns += ev.duration();
                ev.end
            }
            BlockResidency::Dropped => {
                // Recompute the block's tokens (prefill replay).
                let tokens = self.table.meta(id).map(|m| m.tokens).unwrap_or(0);
                let dur = self.recompute.recompute_ns(tokens as u64);
                self.stats.recomputes += 1;
                self.stats.recompute_ns += dur;
                hr.node.clock.now() + dur
            }
        };
        self.table.set_residency(id, BlockResidency::Local);
        self.policy.insert(id, hr.node.clock.now());
        ready
    }

    /// Evict until `headroom` local slots are free. Victims are gathered
    /// first and offloaded as one batch, so multi-block pressure costs
    /// one vectored admission instead of N scalar ones.
    ///
    /// Blocks whose background prefetch copy is still in flight are
    /// skipped as victims while any alternative exists — spilling them
    /// would read local bytes the copy has not finished writing. If
    /// *only* such blocks remain, the oldest one's copy is waited out
    /// (a demand-path stall, correctness over overlap) and it is
    /// evicted normally.
    fn make_room(&mut self, hr: &mut HarvestRuntime, headroom: usize) {
        let now = hr.node.clock.now();
        let mut victims = Vec::new();
        let mut inflight: Vec<BlockId> = Vec::new();
        while self.policy.len() + inflight.len() + headroom > self.cfg.local_capacity_blocks {
            match self.policy.victim() {
                Some(victim) => {
                    self.policy.remove(victim);
                    if self.pending_prefetch.get(&victim).is_some_and(|&r| r > now) {
                        inflight.push(victim);
                        continue;
                    }
                    victims.push(victim);
                }
                None => {
                    let Some(victim) = inflight.pop() else { break };
                    let ready = self.pending_prefetch.get(&victim).copied().unwrap_or(now);
                    hr.node.clock.advance_to(ready);
                    victims.push(victim);
                }
            }
        }
        for id in inflight {
            self.policy.insert(id, now);
        }
        self.offload_batch(hr, victims);
    }

    /// Pre-admission hook: guarantee `blocks` free local slots (e.g.
    /// before prefilling a prompt), evicting one vectored batch if the
    /// pool is short. Clamped to the pool size.
    pub fn reserve_local(&mut self, hr: &mut HarvestRuntime, blocks: usize) {
        self.sync(hr);
        self.make_room(hr, blocks.min(self.cfg.local_capacity_blocks));
    }

    // -- deadline-aware prefetch ------------------------------------------

    /// Phase 1 of a prefetch round: name every non-local block the
    /// predicted `seqs` (from [`crate::server::scheduler::Scheduler::lookahead`])
    /// would have to reload, deduplicated, in prediction order. Moves
    /// nothing and issues nothing. `Dropped` blocks are excluded —
    /// recompute is not DMA and cannot be overlapped by this pipeline.
    pub fn plan_prefetch(
        &mut self,
        hr: &mut HarvestRuntime,
        seqs: &[SeqId],
    ) -> Vec<PlannedPrefetch> {
        self.sync(hr);
        if self.planner.is_none() {
            return Vec::new();
        }
        let bytes = self.cfg.block_bytes();
        let mut seen: BTreeSet<BlockId> = BTreeSet::new();
        let mut out = Vec::new();
        for &seq in seqs {
            for &id in self.table.seq_blocks(seq) {
                if !seen.insert(id) {
                    continue;
                }
                if matches!(
                    self.table.residency(id),
                    Some(BlockResidency::Peer { .. }) | Some(BlockResidency::Host)
                ) {
                    out.push(PlannedPrefetch { block: id, bytes });
                }
            }
        }
        out
    }

    /// Phase 2: issue the planned reloads that are still valid and that
    /// the planner admits, as background transfers completing by
    /// `deadline` (the start of the next decode step — the contract that
    /// keeps prefetch traffic from ever delaying a demand fetch).
    ///
    /// Every entry is revalidated against *current* residency first: a
    /// revocation arriving between plan and submit turned the block
    /// `Dropped` (or host-backed), so the stale peer lease is never
    /// read. Returns how many background reloads were issued.
    pub fn submit_prefetch(
        &mut self,
        hr: &mut HarvestRuntime,
        plan: &[PlannedPrefetch],
        deadline: Ns,
    ) -> usize {
        if self.planner.is_none() || plan.is_empty() {
            return 0;
        }
        // Fold in any revocations that raced in since the plan was made.
        self.sync(hr);
        let compute = self.handler.compute_gpu;
        let dst = DeviceId::Gpu(compute);
        let mut issued = 0;
        for p in plan {
            // Revalidate: the block may have been revoked (Dropped),
            // reloaded by a demand fetch (Local), or freed (None) since
            // the plan snapshot.
            let src = match self.table.residency(p.block) {
                Some(BlockResidency::Peer { handle, peer }) => {
                    if hr.node.dma.tag_busy_until(handle.0) > hr.node.clock.now() {
                        // The spill populate that created this peer copy
                        // is itself still in flight: fetching now would
                        // read unwritten bytes, and releasing the lease
                        // would block on the drain barrier. Skip; the
                        // next round can pick it up.
                        self.planner.as_mut().unwrap().mark_stale_plan();
                        continue;
                    }
                    DeviceId::Gpu(peer)
                }
                Some(BlockResidency::Host) => DeviceId::Host,
                _ => {
                    self.planner.as_mut().unwrap().mark_stale_plan();
                    continue;
                }
            };
            // Admission before any movement: a yielded prefetch must not
            // trigger an eviction either. Admit against the scattered
            // cost the reload will actually pay.
            let admitted = self.planner.as_mut().unwrap().admit(
                &hr.node.topo,
                src,
                dst,
                p.bytes,
                Some(RELOAD_CHUNK_BYTES),
                deadline,
            );
            if !admitted {
                continue;
            }
            self.make_room(hr, 1);
            // make_room can only evict *local* blocks; `p.block` is not
            // local, so the source we validated above is untouched.
            let ready_at = match self.table.residency(p.block).expect("validated above") {
                BlockResidency::Peer { handle, .. } => {
                    let lease =
                        self.leases.remove(&handle).expect("post-sync peer block has live lease");
                    match Transfer::new()
                        .chunked(RELOAD_CHUNK_BYTES)
                        .background()
                        .fetch(&lease, compute)
                        .submit(hr)
                    {
                        Ok(report) => {
                            // The peer copy is being consumed. The lease
                            // stays alive until the tagged background
                            // copy completes (its bytes must not be
                            // reallocated under an in-flight read);
                            // `sync` releases it once matured, when the
                            // drain barrier is a guaranteed no-op.
                            // Bandwidth is accounted in the planner's
                            // ledger only — KvStats' bytes_from_* stay
                            // demand-reload counters.
                            self.deferred_release.push((lease, report.end));
                            report.end
                        }
                        Err(_) => {
                            // Unreachable single-threaded (nothing revokes
                            // between the sync above and here), but fail
                            // closed: treat the lease as already dead.
                            self.table.drop_by_handle(handle);
                            drop(lease);
                            self.planner.as_mut().unwrap().mark_stale_plan();
                            continue;
                        }
                    }
                }
                BlockResidency::Host => {
                    let report = Transfer::new()
                        .chunked(RELOAD_CHUNK_BYTES)
                        .raw(DeviceId::Host, dst, p.bytes)
                        .submit(hr)
                        .expect("raw transfers cannot go stale");
                    report.end
                }
                _ => unreachable!("validated above"),
            };
            self.table.set_residency(p.block, BlockResidency::Local);
            self.policy.insert(p.block, hr.node.clock.now());
            self.pending_prefetch.insert(p.block, ready_at);
            let planner = self.planner.as_mut().unwrap();
            planner.record_issued(p.block.0, p.bytes, ready_at, deadline);
            planner.mark_link_busy(src, dst, ready_at);
            issued += 1;
        }
        issued
    }

    /// Plan + submit in one call — the engine's per-step hook.
    pub fn prefetch_seqs(
        &mut self,
        hr: &mut HarvestRuntime,
        seqs: &[SeqId],
        deadline: Ns,
    ) -> usize {
        let plan = self.plan_prefetch(hr, seqs);
        self.submit_prefetch(hr, &plan, deadline)
    }

    /// Cancel pending prefetches for `seq` (scheduler preemption or
    /// cancellation): their blocks stay local, but the outcome ledger
    /// records the bandwidth as wasted if they are never used.
    pub fn cancel_prefetch_seq(&mut self, seq: SeqId) {
        let Some(planner) = self.planner.as_mut() else { return };
        for &id in self.table.seq_blocks(seq) {
            if self.pending_prefetch.remove(&id).is_some() {
                planner.mark_canceled(id.0);
            }
        }
    }

    /// Migrate one local block out (§5.2 "workers similarly request block
    /// evictions, allowing handlers to migrate blocks out of local HBM").
    pub fn evict_block(&mut self, hr: &mut HarvestRuntime, id: BlockId) {
        self.sync(hr);
        debug_assert_eq!(self.table.residency(id), Some(BlockResidency::Local));
        self.policy.remove(id);
        self.offload_batch(hr, vec![id]);
    }

    /// Move `victims` (already detached from the eviction policy) out of
    /// local HBM: all-or-nothing into peer leases when Harvest is on and
    /// the batch fits, host DRAM otherwise.
    fn offload_batch(&mut self, hr: &mut HarvestRuntime, victims: Vec<BlockId>) {
        if victims.is_empty() {
            return;
        }
        // Evicting a block whose prefetch was never consumed: the
        // background bandwidth was wasted (misprediction or preemption).
        if let Some(planner) = self.planner.as_mut() {
            for id in &victims {
                if self.pending_prefetch.remove(id).is_some() {
                    planner.mark_canceled(id.0);
                }
            }
        }
        let bytes = self.cfg.block_bytes();
        if self.cfg.use_harvest {
            let session = self.session(hr);
            let hints = AllocHints {
                compute_gpu: Some(self.handler.compute_gpu),
                durability: if self.cfg.host_backed_peer {
                    Durability::HostBacked
                } else {
                    Durability::Lossy
                },
                ..Default::default()
            };
            let sizes = vec![bytes; victims.len()];
            match session.alloc_many(hr, &sizes, hints) {
                Ok(leases) => {
                    // One batched-DMA submission: local -> peer for every
                    // victim (plus durable host copies if configured).
                    let mut batch = Transfer::new().chunked(RELOAD_CHUNK_BYTES);
                    for lease in &leases {
                        batch =
                            batch.populate(lease, DeviceId::Gpu(self.handler.compute_gpu));
                        if self.cfg.host_backed_peer {
                            batch = batch.raw(
                                DeviceId::Gpu(self.handler.compute_gpu),
                                DeviceId::Host,
                                bytes,
                            );
                        }
                    }
                    batch.submit(hr).expect("fresh leases");
                    for (id, lease) in victims.into_iter().zip(leases) {
                        self.table.set_residency(
                            id,
                            BlockResidency::Peer { handle: lease.id(), peer: lease.peer() },
                        );
                        self.leases.insert(lease.id(), lease);
                        self.stats.evictions_to_peer += 1;
                    }
                    return;
                }
                Err(_) => {
                    // All-or-nothing rollback: no element of the batch
                    // landed on a peer; every victim takes the host path.
                    self.stats.peer_alloc_failures += 1;
                }
            }
        }
        // Vanilla vLLM path: evict to host DRAM over PCIe.
        for id in victims {
            self.handler.transfer(
                hr,
                DeviceId::Gpu(self.handler.compute_gpu),
                DeviceId::Host,
                bytes,
            );
            self.table.set_residency(id, BlockResidency::Host);
            self.stats.evictions_to_host += 1;
        }
    }

    /// Finish a sequence: release all its blocks (and any peer leases).
    pub fn finish_seq(&mut self, hr: &mut HarvestRuntime, seq: SeqId) {
        self.sync(hr);
        let removed = self.table.remove_seq(seq);
        for (id, res) in removed {
            self.policy.remove(id);
            if self.pending_prefetch.remove(&id).is_some() {
                // Prefetched for a sequence that finished before using it.
                if let Some(p) = self.planner.as_mut() {
                    p.mark_canceled(id.0);
                }
            }
            if let BlockResidency::Peer { handle, .. } = res {
                if let Some(lease) = self.leases.remove(&handle) {
                    let session = self.session.expect("lease implies session");
                    let _ = session.release(hr, lease);
                }
            }
        }
    }

    /// How many peer-revocation drops the event queue has delivered.
    pub fn drops_observed(&self) -> u64 {
        self.stats.revocation_drops
    }

    /// Consistency between policy membership, table residency, and the
    /// lease map.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.table.check_invariants()?;
        let local_in_table = self.table.count_by_residency().0;
        if local_in_table != self.policy.len() {
            return Err(format!(
                "policy tracks {} blocks, table says {} local",
                self.policy.len(),
                local_in_table
            ));
        }
        if self.policy.len() > self.cfg.local_capacity_blocks {
            return Err("local pool over capacity".into());
        }
        let peer_in_table = self.table.count_by_residency().1;
        if peer_in_table != self.leases.len() {
            return Err(format!(
                "table has {} peer blocks but manager holds {} leases",
                peer_in_table,
                self.leases.len()
            ));
        }
        for &id in self.pending_prefetch.keys() {
            if self.table.residency(id) != Some(BlockResidency::Local) {
                return Err(format!("pending prefetch for non-local block {id:?}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::{HarvestConfig, MigConfig, PrefetchConfig, RevocationReason};
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::{NodeSpec, SimNode};
    use crate::moe::config::find_kv_model;

    const GIB: u64 = 1 << 30;

    fn hr() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    fn cfg(use_harvest: bool, cap: usize) -> KvConfig {
        KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap,
            use_harvest,
            host_backed_peer: false,
        }
    }

    #[test]
    fn appends_fill_blocks_at_granularity() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 100), 0);
        let s = SeqId(1);
        for _ in 0..33 {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.table().seq_blocks(s).len(), 3, "33 tokens -> 3 blocks of 16");
        assert_eq!(kv.table().meta(kv.table().seq_blocks(s)[2]).unwrap().tokens, 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn eviction_to_peer_when_harvest_on() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(kv.stats.evictions_to_peer >= 2);
        assert_eq!(kv.stats.evictions_to_host, 0);
        let (_local, peer, host, dropped) = kv.table().count_by_residency();
        assert!(peer >= 2, "peer={peer} host={host} dropped={dropped}");
        kv.check_invariants().unwrap();
        // bytes actually moved GPU0 -> GPU1
        assert!(h.node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Gpu(1)) > 0);
    }

    #[test]
    fn eviction_to_host_when_harvest_off() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(false, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.evictions_to_host >= 2);
        assert!(h.node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Host) > 0);
    }

    #[test]
    fn reload_from_peer_faster_than_host() {
        let measure = |use_harvest: bool| {
            let mut h = hr();
            let mut kv = KvOffloadManager::new(cfg(use_harvest, 4), 0);
            let s = SeqId(1);
            for _ in 0..(16 * 6) {
                kv.append_token(&mut h, s);
            }
            // touch the first (evicted) block
            let first = kv.table().seq_blocks(s)[0];
            assert_ne!(kv.table().residency(first), Some(BlockResidency::Local));
            kv.access_block(&mut h, first);
            (kv.stats.clone(), kv, h)
        };
        let (harvest_stats, kv1, h1) = measure(true);
        let (host_stats, _, _) = measure(false);
        assert_eq!(harvest_stats.peer_reloads, 1);
        assert_eq!(host_stats.host_reloads, 1);
        assert!(
            harvest_stats.reload_ns < host_stats.reload_ns / 3,
            "peer reload {} should be much faster than host {}",
            harvest_stats.reload_ns,
            host_stats.reload_ns
        );
        kv1.check_invariants().unwrap();
        drop(h1);
    }

    #[test]
    fn revocation_drops_lossy_blocks_then_recompute() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        let peer_before = kv.table().count_by_residency().1;
        assert!(peer_before > 0);
        h.revoke_peer(1, RevocationReason::TenantPressure);
        // pull model: the drops become visible at the next sync
        kv.sync(&mut h);
        assert_eq!(kv.drops_observed() as usize, peer_before);
        assert_eq!(kv.stats.revocation_drops as usize, peer_before);
        let (_, peer, _, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, peer_before);
        // accessing a dropped block recomputes
        let first = kv.table().seq_blocks(s)[0];
        let before = kv.stats.recomputes;
        kv.access_block(&mut h, first);
        assert_eq!(kv.stats.recomputes, before + 1);
        assert!(kv.stats.recompute_ns > 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn revocation_visible_without_explicit_sync() {
        // Entry points sync implicitly: no manual call needed as long as
        // the manager is used at all after the revocation.
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        h.revoke_peer(1, RevocationReason::TenantPressure);
        kv.access_seq(&mut h, s); // syncs, then recomputes dropped blocks
        assert!(kv.stats.recomputes > 0);
        assert_eq!(kv.table().count_by_residency().1, 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn host_backed_peer_falls_back_to_host() {
        let mut h = hr();
        let mut c = cfg(true, 4);
        c.host_backed_peer = true;
        let mut kv = KvOffloadManager::new(c, 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        h.revoke_peer(1, RevocationReason::TenantPressure);
        kv.sync(&mut h);
        let (_, peer, host, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, 0, "durable blocks never drop");
        assert!(host >= 2);
    }

    #[test]
    fn full_peer_falls_back_to_host_eviction() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut h = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        h.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 80 * GIB));
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.peer_alloc_failures > 0);
        assert!(kv.stats.evictions_to_host > 0, "graceful fallback to vanilla path");
    }

    #[test]
    fn reserve_local_batches_eviction_all_or_nothing() {
        // Peer capped below the batch: the vectored admission must fail
        // as a whole (no partial peer placement) and every victim must
        // take the host path.
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hcfg = HarvestConfig::for_node(2);
        let c = cfg(true, 4);
        // room for exactly one block on the peer
        hcfg.mig[1] = MigConfig::CachePartition { bytes: c.block_bytes() + c.block_bytes() / 2 };
        let mut h = HarvestRuntime::new(node, hcfg);
        let mut kv = KvOffloadManager::new(c, 0);
        let s = SeqId(1);
        for _ in 0..(16 * 4) {
            kv.append_token(&mut h, s); // fills the pool, no eviction yet
        }
        assert_eq!(kv.stats.evictions_to_peer + kv.stats.evictions_to_host, 0);
        // need 3 free slots -> batch of 3 victims; only 1 would fit
        kv.reserve_local(&mut h, kv.cfg.local_capacity_blocks - 1);
        assert_eq!(kv.stats.evictions_to_peer, 0, "no partial placement");
        assert_eq!(kv.stats.evictions_to_host, 3, "whole batch rolled over to host");
        assert_eq!(h.live_bytes_on(1), 0, "rollback left nothing on the peer");
        assert_eq!(kv.stats.peer_alloc_failures, 1, "one vectored consultation");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_local_admits_batch_to_peer_when_it_fits() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 4) {
            kv.append_token(&mut h, s);
        }
        kv.reserve_local(&mut h, 3);
        assert_eq!(kv.stats.evictions_to_peer, 3, "one vectored batch of 3");
        assert_eq!(kv.stats.evictions_to_host, 0);
        assert_eq!(h.live_bytes_on(1), 3 * kv.cfg.block_bytes());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn finish_seq_releases_peer_leases() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(h.live_bytes_on(1) > 0);
        kv.finish_seq(&mut h, s);
        assert_eq!(h.live_bytes_on(1), 0, "harvest leases released");
        assert!(kv.table().is_empty());
        assert_eq!(kv.local_blocks(), 0);
    }

    #[test]
    fn access_seq_advances_clock_past_reloads() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 8) {
            kv.append_token(&mut h, s);
        }
        let t0 = h.node.clock.now();
        kv.access_seq(&mut h, s);
        assert!(h.node.clock.now() > t0, "reloads take time");
        // afterwards everything the pool can hold is local
        kv.check_invariants().unwrap();
    }

    /// 6 blocks in an 8-slot pool with the first two explicitly evicted
    /// to peer: room to prefetch without evicting anything.
    fn prefetch_setup(h: &mut HarvestRuntime) -> (KvOffloadManager, SeqId, BlockId, BlockId) {
        let mut kv =
            KvOffloadManager::new(cfg(true, 8), 0).with_prefetch(PrefetchConfig::default());
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(h, s);
        }
        let b0 = kv.table().seq_blocks(s)[0];
        let b1 = kv.table().seq_blocks(s)[1];
        kv.evict_block(h, b0);
        kv.evict_block(h, b1);
        assert!(matches!(kv.table().residency(b0), Some(BlockResidency::Peer { .. })));
        assert!(matches!(kv.table().residency(b1), Some(BlockResidency::Peer { .. })));
        // let the spill DMA complete so nothing below waits on it
        h.advance_to(h.node.clock.now() + 10_000_000);
        (kv, s, b0, b1)
    }

    #[test]
    fn prefetch_overlaps_reload_off_critical_path() {
        let mut h = hr();
        let (mut kv, s, b0, b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        assert_eq!(plan.len(), 2, "both peer blocks planned");
        let t0 = h.node.clock.now();
        let deadline = t0 + 1_000_000;
        let issued = kv.submit_prefetch(&mut h, &plan, deadline);
        assert_eq!(issued, 2);
        assert_eq!(h.node.clock.now(), t0, "background prefetch must not advance the clock");
        assert_eq!(kv.table().residency(b0), Some(BlockResidency::Local));
        assert_eq!(kv.table().residency(b1), Some(BlockResidency::Local));
        kv.check_invariants().unwrap();
        // the consumed source leases stay alive until their copies end
        assert_eq!(h.live_bytes_on(1), 2 * kv.cfg.block_bytes(), "deferred release");
        // once the background copies complete, access is pure hit: no stall
        h.advance_to(deadline);
        let t1 = h.node.clock.now();
        kv.access_seq(&mut h, s);
        assert_eq!(h.node.clock.now(), t1, "prefetched blocks reload without stall");
        assert_eq!(h.live_bytes_on(1), 0, "matured source leases released at sync");
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.issued, 2);
        assert_eq!(pf.hits, 2);
        assert_eq!(pf.late, 0);
        assert_eq!(kv.stats.peer_reloads, 0, "no demand reload was needed");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn late_prefetch_is_counted_and_still_bounded_by_copy_end() {
        let mut h = hr();
        let (mut kv, s, _b0, _b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        let t0 = h.node.clock.now();
        kv.submit_prefetch(&mut h, &plan, t0 + 1_000_000);
        // consume immediately, before the background copies finish
        kv.access_seq(&mut h, s);
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.late, 2, "used before arrival");
        assert_eq!(pf.hits, 0);
        assert!(h.node.clock.now() > t0, "partial stall: wait out the residual copy");
        assert!(h.node.clock.now() <= t0 + 1_000_000);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn revocation_between_plan_and_submit_never_reads_stale_lease() {
        let mut h = hr();
        let (mut kv, s, b0, b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        assert_eq!(plan.len(), 2);
        // the race: peer revokes everything after the plan snapshot
        h.revoke_peer(1, RevocationReason::TenantPressure);
        let issued = kv.submit_prefetch(&mut h, &plan, u64::MAX);
        assert_eq!(issued, 0, "stale plan entries are skipped, not read");
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.stale_plans, 2);
        assert_eq!(pf.issued, 0);
        // lossy blocks dropped by the revocation stay dropped
        assert_eq!(kv.table().residency(b0), Some(BlockResidency::Dropped));
        assert_eq!(kv.table().residency(b1), Some(BlockResidency::Dropped));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn unused_prefetch_counts_as_waste() {
        let mut h = hr();
        let (mut kv, s, _b0, _b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        kv.submit_prefetch(&mut h, &plan, h.node.clock.now() + 1_000_000);
        // the sequence finishes before ever touching the prefetched blocks
        kv.finish_seq(&mut h, s);
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.wasted, 2);
        assert_eq!(pf.bytes_wasted, 2 * kv.cfg.block_bytes());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefetch_yields_to_demand_traffic_and_evicts_nothing() {
        let mut h = hr();
        let (mut kv, s, _b0, _b1) = prefetch_setup(&mut h);
        let plan = kv.plan_prefetch(&mut h, &[s]);
        let local_before = kv.local_blocks();
        // demand traffic occupies the reload link (peer -> compute)
        h.node.copy(DeviceId::Gpu(1), DeviceId::Gpu(0), 256 * (1 << 20), None);
        let issued = kv.submit_prefetch(&mut h, &plan, u64::MAX);
        assert_eq!(issued, 0, "prefetch must never queue behind demand traffic");
        let pf = kv.prefetch_stats().unwrap();
        assert_eq!(pf.yielded, 2);
        assert_eq!(kv.local_blocks(), local_before, "a yielded prefetch evicts nothing");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 3), 0);
        for seq in 0..4 {
            for _ in 0..(16 * 2) {
                kv.append_token(&mut h, SeqId(seq));
            }
        }
        assert!(kv.local_blocks() <= 3);
        kv.check_invariants().unwrap();
    }
}
