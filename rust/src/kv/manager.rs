//! `KvOffloadManager` + per-device `OffloadingHandler` (§5.2).
//!
//! "We introduce a KVOffloadManager into vLLM's KV manager, which serves
//! as a pluggable control interface for implementing Harvest's
//! policy-driven allocation, migration, and revocation semantics. ...
//! For each device, Harvest extends vLLM with an OffloadingHandler
//! responsible for executing data movement operations."
//!
//! Flow:
//! * Decode appends tokens; full local pool ⇒ the eviction policy picks
//!   a victim and the handler migrates it out — to peer HBM via
//!   `harvest_alloc` when available (Harvest mode), else to host DRAM
//!   (vanilla-vLLM mode).
//! * Decode touching a non-local block issues a reload through the
//!   handler: peer → NVLink, host → PCIe, `Dropped` → recompute (or
//!   whichever is cheaper per [`RecomputeModel`]).
//! * Peer revocation drops lossy blocks via the unified table
//!   (`drop_by_handle`), exactly the §5.2 callback semantics.

use super::block::{BlockId, SeqId};
use super::block_table::{BlockResidency, UnifiedBlockTable};
use super::eviction::{EvictionPolicy, Lru};
use super::recompute::RecomputeModel;
use crate::harvest::api::{AllocHints, Durability};
use crate::harvest::HarvestRuntime;
use crate::memsim::{DeviceId, Ns};
use crate::moe::config::KvModel;
use std::cell::RefCell;
use std::rc::Rc;

/// DMA descriptor granularity for KV reloads: blocks are batched into
/// chunks of this size (scattered block copies cannot use one huge
/// contiguous DMA; ~4 MiB descriptors reproduce the Fig. 7 GPU:CPU
/// latency ratio band — see DESIGN.md §Calibration).
pub const RELOAD_CHUNK_BYTES: u64 = 4 * 1024 * 1024;

/// Configuration of the KV offload manager.
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    pub model: &'static KvModel,
    /// Tokens per logical block (vLLM default 16).
    pub block_tokens: u32,
    /// Local KV pool capacity, in blocks.
    pub local_capacity_blocks: usize,
    /// Harvest mode: evict to peer HBM when possible. Off = vanilla vLLM
    /// (evict to host only) — the Fig. 7 baseline.
    pub use_harvest: bool,
    /// Also materialise a host copy when evicting to peer (durable mode;
    /// default off — §5.2 treats peer KV as lossy).
    pub host_backed_peer: bool,
}

impl KvConfig {
    pub fn block_bytes(&self) -> u64 {
        self.block_tokens as u64 * self.model.kv_bytes_per_token()
    }
}

/// Cumulative statistics.
#[derive(Debug, Clone, Default)]
pub struct KvStats {
    pub appends: u64,
    pub local_hits: u64,
    pub peer_reloads: u64,
    pub host_reloads: u64,
    pub recomputes: u64,
    pub evictions_to_peer: u64,
    pub evictions_to_host: u64,
    pub peer_alloc_failures: u64,
    pub revocation_drops: u64,
    pub bytes_from_peer: u64,
    pub bytes_from_host: u64,
    pub reload_ns: Ns,
    pub recompute_ns: Ns,
}

impl KvStats {
    pub fn reloads(&self) -> u64 {
        self.peer_reloads + self.host_reloads + self.recomputes
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.local_hits + self.reloads();
        if total == 0 {
            0.0
        } else {
            self.local_hits as f64 / total as f64
        }
    }
}

/// Executes data movement for one device pair (§5.2). Thin by design:
/// policy lives in the manager; the handler only knows how to move KV
/// bytes (batched into [`RELOAD_CHUNK_BYTES`] descriptors).
#[derive(Debug, Clone, Copy)]
pub struct OffloadingHandler {
    pub compute_gpu: usize,
}

impl OffloadingHandler {
    /// Transfer `bytes` of KV between tiers; returns (start, end).
    pub fn transfer(
        &self,
        hr: &mut HarvestRuntime,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        tag: Option<u64>,
    ) -> crate::memsim::CopyEvent {
        let n_chunks = bytes.div_ceil(RELOAD_CHUNK_BYTES).max(1);
        hr.node.copy_scattered(src, dst, bytes, n_chunks, tag)
    }
}

/// The manager.
pub struct KvOffloadManager {
    pub cfg: KvConfig,
    table: Rc<RefCell<UnifiedBlockTable>>,
    policy: Box<dyn EvictionPolicy>,
    handler: OffloadingHandler,
    recompute: RecomputeModel,
    pub stats: KvStats,
    drops_observed: Rc<RefCell<u64>>,
}

impl KvOffloadManager {
    pub fn new(cfg: KvConfig, compute_gpu: usize) -> Self {
        Self::with_policy(cfg, compute_gpu, Box::new(Lru::new()))
    }

    pub fn with_policy(
        cfg: KvConfig,
        compute_gpu: usize,
        policy: Box<dyn EvictionPolicy>,
    ) -> Self {
        Self {
            cfg,
            table: Rc::new(RefCell::new(UnifiedBlockTable::new())),
            policy,
            handler: OffloadingHandler { compute_gpu },
            recompute: RecomputeModel::new(cfg.model.active_params_b),
            stats: KvStats::default(),
            drops_observed: Rc::new(RefCell::new(0)),
        }
    }

    pub fn table(&self) -> std::cell::Ref<'_, UnifiedBlockTable> {
        self.table.borrow()
    }

    pub fn local_blocks(&self) -> usize {
        self.policy.len()
    }

    /// Append one token to `seq`, paging in a new block when the last one
    /// fills. May evict under pressure. Returns the block written.
    pub fn append_token(&mut self, hr: &mut HarvestRuntime, seq: SeqId) -> BlockId {
        self.stats.appends += 1;
        let now = hr.node.clock.now();
        let last = {
            let t = self.table.borrow();
            t.seq_blocks(seq).last().copied().and_then(|id| {
                let m = t.meta(id)?;
                (m.tokens < self.cfg.block_tokens).then_some(id)
            })
        };
        let id = match last {
            // The tail block must be local to be appended to.
            Some(id) if self.table.borrow().residency(id) == Some(BlockResidency::Local) => id,
            Some(id) => {
                self.ensure_local(hr, id);
                id
            }
            None => {
                self.make_room(hr, 1);
                let id = self.table.borrow_mut().new_block(seq, now);
                self.policy.insert(id, now);
                id
            }
        };
        let mut t = self.table.borrow_mut();
        let m = t.meta_mut(id).expect("live block");
        m.tokens += 1;
        m.touch(now);
        drop(t);
        self.policy.touch(id, now);
        id
    }

    /// Decode touches every block of `seq`: reload anything non-local.
    /// Returns when the sequence is fully resident (virtual time may
    /// advance past reload DMA and recompute).
    pub fn access_seq(&mut self, hr: &mut HarvestRuntime, seq: SeqId) -> Ns {
        let ids: Vec<BlockId> = self.table.borrow().seq_blocks(seq).to_vec();
        let mut ready = hr.node.clock.now();
        for id in ids {
            ready = ready.max(self.access_block(hr, id));
        }
        hr.node.clock.advance_to(ready);
        ready
    }

    /// Touch one block; reload/recompute if non-local. Returns readiness.
    pub fn access_block(&mut self, hr: &mut HarvestRuntime, id: BlockId) -> Ns {
        let now = hr.node.clock.now();
        let res = self.table.borrow().residency(id).expect("live block");
        let ready = match res {
            BlockResidency::Local => {
                self.stats.local_hits += 1;
                now
            }
            _ => self.ensure_local(hr, id),
        };
        self.policy.touch(id, hr.node.clock.now());
        if let Some(m) = self.table.borrow_mut().meta_mut(id) {
            m.touch(hr.node.clock.now());
        }
        ready
    }

    /// Bring a block into the local pool (reload or recompute), evicting
    /// to make room first. Returns the readiness time.
    fn ensure_local(&mut self, hr: &mut HarvestRuntime, id: BlockId) -> Ns {
        self.make_room(hr, 1);
        let res = self.table.borrow().residency(id).expect("live block");
        let bytes = self.cfg.block_bytes();
        let ready = match res {
            BlockResidency::Local => hr.node.clock.now(),
            BlockResidency::Peer { handle, peer } => {
                let ev = self.handler.transfer(
                    hr,
                    DeviceId::Gpu(peer),
                    DeviceId::Gpu(self.handler.compute_gpu),
                    bytes,
                    Some(handle.0),
                );
                // The peer copy is consumed: free the harvest allocation.
                let _ = hr.free(handle);
                self.stats.peer_reloads += 1;
                self.stats.bytes_from_peer += bytes;
                self.stats.reload_ns += ev.duration();
                ev.end
            }
            BlockResidency::Host => {
                let ev = self.handler.transfer(
                    hr,
                    DeviceId::Host,
                    DeviceId::Gpu(self.handler.compute_gpu),
                    bytes,
                    None,
                );
                self.stats.host_reloads += 1;
                self.stats.bytes_from_host += bytes;
                self.stats.reload_ns += ev.duration();
                ev.end
            }
            BlockResidency::Dropped => {
                // Recompute the block's tokens (prefill replay).
                let tokens = self.table.borrow().meta(id).map(|m| m.tokens).unwrap_or(0);
                let dur = self.recompute.recompute_ns(tokens as u64);
                self.stats.recomputes += 1;
                self.stats.recompute_ns += dur;
                hr.node.clock.now() + dur
            }
        };
        self.table.borrow_mut().set_residency(id, BlockResidency::Local);
        self.policy.insert(id, hr.node.clock.now());
        ready
    }

    /// Evict until `headroom` local slots are free.
    fn make_room(&mut self, hr: &mut HarvestRuntime, headroom: usize) {
        while self.policy.len() + headroom > self.cfg.local_capacity_blocks {
            let Some(victim) = self.policy.victim() else { break };
            self.evict_block(hr, victim);
        }
    }

    /// Migrate one local block out (§5.2 "workers similarly request block
    /// evictions, allowing handlers to migrate blocks out of local HBM").
    pub fn evict_block(&mut self, hr: &mut HarvestRuntime, id: BlockId) {
        debug_assert_eq!(self.table.borrow().residency(id), Some(BlockResidency::Local));
        let bytes = self.cfg.block_bytes();
        self.policy.remove(id);
        if self.cfg.use_harvest {
            let hints = AllocHints {
                compute_gpu: Some(self.handler.compute_gpu),
                durability: if self.cfg.host_backed_peer {
                    Durability::HostBacked
                } else {
                    Durability::Lossy
                },
                ..Default::default()
            };
            if let Ok(handle) = hr.alloc(bytes, hints) {
                // Move local -> peer.
                self.handler.transfer(
                    hr,
                    DeviceId::Gpu(self.handler.compute_gpu),
                    DeviceId::Gpu(handle.peer),
                    bytes,
                    Some(handle.id.0),
                );
                if self.cfg.host_backed_peer {
                    // Durable mode: also materialise the host copy now.
                    self.handler.transfer(
                        hr,
                        DeviceId::Gpu(self.handler.compute_gpu),
                        DeviceId::Host,
                        bytes,
                        None,
                    );
                }
                let table = Rc::clone(&self.table);
                let drops = Rc::clone(&self.drops_observed);
                let host_backed = self.cfg.host_backed_peer;
                hr.register_cb(handle.id, move |rev| {
                    let mut t = table.borrow_mut();
                    if host_backed {
                        // A host copy exists: fall back to it.
                        if let Some(b) = t.drop_by_handle(rev.handle.id) {
                            t.set_residency(b, BlockResidency::Host);
                        }
                    } else {
                        t.drop_by_handle(rev.handle.id);
                    }
                    *drops.borrow_mut() += 1;
                })
                .expect("fresh handle");
                self.table
                    .borrow_mut()
                    .set_residency(id, BlockResidency::Peer { handle: handle.id, peer: handle.peer });
                self.stats.evictions_to_peer += 1;
                return;
            }
            self.stats.peer_alloc_failures += 1;
        }
        // Vanilla vLLM path: evict to host DRAM over PCIe.
        self.handler.transfer(
            hr,
            DeviceId::Gpu(self.handler.compute_gpu),
            DeviceId::Host,
            bytes,
            None,
        );
        self.table.borrow_mut().set_residency(id, BlockResidency::Host);
        self.stats.evictions_to_host += 1;
    }

    /// Finish a sequence: release all its blocks (and any peer handles).
    pub fn finish_seq(&mut self, hr: &mut HarvestRuntime, seq: SeqId) {
        let removed = self.table.borrow_mut().remove_seq(seq);
        for (id, res) in removed {
            self.policy.remove(id);
            if let BlockResidency::Peer { handle, .. } = res {
                let _ = hr.free(handle);
            }
        }
    }

    /// How many peer-revocation drops callbacks have delivered.
    pub fn drops_observed(&self) -> u64 {
        *self.drops_observed.borrow()
    }

    /// Consistency between policy membership and table residency.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.table.borrow().check_invariants()?;
        let local_in_table = self.table.borrow().count_by_residency().0;
        if local_in_table != self.policy.len() {
            return Err(format!(
                "policy tracks {} blocks, table says {} local",
                self.policy.len(),
                local_in_table
            ));
        }
        if self.policy.len() > self.cfg.local_capacity_blocks {
            return Err("local pool over capacity".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::{HarvestConfig, RevocationReason};
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::{NodeSpec, SimNode};
    use crate::moe::config::find_kv_model;

    const GIB: u64 = 1 << 30;

    fn hr() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    fn cfg(use_harvest: bool, cap: usize) -> KvConfig {
        KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap,
            use_harvest,
            host_backed_peer: false,
        }
    }

    #[test]
    fn appends_fill_blocks_at_granularity() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 100), 0);
        let s = SeqId(1);
        for _ in 0..33 {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.table().seq_blocks(s).len(), 3, "33 tokens -> 3 blocks of 16");
        assert_eq!(kv.table().meta(kv.table().seq_blocks(s)[2]).unwrap().tokens, 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn eviction_to_peer_when_harvest_on() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(kv.stats.evictions_to_peer >= 2);
        assert_eq!(kv.stats.evictions_to_host, 0);
        let (_local, peer, host, dropped) = kv.table().count_by_residency();
        assert!(peer >= 2, "peer={peer} host={host} dropped={dropped}");
        kv.check_invariants().unwrap();
        // bytes actually moved GPU0 -> GPU1
        assert!(h.node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Gpu(1)) > 0);
    }

    #[test]
    fn eviction_to_host_when_harvest_off() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(false, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.evictions_to_host >= 2);
        assert!(h.node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Host) > 0);
    }

    #[test]
    fn reload_from_peer_faster_than_host() {
        let measure = |use_harvest: bool| {
            let mut h = hr();
            let mut kv = KvOffloadManager::new(cfg(use_harvest, 4), 0);
            let s = SeqId(1);
            for _ in 0..(16 * 6) {
                kv.append_token(&mut h, s);
            }
            // touch the first (evicted) block
            let first = kv.table().seq_blocks(s)[0];
            assert_ne!(kv.table().residency(first), Some(BlockResidency::Local));
            kv.access_block(&mut h, first);
            (kv.stats.clone(), kv)
        };
        let (harvest_stats, kv1) = measure(true);
        let (host_stats, _) = measure(false);
        assert_eq!(harvest_stats.peer_reloads, 1);
        assert_eq!(host_stats.host_reloads, 1);
        assert!(
            harvest_stats.reload_ns < host_stats.reload_ns / 3,
            "peer reload {} should be much faster than host {}",
            harvest_stats.reload_ns,
            host_stats.reload_ns
        );
        kv1.check_invariants().unwrap();
    }

    #[test]
    fn revocation_drops_lossy_blocks_then_recompute() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        let peer_before = kv.table().count_by_residency().1;
        assert!(peer_before > 0);
        h.revoke_peer(1, RevocationReason::TenantPressure);
        assert_eq!(kv.drops_observed() as usize, peer_before);
        let (_, peer, _, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, peer_before);
        // accessing a dropped block recomputes
        let first = kv.table().seq_blocks(s)[0];
        let before = kv.stats.recomputes;
        kv.access_block(&mut h, first);
        assert_eq!(kv.stats.recomputes, before + 1);
        assert!(kv.stats.recompute_ns > 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn host_backed_peer_falls_back_to_host() {
        let mut h = hr();
        let mut c = cfg(true, 4);
        c.host_backed_peer = true;
        let mut kv = KvOffloadManager::new(c, 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        h.revoke_peer(1, RevocationReason::TenantPressure);
        let (_, peer, host, dropped) = kv.table().count_by_residency();
        assert_eq!(peer, 0);
        assert_eq!(dropped, 0, "durable blocks never drop");
        assert!(host >= 2);
    }

    #[test]
    fn full_peer_falls_back_to_host_eviction() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut h = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        h.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 80 * GIB));
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert_eq!(kv.stats.evictions_to_peer, 0);
        assert!(kv.stats.peer_alloc_failures > 0);
        assert!(kv.stats.evictions_to_host > 0, "graceful fallback to vanilla path");
    }

    #[test]
    fn finish_seq_releases_peer_handles() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 6) {
            kv.append_token(&mut h, s);
        }
        assert!(h.live_bytes_on(1) > 0);
        kv.finish_seq(&mut h, s);
        assert_eq!(h.live_bytes_on(1), 0, "harvest allocations freed");
        assert!(kv.table().is_empty());
        assert_eq!(kv.local_blocks(), 0);
    }

    #[test]
    fn access_seq_advances_clock_past_reloads() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 4), 0);
        let s = SeqId(1);
        for _ in 0..(16 * 8) {
            kv.append_token(&mut h, s);
        }
        let t0 = h.node.clock.now();
        kv.access_seq(&mut h, s);
        assert!(h.node.clock.now() > t0, "reloads take time");
        // afterwards everything the pool can hold is local
        kv.check_invariants().unwrap();
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut h = hr();
        let mut kv = KvOffloadManager::new(cfg(true, 3), 0);
        for seq in 0..4 {
            for _ in 0..(16 * 2) {
                kv.append_token(&mut h, SeqId(seq));
            }
        }
        assert!(kv.local_blocks() <= 3);
        kv.check_invariants().unwrap();
    }
}
