//! The unified KV block table (§5.2): logical block id → residency
//! across local HBM and the harvest tiers (peer GPU / CXL / host DRAM /
//! SSD, all lease-addressed), plus `Dropped` for lossy-revoked blocks
//! awaiting recomputation.

use super::block::{BlockId, KvBlockMeta, SeqId};
use crate::harvest::api::{LeaseId, MemoryTier};
use crate::memsim::Ns;
use std::collections::BTreeMap;

/// Where a logical block's bytes currently live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockResidency {
    /// In the compute GPU's KV pool — attention can read it directly.
    Local,
    /// Off-pool, cached under a live harvest lease on `tier` (peer HBM
    /// over NVLink, CXL, or host DRAM over PCIe). The pre-tier design
    /// kept a parallel `Host` variant with raw untracked copies; host is
    /// now just another leased tier.
    Leased { handle: LeaseId, tier: MemoryTier },
    /// Lost (revocation of a lossy block); must be recomputed.
    Dropped,
}

impl BlockResidency {
    /// The tier holding a leased block, if any.
    pub fn tier(&self) -> Option<MemoryTier> {
        match self {
            BlockResidency::Leased { tier, .. } => Some(*tier),
            _ => None,
        }
    }

    pub fn is_peer(&self) -> bool {
        matches!(self, BlockResidency::Leased { tier: MemoryTier::PeerHbm(_), .. })
    }
}

/// The table. One entry per logical block, with per-sequence ordering and
/// a reverse handle index for revocation repair.
#[derive(Debug, Clone, Default)]
pub struct UnifiedBlockTable {
    entries: BTreeMap<BlockId, (KvBlockMeta, BlockResidency)>,
    by_seq: BTreeMap<SeqId, Vec<BlockId>>,
    by_handle: BTreeMap<LeaseId, BlockId>,
    next_id: u64,
}

impl UnifiedBlockTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a fresh (local) block to `seq`.
    pub fn new_block(&mut self, seq: SeqId, now: Ns) -> BlockId {
        let id = BlockId(self.next_id);
        self.next_id += 1;
        let index = self.by_seq.get(&seq).map(|v| v.len() as u32).unwrap_or(0);
        self.entries.insert(id, (KvBlockMeta::new(seq, index, now), BlockResidency::Local));
        self.by_seq.entry(seq).or_default().push(id);
        id
    }

    pub fn meta(&self, id: BlockId) -> Option<&KvBlockMeta> {
        self.entries.get(&id).map(|(m, _)| m)
    }

    pub fn meta_mut(&mut self, id: BlockId) -> Option<&mut KvBlockMeta> {
        self.entries.get_mut(&id).map(|(m, _)| m)
    }

    pub fn residency(&self, id: BlockId) -> Option<BlockResidency> {
        self.entries.get(&id).map(|(_, r)| *r)
    }

    /// Transition a block's residency, maintaining the handle index.
    pub fn set_residency(&mut self, id: BlockId, res: BlockResidency) {
        let Some((_, cur)) = self.entries.get_mut(&id) else { return };
        if let BlockResidency::Leased { handle, .. } = *cur {
            self.by_handle.remove(&handle);
        }
        if let BlockResidency::Leased { handle, .. } = res {
            self.by_handle.insert(handle, id);
        }
        self.entries.get_mut(&id).unwrap().1 = res;
    }

    /// The block currently leased under `handle`, if any.
    pub fn block_of_handle(&self, handle: LeaseId) -> Option<BlockId> {
        self.by_handle.get(&handle).copied()
    }

    /// Revocation path: the leased copy under `handle` is gone. Lossy KV
    /// semantics → the block becomes `Dropped`. Returns the block.
    pub fn drop_by_handle(&mut self, handle: LeaseId) -> Option<BlockId> {
        let id = self.by_handle.remove(&handle)?;
        self.entries.get_mut(&id)?.1 = BlockResidency::Dropped;
        Some(id)
    }

    /// Remove a whole finished sequence; returns its blocks (the caller
    /// releases physical resources).
    pub fn remove_seq(&mut self, seq: SeqId) -> Vec<(BlockId, BlockResidency)> {
        let ids = self.by_seq.remove(&seq).unwrap_or_default();
        ids.into_iter()
            .filter_map(|id| {
                let (_, r) = self.entries.remove(&id)?;
                if let BlockResidency::Leased { handle, .. } = r {
                    self.by_handle.remove(&handle);
                }
                Some((id, r))
            })
            .collect()
    }

    pub fn seq_blocks(&self, seq: SeqId) -> &[BlockId] {
        self.by_seq.get(&seq).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn seqs(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.by_seq.keys().copied()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Counts as `(local, peer-leased, host-or-cxl-leased, dropped)` —
    /// the off-GPU tiers share the third slot.
    pub fn count_by_residency(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for (_, r) in self.entries.values() {
            match r {
                BlockResidency::Local => c.0 += 1,
                BlockResidency::Leased { tier: MemoryTier::PeerHbm(_), .. } => c.1 += 1,
                BlockResidency::Leased { .. } => c.2 += 1,
                BlockResidency::Dropped => c.3 += 1,
            }
        }
        c
    }

    /// Blocks currently local (eviction candidates), with metadata.
    pub fn local_blocks(&self) -> impl Iterator<Item = (BlockId, &KvBlockMeta)> + '_ {
        self.entries.iter().filter_map(|(&id, (m, r))| {
            matches!(r, BlockResidency::Local).then_some((id, m))
        })
    }

    /// Blocks currently leased off-pool (cold-tier aging candidates),
    /// with their lease handle, resident tier, and metadata.
    pub fn leased_blocks(
        &self,
    ) -> impl Iterator<Item = (BlockId, LeaseId, MemoryTier, &KvBlockMeta)> + '_ {
        self.entries.iter().filter_map(|(&id, (m, r))| match r {
            BlockResidency::Leased { handle, tier } => Some((id, *handle, *tier, m)),
            _ => None,
        })
    }

    /// Invariants (property-tested): reverse handle index is exactly the
    /// set of Leased entries; per-seq lists are dense, ordered, and agree
    /// with metadata.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (&h, &id) in &self.by_handle {
            match self.residency(id) {
                Some(BlockResidency::Leased { handle, .. }) if handle == h => {}
                other => return Err(format!("by_handle {h:?} -> {id:?} but {other:?}")),
            }
        }
        for (&id, (m, r)) in &self.entries {
            if let BlockResidency::Leased { handle, .. } = r {
                if self.by_handle.get(handle) != Some(&id) {
                    return Err(format!("leased block {id:?} missing reverse index"));
                }
            }
            let list = self.seq_blocks(m.seq);
            if list.get(m.index_in_seq as usize) != Some(&id) {
                return Err(format!("block {id:?} not at its index in seq list"));
            }
        }
        for (&seq, ids) in &self.by_seq {
            for (i, id) in ids.iter().enumerate() {
                let m = self.meta(*id).ok_or(format!("seq {seq:?} lists dead block"))?;
                if m.seq != seq || m.index_in_seq as usize != i {
                    return Err(format!("seq list disagrees with meta for {id:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn peer(handle: LeaseId, gpu: usize) -> BlockResidency {
        BlockResidency::Leased { handle, tier: MemoryTier::PeerHbm(gpu) }
    }

    #[test]
    fn blocks_append_in_order() {
        let mut t = UnifiedBlockTable::new();
        let s = SeqId(1);
        let a = t.new_block(s, 0);
        let b = t.new_block(s, 1);
        assert_eq!(t.seq_blocks(s), &[a, b]);
        assert_eq!(t.meta(b).unwrap().index_in_seq, 1);
        assert_eq!(t.residency(a), Some(BlockResidency::Local));
        t.check_invariants().unwrap();
    }

    #[test]
    fn residency_transitions_maintain_handle_index() {
        let mut t = UnifiedBlockTable::new();
        let s = SeqId(1);
        let a = t.new_block(s, 0);
        let h = LeaseId(5);
        t.set_residency(a, peer(h, 1));
        assert_eq!(t.block_of_handle(h), Some(a));
        t.check_invariants().unwrap();
        // a tier change under the same lease keeps the index
        t.set_residency(a, BlockResidency::Leased { handle: h, tier: MemoryTier::Host });
        assert_eq!(t.block_of_handle(h), Some(a));
        assert_eq!(t.residency(a).unwrap().tier(), Some(MemoryTier::Host));
        t.check_invariants().unwrap();
        t.set_residency(a, BlockResidency::Local);
        t.check_invariants().unwrap();
        // handle mapping gone after leaving Leased
        assert_eq!(t.drop_by_handle(h), None);
        assert_eq!(t.block_of_handle(h), None);
    }

    #[test]
    fn drop_by_handle_marks_dropped() {
        let mut t = UnifiedBlockTable::new();
        let a = t.new_block(SeqId(1), 0);
        let h = LeaseId(9);
        t.set_residency(a, peer(h, 1));
        assert_eq!(t.drop_by_handle(h), Some(a));
        assert_eq!(t.residency(a), Some(BlockResidency::Dropped));
        t.check_invariants().unwrap();
    }

    #[test]
    fn remove_seq_cleans_everything() {
        let mut t = UnifiedBlockTable::new();
        let s = SeqId(2);
        let a = t.new_block(s, 0);
        let b = t.new_block(s, 0);
        let h = LeaseId(1);
        t.set_residency(b, peer(h, 1));
        let removed = t.remove_seq(s);
        assert_eq!(removed.len(), 2);
        assert!(t.is_empty());
        assert_eq!(t.drop_by_handle(h), None, "handle index cleaned");
        assert_eq!(t.seq_blocks(s), &[] as &[BlockId]);
        let _ = a;
        t.check_invariants().unwrap();
    }

    #[test]
    fn counts_by_residency() {
        let mut t = UnifiedBlockTable::new();
        let s = SeqId(3);
        let a = t.new_block(s, 0);
        let b = t.new_block(s, 0);
        let c = t.new_block(s, 0);
        let d = t.new_block(s, 0);
        t.set_residency(a, BlockResidency::Leased { handle: LeaseId(1), tier: MemoryTier::Host });
        t.set_residency(b, BlockResidency::Dropped);
        t.set_residency(d, peer(LeaseId(2), 1));
        let _ = c;
        assert_eq!(t.count_by_residency(), (1, 1, 1, 1));
    }

    #[test]
    fn separate_seqs_independent() {
        let mut t = UnifiedBlockTable::new();
        let a = t.new_block(SeqId(1), 0);
        let b = t.new_block(SeqId(2), 0);
        assert_eq!(t.meta(a).unwrap().index_in_seq, 0);
        assert_eq!(t.meta(b).unwrap().index_in_seq, 0);
        t.remove_seq(SeqId(1));
        assert_eq!(t.seq_blocks(SeqId(2)), &[b]);
        t.check_invariants().unwrap();
    }
}
