//! Logical KV blocks (vLLM-style fixed-size paging, §5.2).

use crate::memsim::Ns;

/// Globally unique logical block id (never reused).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BlockId(pub u64);

/// Sequence (request) id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SeqId(pub u64);

/// Metadata for one logical block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvBlockMeta {
    pub seq: SeqId,
    /// Position of this block within its sequence (0-based).
    pub index_in_seq: u32,
    /// Tokens currently written into the block (≤ block size).
    pub tokens: u32,
    pub last_access: Ns,
    pub access_count: u64,
}

impl KvBlockMeta {
    pub fn new(seq: SeqId, index_in_seq: u32, now: Ns) -> Self {
        Self { seq, index_in_seq, tokens: 0, last_access: now, access_count: 0 }
    }

    pub fn touch(&mut self, now: Ns) {
        self.last_access = now;
        self.access_count += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_updates_recency_and_count() {
        let mut m = KvBlockMeta::new(SeqId(1), 0, 10);
        assert_eq!(m.access_count, 0);
        m.touch(50);
        m.touch(70);
        assert_eq!(m.last_access, 70);
        assert_eq!(m.access_count, 2);
    }
}
