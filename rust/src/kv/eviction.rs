//! Eviction policies for the local KV pool, plus the §8 sliding-window
//! policy switcher ("a sliding window-like algorithm that monitors a
//! system's performance and hot-swaps policies").

use super::block::BlockId;
use crate::memsim::Ns;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Tracks local blocks and picks eviction victims.
pub trait EvictionPolicy {
    fn name(&self) -> &'static str;
    /// A block became local.
    fn insert(&mut self, id: BlockId, now: Ns);
    /// A local block was accessed.
    fn touch(&mut self, id: BlockId, now: Ns);
    /// A block left the local pool (evicted or sequence finished).
    fn remove(&mut self, id: BlockId);
    /// Pick (without removing) the current victim.
    fn victim(&mut self) -> Option<BlockId>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Least-recently-used.
#[derive(Debug, Default)]
pub struct Lru {
    by_recency: BTreeSet<(Ns, BlockId)>,
    stamp: BTreeMap<BlockId, Ns>,
    tick: u64,
}

impl Lru {
    pub fn new() -> Self {
        Self::default()
    }

    /// Monotone stamp even when `now` repeats (virtual time can stall).
    fn next_stamp(&mut self, now: Ns) -> Ns {
        self.tick += 1;
        now.max(self.tick)
    }
}

impl EvictionPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn insert(&mut self, id: BlockId, now: Ns) {
        let s = self.next_stamp(now);
        self.stamp.insert(id, s);
        self.by_recency.insert((s, id));
    }

    fn touch(&mut self, id: BlockId, now: Ns) {
        if let Some(&old) = self.stamp.get(&id) {
            self.by_recency.remove(&(old, id));
            let s = self.next_stamp(now);
            self.stamp.insert(id, s);
            self.by_recency.insert((s, id));
        }
    }

    fn remove(&mut self, id: BlockId) {
        if let Some(old) = self.stamp.remove(&id) {
            self.by_recency.remove(&(old, id));
        }
    }

    fn victim(&mut self) -> Option<BlockId> {
        self.by_recency.first().map(|&(_, id)| id)
    }

    fn len(&self) -> usize {
        self.stamp.len()
    }
}

/// First-in-first-out.
#[derive(Debug, Default)]
pub struct Fifo {
    queue: VecDeque<BlockId>,
    present: BTreeSet<BlockId>,
}

impl Fifo {
    pub fn new() -> Self {
        Self::default()
    }
}

impl EvictionPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn insert(&mut self, id: BlockId, _now: Ns) {
        if self.present.insert(id) {
            self.queue.push_back(id);
        }
    }

    fn touch(&mut self, _id: BlockId, _now: Ns) {}

    fn remove(&mut self, id: BlockId) {
        if self.present.remove(&id) {
            self.queue.retain(|&b| b != id);
        }
    }

    fn victim(&mut self) -> Option<BlockId> {
        self.queue.front().copied()
    }

    fn len(&self) -> usize {
        self.present.len()
    }
}

/// Least-frequently-used (ties by id = age).
#[derive(Debug, Default)]
pub struct Lfu {
    counts: BTreeMap<BlockId, u64>,
}

impl Lfu {
    pub fn new() -> Self {
        Self::default()
    }
}

impl EvictionPolicy for Lfu {
    fn name(&self) -> &'static str {
        "lfu"
    }

    fn insert(&mut self, id: BlockId, _now: Ns) {
        self.counts.entry(id).or_insert(0);
    }

    fn touch(&mut self, id: BlockId, _now: Ns) {
        if let Some(c) = self.counts.get_mut(&id) {
            *c += 1;
        }
    }

    fn remove(&mut self, id: BlockId) {
        self.counts.remove(&id);
    }

    fn victim(&mut self) -> Option<BlockId> {
        self.counts.iter().min_by_key(|&(&id, &c)| (c, id)).map(|(&id, _)| id)
    }

    fn len(&self) -> usize {
        self.counts.len()
    }
}

/// §8 future-work: monitor reload rate over a sliding window and
/// hot-swap between candidate policies when the current one
/// underperforms. The switcher wraps two policies, mirrors every event
/// into both (so the standby is warm), and delegates victim selection to
/// the active one.
pub struct PolicySwitcher {
    policies: Vec<Box<dyn EvictionPolicy>>,
    active: usize,
    window: usize,
    /// Sliding outcome window: true = access hit local, false = miss.
    outcomes: VecDeque<bool>,
    /// Miss-rate threshold that triggers a swap.
    swap_threshold: f64,
    /// Cooldown (events) after a swap before another is allowed.
    cooldown: usize,
    since_swap: usize,
    pub swaps: u64,
}

impl PolicySwitcher {
    pub fn new(policies: Vec<Box<dyn EvictionPolicy>>, window: usize, swap_threshold: f64) -> Self {
        assert!(!policies.is_empty());
        Self {
            policies,
            active: 0,
            window: window.max(1),
            outcomes: VecDeque::new(),
            swap_threshold,
            cooldown: window.max(1),
            since_swap: 0,
            swaps: 0,
        }
    }

    pub fn active_name(&self) -> &'static str {
        self.policies[self.active].name()
    }

    /// Report an access outcome; may rotate the active policy.
    pub fn report(&mut self, hit: bool) {
        self.outcomes.push_back(hit);
        if self.outcomes.len() > self.window {
            self.outcomes.pop_front();
        }
        self.since_swap += 1;
        if self.outcomes.len() == self.window && self.since_swap >= self.cooldown {
            let misses = self.outcomes.iter().filter(|&&h| !h).count();
            if misses as f64 / self.window as f64 > self.swap_threshold {
                self.active = (self.active + 1) % self.policies.len();
                self.swaps += 1;
                self.since_swap = 0;
                self.outcomes.clear();
            }
        }
    }
}

impl EvictionPolicy for PolicySwitcher {
    fn name(&self) -> &'static str {
        "switcher"
    }

    fn insert(&mut self, id: BlockId, now: Ns) {
        for p in &mut self.policies {
            p.insert(id, now);
        }
    }

    fn touch(&mut self, id: BlockId, now: Ns) {
        for p in &mut self.policies {
            p.touch(id, now);
        }
    }

    fn remove(&mut self, id: BlockId) {
        for p in &mut self.policies {
            p.remove(id);
        }
    }

    fn victim(&mut self) -> Option<BlockId> {
        self.policies[self.active].victim()
    }

    fn len(&self) -> usize {
        self.policies[self.active].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b(i: u64) -> BlockId {
        BlockId(i)
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut p = Lru::new();
        p.insert(b(1), 10);
        p.insert(b(2), 20);
        p.insert(b(3), 30);
        p.touch(b(1), 40); // 2 is now oldest
        assert_eq!(p.victim(), Some(b(2)));
        p.remove(b(2));
        assert_eq!(p.victim(), Some(b(3)));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn lru_handles_equal_timestamps() {
        let mut p = Lru::new();
        p.insert(b(1), 0);
        p.insert(b(2), 0);
        p.insert(b(3), 0);
        assert_eq!(p.victim(), Some(b(1)), "insertion order breaks ties");
        p.touch(b(1), 0);
        assert_eq!(p.victim(), Some(b(2)));
    }

    #[test]
    fn fifo_ignores_touches() {
        let mut p = Fifo::new();
        p.insert(b(1), 0);
        p.insert(b(2), 0);
        p.touch(b(1), 100);
        assert_eq!(p.victim(), Some(b(1)));
    }

    #[test]
    fn lfu_evicts_cold_block() {
        let mut p = Lfu::new();
        p.insert(b(1), 0);
        p.insert(b(2), 0);
        p.insert(b(3), 0);
        p.touch(b(1), 1);
        p.touch(b(1), 2);
        p.touch(b(3), 3);
        assert_eq!(p.victim(), Some(b(2)));
    }

    #[test]
    fn remove_unknown_is_noop() {
        let mut p = Lru::new();
        p.insert(b(1), 0);
        p.remove(b(99));
        p.touch(b(99), 5);
        assert_eq!(p.len(), 1);
        let mut f = Fifo::new();
        f.remove(b(1));
        assert_eq!(f.victim(), None);
    }

    #[test]
    fn switcher_swaps_on_sustained_misses() {
        let mut s = PolicySwitcher::new(
            vec![Box::new(Lru::new()), Box::new(Fifo::new())],
            10,
            0.5,
        );
        assert_eq!(s.active_name(), "lru");
        for _ in 0..10 {
            s.report(false);
        }
        assert_eq!(s.active_name(), "fifo");
        assert_eq!(s.swaps, 1);
        // cooldown: immediate further misses don't swap right away
        for _ in 0..5 {
            s.report(false);
        }
        assert_eq!(s.swaps, 1);
        for _ in 0..5 {
            s.report(false);
        }
        assert_eq!(s.swaps, 2, "swaps again after full window of misses");
    }

    #[test]
    fn switcher_keeps_policy_on_hits() {
        let mut s = PolicySwitcher::new(
            vec![Box::new(Lru::new()), Box::new(Fifo::new())],
            8,
            0.5,
        );
        for _ in 0..100 {
            s.report(true);
        }
        assert_eq!(s.swaps, 0);
        assert_eq!(s.active_name(), "lru");
    }

    #[test]
    fn switcher_mirrors_state_into_standby() {
        let mut s = PolicySwitcher::new(
            vec![Box::new(Lru::new()), Box::new(Fifo::new())],
            4,
            0.5,
        );
        s.insert(b(1), 1);
        s.insert(b(2), 2);
        s.touch(b(1), 3);
        // swap to fifo
        for _ in 0..4 {
            s.report(false);
        }
        assert_eq!(s.active_name(), "fifo");
        // fifo was warm: victim is first-inserted
        assert_eq!(s.victim(), Some(b(1)));
    }
}
