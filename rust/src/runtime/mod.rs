//! PJRT runtime: load and execute the AOT-compiled L2/L1 artifacts.
//!
//! The bridge works on HLO **text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly. See `python/compile/aot.py` and
//! `/opt/xla-example/README.md`.
//!
//! One [`Executable`] per model variant is compiled once at startup; the
//! request path then only calls `execute` with device-resident literals.
//! Python never runs here.

mod manifest;
mod model;
mod weights;

pub use manifest::{ArgSpec, ExecutableSpec, Manifest, RuntimeModelConfig};
pub use model::{DecodeOutput, DecodeSlot, ModelRuntime, PagedKvState};
pub use weights::Weights;

use anyhow::{anyhow as eyre, Context, Result};
use std::path::Path;

/// A compiled PJRT executable plus the metadata needed to call it.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    spec: ExecutableSpec,
}

/// Wrapper around the PJRT CPU client that loads `artifacts/*.hlo.txt`.
#[derive(Clone)]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Upload a host literal to a device-resident buffer.
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_literal(None, lit).map_err(|e| eyre!("{e:?}"))
    }

    /// Create a CPU PJRT client (the only backend available on this image;
    /// on a real deployment this would be the GPU plugin).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| eyre!("{e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load(&self, dir: &Path, name: &str, spec: &ExecutableSpec) -> Result<Executable> {
        let path = dir.join(&spec.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| eyre!("non-utf8 path"))?,
        )
        .map_err(|e| eyre!("{e:?}"))
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| eyre!("{e:?}"))
            .with_context(|| format!("compiling {name}"))?;
        Ok(Executable { name: name.to_string(), exe, spec: spec.clone() })
    }
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn spec(&self) -> &ExecutableSpec {
        &self.spec
    }

    fn check_arity(&self, n: usize) -> Result<()> {
        if n != self.spec.args.len() {
            return Err(eyre!(
                "{}: expected {} args, got {}",
                self.name,
                self.spec.args.len(),
                n
            ));
        }
        Ok(())
    }

    /// Normalize PJRT outputs to one literal per logical result, whether
    /// the runtime untupled the root (return_tuple=False artifacts) or
    /// handed back a single tuple buffer.
    fn outputs_to_literals(bufs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        let inner = bufs.into_iter().next().ok_or_else(|| eyre!("no replica outputs"))?;
        if inner.len() == 1 {
            let lit = inner[0].to_literal_sync().map_err(|e| eyre!("{e:?}"))?;
            match lit.to_tuple() {
                Ok(parts) if !parts.is_empty() => Ok(parts),
                _ => Ok(vec![inner[0].to_literal_sync().map_err(|e| eyre!("{e:?}"))?]),
            }
        } else {
            inner
                .iter()
                .map(|b| b.to_literal_sync().map_err(|e| eyre!("{e:?}")))
                .collect()
        }
    }

    /// Execute with the given literals; returns one literal per result.
    pub fn execute(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.check_arity(args.len())?;
        let bufs = self.exe.execute::<xla::Literal>(args).map_err(|e| eyre!("{e:?}"))?;
        Self::outputs_to_literals(bufs)
    }

    /// Like [`Executable::execute`] but borrowing the argument literals —
    /// avoids cloning multi-MB weight/KV literals on the hot path.
    pub fn execute_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.check_arity(args.len())?;
        let bufs = self.exe.execute::<&xla::Literal>(args).map_err(|e| eyre!("{e:?}"))?;
        Self::outputs_to_literals(bufs)
    }

    /// Device-buffer path: arguments stay resident on the device and the
    /// results come back as device buffers — the decode hot loop feeds
    /// the KV state buffers straight back without any host round-trip
    /// (EXPERIMENTS.md §Perf).
    pub fn execute_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        self.check_arity(args.len())?;
        let bufs = self.exe.execute_b::<&xla::PjRtBuffer>(args).map_err(|e| eyre!("{e:?}"))?;
        bufs.into_iter().next().ok_or_else(|| eyre!("no replica outputs"))
    }
}
