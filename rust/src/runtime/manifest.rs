//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! (producer, build time) and the Rust runtime (consumer, request path).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Static geometry of the AOT-compiled model; mirrors
/// `python/compile/model.py::ModelConfig`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RuntimeModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_ff: usize,
    pub page_size: usize,
    pub num_pages: usize,
    pub max_pages_per_seq: usize,
}

impl RuntimeModelConfig {
    pub fn max_context(&self) -> usize {
        self.page_size * self.max_pages_per_seq
    }

    /// f32 element count of one KV (key or value) page-pool tensor.
    pub fn kv_pool_elems(&self) -> usize {
        self.n_layers * self.num_pages * self.page_size * self.n_heads * self.head_dim
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            vocab: v.get("vocab")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_experts: v.get("n_experts")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            page_size: v.get("page_size")?.as_usize()?,
            num_pages: v.get("num_pages")?.as_usize()?,
            max_pages_per_seq: v.get("max_pages_per_seq")?.as_usize()?,
        })
    }
}

/// One argument of an AOT executable.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v.get("shape")?.as_usize_vec()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT executable (an HLO-text file plus its calling convention).
#[derive(Debug, Clone)]
pub struct ExecutableSpec {
    pub path: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<String>,
}

impl ExecutableSpec {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            path: v.get("path")?.as_str()?.to_string(),
            args: v.get("args")?.as_arr()?.iter().map(ArgSpec::from_json).collect::<Result<_>>()?,
            outputs: v
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|o| Ok(o.as_str()?.to_string()))
                .collect::<Result<_>>()?,
        })
    }
}

/// A parameter slice inside `weights.bin`.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub seed: u64,
    pub config: RuntimeModelConfig,
    pub executables: BTreeMap<String, ExecutableSpec>,
    pub params: Vec<ParamSpec>,
    pub weights_sha256: String,
    pub weights_nbytes: usize,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut executables = BTreeMap::new();
        for (name, spec) in v.get("executables")?.as_obj()? {
            executables.insert(name.clone(), ExecutableSpec::from_json(spec)?);
        }
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.as_usize_vec()?,
                    offset: p.get("offset")?.as_usize()?,
                    nbytes: p.get("nbytes")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            seed: v.get("seed")?.as_u64()?,
            config: RuntimeModelConfig::from_json(v.get("config")?)?,
            executables,
            params,
            weights_sha256: v.get("weights_sha256")?.as_str()?.to_string(),
            weights_nbytes: v.get("weights_nbytes")?.as_usize()?,
        };
        m.validate()?;
        Ok(m)
    }

    /// Internal consistency: param offsets contiguous, executables present.
    pub fn validate(&self) -> Result<()> {
        let mut end = 0usize;
        for p in &self.params {
            if p.offset != end {
                bail!("param {} offset {} != expected {end}", p.name, p.offset);
            }
            let elems: usize = p.shape.iter().product();
            if elems * 4 != p.nbytes {
                bail!("param {} nbytes mismatch", p.name);
            }
            end += p.nbytes;
        }
        if end != self.weights_nbytes {
            bail!("weights_nbytes {} != sum of params {end}", self.weights_nbytes);
        }
        for name in ["decode_step_b1", "decode_step_b4", "moe_ffn", "paged_attention"] {
            if !self.executables.contains_key(name) {
                bail!("manifest missing executable {name}");
            }
        }
        Ok(())
    }

    pub fn executable(&self, name: &str) -> Result<&ExecutableSpec> {
        self.executables.get(name).ok_or_else(|| anyhow!("no executable {name} in manifest"))
    }

    /// Batch sizes for which a `decode_step_b{B}` variant exists, ascending.
    pub fn decode_batch_variants(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .keys()
            .filter_map(|k| k.strip_prefix("decode_step_b").and_then(|s| s.parse().ok()))
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "seed": 0,
      "config": {"vocab": 8, "d_model": 4, "n_heads": 1, "head_dim": 4,
                 "n_layers": 1, "n_experts": 2, "top_k": 1, "d_ff": 8,
                 "page_size": 2, "num_pages": 4, "max_pages_per_seq": 2},
      "executables": {
        "decode_step_b1": {"path": "a.hlo.txt", "args": [], "outputs": []},
        "decode_step_b4": {"path": "b.hlo.txt", "args": [], "outputs": []},
        "moe_ffn": {"path": "c.hlo.txt",
          "args": [{"name": "x", "shape": [4, 4], "dtype": "float32"}],
          "outputs": ["y"]},
        "paged_attention": {"path": "d.hlo.txt", "args": [], "outputs": []}
      },
      "params": [
        {"name": "embed", "shape": [8, 4], "offset": 0, "nbytes": 128},
        {"name": "ln_f", "shape": [4], "offset": 128, "nbytes": 16}
      ],
      "weights_sha256": "x",
      "weights_nbytes": 144
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.config.d_model, 4);
        assert_eq!(m.decode_batch_variants(), vec![1, 4]);
        assert_eq!(m.executable("moe_ffn").unwrap().args[0].shape, vec![4, 4]);
        assert_eq!(m.config.max_context(), 4);
    }

    #[test]
    fn rejects_bad_offsets() {
        let bad = MINI.replace(r#""offset": 128"#, r#""offset": 64"#);
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_executable() {
        let bad = MINI.replace("paged_attention", "paged_attn_typo");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_nbytes_shape_mismatch() {
        let bad = MINI.replace(r#""nbytes": 16"#, r#""nbytes": 20"#);
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert_eq!(m.config.n_heads * m.config.head_dim, m.config.d_model);
            assert!(!m.decode_batch_variants().is_empty());
        }
    }
}
