//! Loader for `artifacts/weights.bin` (f32 LE, `param_specs` order).

use super::manifest::Manifest;
use anyhow::{anyhow as eyre, Context, Result};
use std::path::Path;

/// All model parameters as XLA literals, in manifest (= calling
/// convention) order. Created once at startup; literals are cheap to pass
/// by reference to `Executable::execute`.
pub struct Weights {
    literals: Vec<xla::Literal>,
    names: Vec<String>,
}

impl Weights {
    pub fn load(artifacts_dir: &Path, manifest: &Manifest) -> Result<Self> {
        let path = artifacts_dir.join("weights.bin");
        let blob = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if blob.len() != manifest.weights_nbytes {
            return Err(eyre!(
                "weights.bin is {} bytes, manifest says {}",
                blob.len(),
                manifest.weights_nbytes
            ));
        }
        let mut literals = Vec::with_capacity(manifest.params.len());
        let mut names = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let bytes = &blob[p.offset..p.offset + p.nbytes];
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &p.shape,
                bytes,
            )
            .map_err(|e| eyre!("{e:?}"))?;
            literals.push(lit);
            names.push(p.name.clone());
        }
        Ok(Self { literals, names })
    }

    pub fn len(&self) -> usize {
        self.literals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.literals.is_empty()
    }

    pub fn literals(&self) -> &[xla::Literal] {
        &self.literals
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn by_name(&self, name: &str) -> Option<&xla::Literal> {
        self.names.iter().position(|n| n == name).map(|i| &self.literals[i])
    }

    /// Total parameter bytes (all f32).
    pub fn total_bytes(&self) -> usize {
        self.literals.iter().map(|l| l.size_bytes()).sum()
    }
}
