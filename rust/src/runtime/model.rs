//! High-level model runtime: the real-compute decode path.
//!
//! [`ModelRuntime`] owns the compiled `decode_step_b{B}` executables, the
//! weight literals, and a [`PagedKvState`] (the *physical* KV page pools
//! fed to the HLO). The serving engine calls [`ModelRuntime::decode`] with
//! a micro-batch; everything here is pure Rust + PJRT — Python never runs.
//!
//! Note the division of labour: the HLO only ever sees *physical page
//! indices*. Which tier a page logically lives on (local / peer / host)
//! and what the transfer costs are is the Harvest coordinator's business
//! (`crate::kv`, `crate::harvest`); by the time a decode step executes,
//! the referenced pages are resident in the pool.

use super::{Executable, Manifest, PjrtRuntime, RuntimeModelConfig, Weights};
use anyhow::{anyhow as eyre, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One sequence's slot in a decode micro-batch.
#[derive(Debug, Clone)]
pub struct DecodeSlot {
    /// Token id to feed at this step.
    pub token: i32,
    /// 0-based decode position (== number of tokens already in the cache).
    pub pos: i32,
    /// Logical→physical page map for this sequence (padded to
    /// `max_pages_per_seq`; unused entries may be any valid page).
    pub page_table: Vec<i32>,
}

/// Output of one decode step.
#[derive(Debug, Clone)]
pub struct DecodeOutput {
    /// `[B][vocab]` logits.
    pub logits: Vec<Vec<f32>>,
    /// `[L][B][k]` expert ids actually routed by the gating network —
    /// this is what drives the MoE residency/transfer simulation with
    /// *real* routing decisions.
    pub routed: Vec<Vec<Vec<i32>>>,
}

/// The physical KV page pools (key + value), kept as literals and fed
/// back functionally each step.
pub struct PagedKvState {
    kv_k: xla::Literal,
    kv_v: xla::Literal,
    shape: Vec<usize>,
}

impl PagedKvState {
    fn zeros(cfg: &RuntimeModelConfig) -> Result<Self> {
        let shape = vec![cfg.n_layers, cfg.num_pages, cfg.page_size, cfg.n_heads, cfg.head_dim];
        let nbytes = shape.iter().product::<usize>() * 4;
        let zeros = vec![0u8; nbytes];
        let mk = || {
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &shape, &zeros)
                .map_err(|e| eyre!("{e:?}"))
        };
        Ok(Self { kv_k: mk()?, kv_v: mk()?, shape })
    }

    pub fn size_bytes(&self) -> usize {
        2 * self.shape.iter().product::<usize>() * 4
    }
}

/// Loads everything under `artifacts/` and exposes a batched decode step.
pub struct ModelRuntime {
    pub manifest: Manifest,
    weights: Weights,
    decode_exes: BTreeMap<usize, Executable>,
    kv: PagedKvState,
}

fn lit_i32(vals: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, &bytes)
        .map_err(|e| eyre!("{e:?}"))
}

impl ModelRuntime {
    /// Load manifest + weights and compile all decode variants.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let rt = PjrtRuntime::cpu()?;
        Self::load_with(artifacts_dir, &rt)
    }

    pub fn load_with(artifacts_dir: &Path, rt: &PjrtRuntime) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = Weights::load(artifacts_dir, &manifest)?;
        let mut decode_exes = BTreeMap::new();
        for b in manifest.decode_batch_variants() {
            let name = format!("decode_step_b{b}");
            let spec = manifest.executable(&name)?;
            decode_exes.insert(b, rt.load(artifacts_dir, &name, spec)?);
        }
        let kv = PagedKvState::zeros(&manifest.config)?;
        Ok(Self { manifest, weights, decode_exes, kv })
    }

    pub fn config(&self) -> &RuntimeModelConfig {
        &self.manifest.config
    }

    /// Batch sizes with a compiled variant, ascending.
    pub fn batch_variants(&self) -> Vec<usize> {
        self.decode_exes.keys().copied().collect()
    }

    /// Smallest compiled batch variant that fits `n` slots.
    pub fn pick_batch(&self, n: usize) -> Option<usize> {
        self.decode_exes.keys().copied().find(|b| *b >= n)
    }

    pub fn kv_state_bytes(&self) -> usize {
        self.kv.size_bytes()
    }

    pub fn weights_bytes(&self) -> usize {
        self.weights.total_bytes()
    }

    /// Reset the KV pools to zero (e.g. between benchmark trials).
    pub fn reset_kv(&mut self) -> Result<()> {
        self.kv = PagedKvState::zeros(&self.manifest.config)?;
        Ok(())
    }

    /// Run one decode step for `slots` (padded up to a compiled batch
    /// variant). Returns per-slot logits and per-layer routed experts;
    /// the internal KV pools are updated functionally.
    pub fn decode(&mut self, slots: &[DecodeSlot]) -> Result<DecodeOutput> {
        let cfg = self.manifest.config.clone();
        let b = self
            .pick_batch(slots.len())
            .ok_or_else(|| eyre!("no decode variant fits batch {}", slots.len()))?;
        let exe = &self.decode_exes[&b];
        let mp = cfg.max_pages_per_seq;

        let mut ids = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut pt = vec![0i32; b * mp];
        let mut lens = vec![0i32; b];
        for (i, s) in slots.iter().enumerate() {
            if s.page_table.len() != mp {
                return Err(eyre!(
                    "slot {i}: page_table len {} != max_pages_per_seq {mp}",
                    s.page_table.len()
                ));
            }
            let needed = (s.pos as usize) / cfg.page_size + 1;
            debug_assert!(needed <= mp);
            ids[i] = s.token;
            pos[i] = s.pos;
            lens[i] = s.pos + 1;
            pt[i * mp..(i + 1) * mp].copy_from_slice(&s.page_table);
        }
        // Padding slots are parked on a dedicated scratch page (the last
        // physical page) with seq_len 0, so their KV writes never touch a
        // real sequence's pages and they are masked out of attention.
        for i in slots.len()..b {
            ids[i] = 0;
            pos[i] = 0;
            lens[i] = 0; // masked out of attention entirely
            let scratch = (cfg.num_pages - 1) as i32;
            for j in 0..mp {
                pt[i * mp + j] = scratch;
            }
        }

        let ids_l = lit_i32(&ids, &[b])?;
        let pos_l = lit_i32(&pos, &[b])?;
        let pt_l = lit_i32(&pt, &[b, mp])?;
        let lens_l = lit_i32(&lens, &[b])?;

        // NOTE (§Perf): a fully device-resident path via `execute_b`
        // was tried and reverted — xla 0.1.6's `execute_b` returns the
        // root as ONE tuple buffer (unlike `execute`, which untuples)
        // and tuple buffers cannot be read back with this API. The
        // untupled (return_tuple=False) artifacts still cut the output
        // copy in half vs. the tuple path.
        let mut arg_refs: Vec<&xla::Literal> = Vec::with_capacity(self.weights.len() + 6);
        arg_refs.extend(self.weights.literals().iter());
        arg_refs.push(&ids_l);
        arg_refs.push(&pos_l);
        arg_refs.push(&pt_l);
        arg_refs.push(&lens_l);
        arg_refs.push(&self.kv.kv_k);
        arg_refs.push(&self.kv.kv_v);

        let mut outs = exe.execute_refs(&arg_refs)?;
        if outs.len() != 4 {
            return Err(eyre!("decode_step returned {} outputs, want 4", outs.len()));
        }
        let kv_v = outs.pop().unwrap();
        let kv_k = outs.pop().unwrap();
        let routed_lit = outs.pop().unwrap();
        let logits_lit = outs.pop().unwrap();
        self.kv.kv_k = kv_k;
        self.kv.kv_v = kv_v;

        let logits_flat = logits_lit.to_vec::<f32>().map_err(|e| eyre!("{e:?}"))?;
        let routed_flat = routed_lit.to_vec::<i32>().map_err(|e| eyre!("{e:?}"))?;
        let v = cfg.vocab;
        let (l_layers, k) = (cfg.n_layers, cfg.top_k);
        let logits = (0..slots.len()).map(|i| logits_flat[i * v..(i + 1) * v].to_vec()).collect();
        let routed = (0..l_layers)
            .map(|l| {
                (0..slots.len())
                    .map(|i| {
                        let base = l * b * k + i * k;
                        routed_flat[base..base + k].to_vec()
                    })
                    .collect()
            })
            .collect();
        Ok(DecodeOutput { logits, routed })
    }
}
