//! One node of the cluster: a full single-node serving stack —
//! [`HarvestRuntime`] over its own [`crate::memsim::SimNode`], a
//! [`KvOffloadManager`], a decode scheduler and serving metrics — driven
//! as an *incremental step loop* instead of [`crate::server::SimEngine`]'s
//! closed run-to-completion loop, so the [`super::Cluster`] event loop
//! can interleave nodes in global virtual-time order and route arrivals
//! against live node state.
//!
//! Each step reproduces one `SimEngine` iteration exactly: admit arrived
//! requests (prefill), drain revocations, restore KV residency for the
//! scheduled cohort (charging decode stalls), overlap deadline-aware
//! prefetch/promotion with the step's compute, decode one token per
//! cohort member. On top of that the node keeps a **prefix cache**: the
//! KV blocks of each shared prompt prefix it has served, held as a
//! dedicated sequence in the KV manager (so they age, offload to harvest
//! tiers and reload like any other blocks). A request routed here whose
//! prefix group is cached prefills only its unshared suffix — the
//! affinity win the router exploits — and decode touches the prefix
//! blocks every step, keeping them genuinely resident on this node.

use crate::harvest::{HarvestRuntime, Transfer};
use crate::kv::{KvOffloadManager, KvStats, SeqId};
use crate::memsim::{DeviceId, Ns, SimNode};
use crate::server::{CompletelyFair, Fcfs, Request, Scheduler, ServeMetrics, SimEngineConfig};
use crate::tenantsim::{FleetStats, TenantFleet};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::router::NodeView;
use super::TierLedger;

/// Sequence-id namespace for prefix-cache sequences, far above any
/// request id the workload generator produces.
const PREFIX_SEQ_BASE: u64 = 1 << 40;

/// Which decode scheduler each node runs (a buildable spec, since every
/// node needs its own scheduler instance).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerSpec {
    Fcfs,
    CompletelyFair { quantum: u32 },
}

impl SchedulerSpec {
    pub fn build(&self) -> Box<dyn Scheduler> {
        match *self {
            SchedulerSpec::Fcfs => Box::new(Fcfs::new()),
            SchedulerSpec::CompletelyFair { quantum } => Box::new(CompletelyFair::new(quantum)),
        }
    }

    /// Parse the config-file spelling (`server.scheduler` + quantum).
    pub fn parse(name: &str, quantum: u32) -> anyhow::Result<Self> {
        match name {
            "fcfs" => Ok(SchedulerSpec::Fcfs),
            "cf" | "completely-fair" => Ok(SchedulerSpec::CompletelyFair { quantum }),
            other => anyhow::bail!("unknown scheduler `{other}` (fcfs | cf)"),
        }
    }
}

/// A cached shared-prefix: its KV lives under `seq` in this node's KV
/// manager; `ready_at` gates reuse while the blocks are still arriving
/// (initial build or fabric migration).
#[derive(Debug, Clone, Copy)]
struct PrefixEntry {
    seq: SeqId,
    tokens: u32,
    ready_at: Ns,
}

/// Per-node slice of a [`super::ClusterReport`].
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub node: usize,
    pub metrics: ServeMetrics,
    pub kv_stats: KvStats,
    /// Requests the router assigned here.
    pub routed: u64,
    /// Requests served to completion here.
    pub finished: u64,
    /// Admissions whose prefill reused this node's cached prefix KV.
    pub prefix_hits: u64,
    /// Live harvest bytes by tier class at report time.
    pub ledger: TierLedger,
    /// Co-tenant fleet counters (None when this node runs without one).
    pub tenant: Option<FleetStats>,
}

/// One simulated server of the cluster.
pub struct ClusterNode {
    pub id: usize,
    hr: HarvestRuntime,
    kv: KvOffloadManager,
    scheduler: Box<dyn Scheduler>,
    cfg: SimEngineConfig,
    compute_gpu: usize,
    /// Routed, not yet admitted (arrival order — the router processes
    /// arrivals in global time order).
    pending: VecDeque<Request>,
    /// Admitted, decoding.
    live: BTreeMap<SeqId, Request>,
    prefix_cache: BTreeMap<u32, PrefixEntry>,
    next_prefix_seq: u64,
    pub metrics: ServeMetrics,
    finished: Vec<SeqId>,
    routed: u64,
    prefix_hits: u64,
    /// This node's co-tenant population (per-node fleets: heterogeneous
    /// pressure across an otherwise homogeneous cluster).
    tenants: Option<TenantFleet>,
}

impl ClusterNode {
    pub(crate) fn new(
        id: usize,
        node: SimNode,
        harvest: crate::harvest::HarvestConfig,
        engine: SimEngineConfig,
        sched: SchedulerSpec,
        tenants: Option<TenantFleet>,
    ) -> Self {
        let mut kv = KvOffloadManager::new(engine.kv, 0);
        if let Some(p) = engine.prefetch {
            kv = kv.with_prefetch(p);
        }
        let mut hr = HarvestRuntime::new(node, harvest);
        let mut tenants = tenants;
        if let Some(f) = tenants.as_mut() {
            f.install(&mut hr);
        }
        let mut metrics = ServeMetrics::new();
        metrics.on_start(hr.node.clock.now());
        Self {
            id,
            hr,
            kv,
            scheduler: sched.build(),
            cfg: engine,
            compute_gpu: 0,
            pending: VecDeque::new(),
            live: BTreeMap::new(),
            prefix_cache: BTreeMap::new(),
            next_prefix_seq: 0,
            metrics,
            finished: Vec::new(),
            routed: 0,
            prefix_hits: 0,
            tenants,
        }
    }

    /// Advance this node's clock, stepping its co-tenant fleet when one
    /// is attached.
    fn advance(&mut self, t: Ns) {
        match &mut self.tenants {
            Some(f) => f.advance_to(&mut self.hr, t),
            None => {
                self.hr.advance_to(t);
            }
        }
    }

    // -- introspection ---------------------------------------------------

    pub fn now(&self) -> Ns {
        self.hr.node.clock.now()
    }

    /// Requests waiting or decoding here.
    pub fn queue_depth(&self) -> usize {
        self.pending.len() + self.live.len()
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.live.is_empty()
    }

    /// The virtual time of this node's next step (only meaningful while
    /// [`ClusterNode::has_work`]).
    pub(crate) fn next_event_time(&self) -> Ns {
        if !self.live.is_empty() {
            return self.now();
        }
        match self.pending.front() {
            Some(r) => self.now().max(r.arrival),
            None => self.now(),
        }
    }

    pub fn holds_prefix(&self, group: u32) -> bool {
        self.prefix_cache.contains_key(&group)
    }

    /// The KV sequence holding `group`'s prefix blocks on this node.
    pub fn prefix_seq(&self, group: u32) -> Option<SeqId> {
        self.prefix_cache.get(&group).map(|e| e.seq)
    }

    pub fn kv_manager(&self) -> &KvOffloadManager {
        &self.kv
    }

    pub fn runtime(&self) -> &HarvestRuntime {
        &self.hr
    }

    /// Live harvest bytes by tier class (the node's slice of the
    /// cluster ledger).
    pub fn ledger(&self) -> TierLedger {
        use crate::harvest::MemoryTier;
        let peer = (0..self.hr.node.n_gpus()).map(|g| self.hr.live_bytes_on(g)).sum();
        TierLedger {
            peer,
            cxl: self.hr.live_bytes_on_tier(MemoryTier::CxlMem),
            host: self.hr.live_bytes_on_tier(MemoryTier::Host),
            ssd: self.hr.live_bytes_on_tier(MemoryTier::Ssd),
        }
    }

    /// Load snapshot for the router. `group` marks whose prefix
    /// membership to report.
    pub(crate) fn view(&self, group: Option<u32>) -> NodeView {
        let free_hbm =
            (0..self.hr.node.n_gpus()).map(|g| self.hr.node.harvestable_now(g)).sum();
        NodeView {
            node: self.id,
            queue_depth: self.queue_depth(),
            free_local_blocks: self
                .cfg
                .kv
                .local_capacity_blocks
                .saturating_sub(self.kv.local_blocks()),
            free_hbm_bytes: free_hbm,
            has_prefix: group.is_some_and(|g| self.prefix_cache.contains_key(&g)),
        }
    }

    pub(crate) fn report(&self) -> NodeReport {
        NodeReport {
            node: self.id,
            metrics: self.metrics.clone(),
            kv_stats: self.kv.stats.clone(),
            routed: self.routed,
            finished: self.finished.len() as u64,
            prefix_hits: self.prefix_hits,
            ledger: self.ledger(),
            tenant: self.tenants.as_ref().map(|f| f.stats()),
        }
    }

    /// This node's co-tenant fleet counters, when one is attached.
    pub fn tenant_stats(&self) -> Option<FleetStats> {
        self.tenants.as_ref().map(|f| f.stats())
    }

    // -- routing-side entry points ---------------------------------------

    /// Accept a routed request (arrivals are handed over in global
    /// arrival order, so the pending queue stays arrival-sorted).
    pub(crate) fn enqueue(&mut self, req: Request) {
        self.routed += 1;
        self.pending.push_back(req);
    }

    /// Read out `seq`'s blocks for a fabric migration: restore residency
    /// (lease-addressed reloads for anything on a harvest tier), then
    /// egress compute-GPU → host staging for the NIC. Returns the byte
    /// count and the virtual time the payload is ready to leave.
    pub(crate) fn export_prefix(&mut self, group: u32) -> Option<(u32, u64, Ns)> {
        let entry = *self.prefix_cache.get(&group)?;
        let ready = self.kv.access_seq(&mut self.hr, entry.seq);
        let blocks = self.kv.table().seq_blocks(entry.seq).len() as u64;
        let bytes = blocks * self.cfg.kv.block_bytes();
        if bytes == 0 {
            return Some((entry.tokens, 0, ready));
        }
        let report = Transfer::new()
            .raw(DeviceId::Gpu(self.compute_gpu), DeviceId::Host, bytes)
            .submit(&mut self.hr)
            .expect("raw transfer cannot go stale");
        Some((entry.tokens, bytes, report.end.max(ready)))
    }

    /// Land a migrated prefix: build the group's blocks in this node's
    /// KV manager and gate reuse on the later of `ready_at` (the fabric
    /// delivery time) and the host-staging → HBM ingress completing on
    /// the local PCIe link. (The ingress is scheduled when the migration
    /// is decided rather than at NIC delivery — a deliberate
    /// simplification that can occupy the link early; the *gate* is
    /// never early, so reuse always pays both hops.)
    pub(crate) fn install_prefix(&mut self, group: u32, tokens: u32, ready_at: Ns) {
        if self.prefix_cache.contains_key(&group) {
            return;
        }
        let seq = self.build_prefix(group, tokens);
        let blocks = self.kv.table().seq_blocks(seq).len() as u64;
        let bytes = blocks * self.cfg.kv.block_bytes();
        let mut gate = ready_at;
        if bytes > 0 {
            let ingress = Transfer::new()
                .raw(DeviceId::Host, DeviceId::Gpu(self.compute_gpu), bytes)
                .submit(&mut self.hr)
                .expect("raw transfer cannot go stale");
            gate = gate.max(ingress.end);
        }
        if let Some(e) = self.prefix_cache.get_mut(&group) {
            e.ready_at = gate;
        }
    }

    /// Create the prefix sequence and append its tokens (no compute is
    /// charged here — the caller accounts prefill or fabric time).
    fn build_prefix(&mut self, group: u32, tokens: u32) -> SeqId {
        let seq = SeqId(PREFIX_SEQ_BASE + self.next_prefix_seq);
        self.next_prefix_seq += 1;
        let bt = self.cfg.kv.block_tokens as usize;
        self.kv.reserve_local(&mut self.hr, (tokens as usize).div_ceil(bt));
        for _ in 0..tokens {
            self.kv.append_token(&mut self.hr, seq);
        }
        self.prefix_cache
            .insert(group, PrefixEntry { seq, tokens, ready_at: self.now() });
        seq
    }

    // -- the step loop ---------------------------------------------------

    /// Admission + prefill for every arrived request that fits.
    fn admit_ready(&mut self) {
        while self.live.len() < self.cfg.max_running {
            let Some(front) = self.pending.front() else { break };
            if front.arrival > self.now() {
                break;
            }
            let mut req = self.pending.pop_front().expect("checked front");
            self.prefill(&mut req);
            self.scheduler.admit(req.id);
            self.live.insert(req.id, req);
        }
    }

    /// Prefill one request. A cached prefix group shrinks the prefill to
    /// the unshared suffix (the affinity win); reuse waits for the
    /// prefix's `ready_at` when its blocks are still in flight over the
    /// node fabric — the wait overlaps the suffix prefill.
    fn prefill(&mut self, req: &mut Request) {
        let (cached, gate) = match req.prefix_group.and_then(|g| self.prefix_cache.get(&g)) {
            Some(e) => (e.tokens.min(req.shared_prefix_tokens), e.ready_at),
            None => (0, 0),
        };
        if cached > 0 {
            self.prefix_hits += 1;
        }
        let fresh = req.prompt_tokens - cached;
        let prefill_ns = self.cfg.prefill_ns_per_token * fresh as u64;
        self.advance(self.now() + prefill_ns);
        self.advance(gate);
        let bt = self.cfg.kv.block_tokens as usize;
        // Vectored admission: free the suffix's block footprint in one
        // all-or-nothing batch instead of evicting per token.
        self.kv.reserve_local(&mut self.hr, (fresh as usize).div_ceil(bt));
        for _ in 0..fresh {
            self.kv.append_token(&mut self.hr, req.id);
        }
        if cached == 0 && req.shared_prefix_tokens > 0 {
            if let Some(g) = req.prefix_group {
                // First request of the group on this node: its prefill
                // (charged above, full-length) built the prefix KV —
                // retain it as the group cache.
                self.build_prefix(g, req.shared_prefix_tokens);
            }
        }
        req.first_token_at = Some(self.now());
        self.metrics.on_first_token(req.arrival, self.now());
    }

    /// Run one engine iteration: admit, restore residency, overlap
    /// prefetch with compute, decode one token per cohort member.
    /// Mirrors [`crate::server::SimEngine::run`]'s loop body.
    pub(crate) fn step(&mut self) {
        if self.live.is_empty() {
            let next_arrival = self.pending.front().map(|r| r.arrival.max(self.now()));
            if let Some(at) = next_arrival {
                self.advance(at);
            }
        }
        self.admit_ready();
        let cohort = self.scheduler.select(self.cfg.decode_slots);
        if cohort.is_empty() {
            return;
        }
        let step_start = self.now();
        // Tick boundary: fold in revocations, then restore residency —
        // the cohort's own blocks plus the prefix blocks decode attends
        // over (this is where preemption and offload churn cost).
        self.kv.sync(&mut self.hr);
        let mut groups_touched: BTreeSet<u32> = BTreeSet::new();
        for &seq in &cohort {
            if let Some(g) = self.live.get(&seq).and_then(|r| r.prefix_group) {
                if groups_touched.insert(g) {
                    let pseq = self.prefix_cache.get(&g).map(|e| e.seq);
                    if let Some(pseq) = pseq {
                        self.kv.access_seq(&mut self.hr, pseq);
                    }
                }
            }
        }
        for &seq in &cohort {
            self.kv.access_seq(&mut self.hr, seq);
        }
        self.metrics.on_stall(self.now() - step_start);
        // Overlap predicted reloads/promotions with this step's compute.
        if let Some(pcfg) = self.cfg.prefetch {
            let predicted = self.scheduler.lookahead(self.cfg.decode_slots, pcfg.horizon);
            let deadline = self.now() + self.cfg.step_compute_ns;
            self.kv.prefetch_seqs(&mut self.hr, &predicted, deadline);
            self.kv.promote_blocks(&mut self.hr, &predicted, deadline);
        }
        self.advance(self.now() + self.cfg.step_compute_ns);
        let step_ns = self.now() - step_start;
        for &seq in &cohort {
            self.kv.append_token(&mut self.hr, seq);
            let now = self.hr.node.clock.now();
            let req = self.live.get_mut(&seq).expect("scheduled request is live");
            req.generated += 1;
            let finished = req.done();
            let arrival = req.arrival;
            if finished {
                req.finished_at = Some(now);
            }
            self.metrics.on_token(step_ns);
            if finished {
                self.metrics.on_finish(arrival, now);
                self.scheduler.retire(seq);
                self.kv.finish_seq(&mut self.hr, seq);
                self.live.remove(&seq);
                self.finished.push(seq);
            }
        }
    }

    /// Finalize metrics at end of run (attach the prefetch ledger).
    pub(crate) fn finalize(&mut self) {
        self.metrics.prefetch = self.kv.prefetch_stats().cloned();
    }
}
