//! One node of the cluster: a full single-node serving stack —
//! [`HarvestRuntime`] over its own [`crate::memsim::SimNode`] plus a
//! [`crate::server::NodeStepper`] (KV manager, decode scheduler, prefix
//! cache, serving metrics, optional co-tenant fleet) — driven
//! *incrementally* under the [`super::Cluster`] event calendar instead
//! of [`crate::server::SimEngine`]'s closed run-to-completion loop.
//!
//! The loop body is **not** re-implemented here: every
//! [`ClusterNode::step`] is one [`crate::server::NodeStepper::step`],
//! the exact same code path the single-node engine runs. What this type
//! adds is the cluster plumbing: the node owns its runtime (the engine
//! borrows one), exposes routing snapshots ([`NodeView`]), tier ledgers
//! and report rollups, and adapts the stepper's prefix-cache
//! export/install hooks to fabric migrations.

use crate::harvest::HarvestRuntime;
use crate::kv::{KvOffloadManager, KvStats, SeqId};
use crate::memsim::{Ns, SimNode};
use crate::server::{
    CompletelyFair, Fcfs, NodeStepper, Request, RequestOutcome, Scheduler, ServeMetrics,
    SimEngineConfig,
};
use crate::tenantsim::{FleetStats, TenantFleet};

use super::router::NodeView;
use super::TierLedger;

/// Which decode scheduler each node runs (a buildable spec, since every
/// node needs its own scheduler instance).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerSpec {
    Fcfs,
    CompletelyFair { quantum: u32 },
}

impl SchedulerSpec {
    pub fn build(&self) -> Box<dyn Scheduler> {
        match *self {
            SchedulerSpec::Fcfs => Box::new(Fcfs::new()),
            SchedulerSpec::CompletelyFair { quantum } => Box::new(CompletelyFair::new(quantum)),
        }
    }

    /// Parse the config-file spelling (`server.scheduler` + quantum).
    pub fn parse(name: &str, quantum: u32) -> anyhow::Result<Self> {
        match name {
            "fcfs" => Ok(SchedulerSpec::Fcfs),
            "cf" | "completely-fair" => Ok(SchedulerSpec::CompletelyFair { quantum }),
            other => anyhow::bail!("unknown scheduler `{other}` (fcfs | cf)"),
        }
    }
}

/// Per-node slice of a [`super::ClusterReport`].
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub node: usize,
    pub metrics: ServeMetrics,
    pub kv_stats: KvStats,
    /// Requests the router assigned here.
    pub routed: u64,
    /// Requests served to completion here.
    pub finished: u64,
    /// Admissions whose prefill reused this node's cached prefix KV.
    pub prefix_hits: u64,
    /// Live harvest bytes by tier class at report time.
    pub ledger: TierLedger,
    /// Co-tenant fleet counters (None when this node runs without one).
    pub tenant: Option<FleetStats>,
    /// Per-request completion records in finish order.
    pub completions: Vec<RequestOutcome>,
    /// Engine iterations this node executed.
    pub steps: u64,
    /// Requests this node's admission controller shed.
    pub sheds: u64,
    /// Per-request latency attribution ledgers (None unless the engine
    /// config armed attribution — see [`crate::obs::attrib`]).
    pub attribution: Option<crate::obs::AttributionReport>,
}

/// One simulated server of the cluster: an owned runtime plus the
/// shared stepper.
pub struct ClusterNode {
    pub id: usize,
    hr: HarvestRuntime,
    stepper: NodeStepper,
    routed: u64,
}

impl ClusterNode {
    pub(crate) fn new(
        id: usize,
        node: SimNode,
        harvest: crate::harvest::HarvestConfig,
        placement: crate::harvest::PlacementSpec,
        engine: SimEngineConfig,
        sched: SchedulerSpec,
        tenants: Option<TenantFleet>,
    ) -> Self {
        let mut hr = HarvestRuntime::with_policy(node, harvest, placement.build());
        let mut stepper = NodeStepper::new(engine, sched.build(), 0);
        stepper.set_tenants(tenants);
        stepper.install(&mut hr);
        Self { id, hr, stepper, routed: 0 }
    }

    // -- introspection ---------------------------------------------------

    pub fn now(&self) -> Ns {
        self.hr.node.clock.now()
    }

    /// Requests waiting or decoding here.
    pub fn queue_depth(&self) -> usize {
        self.stepper.queue_depth()
    }

    pub fn has_work(&self) -> bool {
        self.stepper.has_work()
    }

    /// The virtual time of this node's next step (only meaningful while
    /// [`ClusterNode::has_work`]).
    pub(crate) fn next_event_time(&self) -> Ns {
        self.stepper.next_event_time(&self.hr)
    }

    pub fn holds_prefix(&self, group: u32) -> bool {
        self.stepper.holds_prefix(group)
    }

    /// The KV sequence holding `group`'s prefix blocks on this node.
    pub fn prefix_seq(&self, group: u32) -> Option<SeqId> {
        self.stepper.prefix_seq(group)
    }

    pub fn kv_manager(&self) -> &KvOffloadManager {
        self.stepper.kv_manager()
    }

    pub fn runtime(&self) -> &HarvestRuntime {
        &self.hr
    }

    /// This node's serving metrics so far.
    pub fn metrics(&self) -> &ServeMetrics {
        self.stepper.metrics()
    }

    /// Live harvest bytes by tier class (the node's slice of the
    /// cluster ledger).
    pub fn ledger(&self) -> TierLedger {
        TierLedger::snapshot(&self.hr)
    }

    /// Load snapshot for the router. `group` marks whose prefix
    /// membership to report. Besides the load triple, the view carries
    /// the control-plane signals harvest-priced routing consumes:
    /// per-tier harvestable bytes, tenant-held bytes, occupancy, churn
    /// counters, and the admission controller's accepting state.
    pub(crate) fn view(&self, group: Option<u32>) -> NodeView {
        let free_hbm =
            (0..self.hr.node.n_gpus()).map(|g| self.hr.node.harvestable_now(g)).sum();
        let cfg = self.stepper.config();
        let free_local_blocks = cfg
            .kv
            .local_capacity_blocks
            .saturating_sub(self.stepper.kv_manager().local_blocks());
        let now = self.hr.node.clock.now();
        let mut v = NodeView::new(self.id, self.queue_depth(), free_local_blocks);
        v.free_hbm_bytes = free_hbm;
        v.has_prefix = group.is_some_and(|g| self.stepper.holds_prefix(g));
        v.occupancy_pm = self.stepper.occupancy_pm();
        v.tenant_held_bytes = self.hr.node.gpus.iter().map(|g| g.tenant_used_at(now)).sum();
        v.harvest_host_bytes = self.hr.node.host.free_bytes();
        v.harvest_cxl_bytes = self.hr.node.cxl.free_bytes();
        v.harvest_ssd_bytes = self.hr.node.ssd.free_bytes();
        v.sheds = self.stepper.shed_ids().len() as u64;
        v.demotions = self.hr.demotions;
        v.accepting = self.stepper.admission_accepting();
        v.block_bytes = cfg.kv.block_bytes();
        v
    }

    pub(crate) fn report(&self) -> NodeReport {
        NodeReport {
            node: self.id,
            metrics: self.stepper.metrics().clone(),
            kv_stats: self.stepper.kv_manager().stats.clone(),
            routed: self.routed,
            finished: self.stepper.finished(),
            prefix_hits: self.stepper.prefix_hits(),
            ledger: self.ledger(),
            tenant: self.stepper.tenant_stats(),
            completions: self.stepper.completions().to_vec(),
            steps: self.stepper.steps(),
            sheds: self.stepper.shed_ids().len() as u64,
            attribution: self.stepper.attribution_report(),
        }
    }

    /// This node's co-tenant fleet counters, when one is attached.
    pub fn tenant_stats(&self) -> Option<FleetStats> {
        self.stepper.tenant_stats()
    }

    /// Requests this node's admission controller shed, in decision order.
    pub fn shed_ids(&self) -> &[SeqId] {
        self.stepper.shed_ids()
    }

    /// The node stepper's admission-controller counters, when one runs.
    pub fn admission_stats(&self) -> Option<crate::control::AdmissionStats> {
        self.stepper.admission_stats()
    }

    // -- routing-side entry points ---------------------------------------

    /// Accept a routed request (arrivals are handed over in global
    /// arrival order, so the pending queue stays arrival-sorted).
    pub(crate) fn enqueue(&mut self, req: Request) {
        self.routed += 1;
        self.stepper.enqueue(req);
    }

    /// Read out `seq`'s blocks for a fabric migration (see
    /// [`NodeStepper::export_prefix`]).
    pub(crate) fn export_prefix(&mut self, group: u32) -> Option<(u32, u64, Ns)> {
        self.stepper.export_prefix(&mut self.hr, group)
    }

    /// Land a migrated prefix (see [`NodeStepper::install_prefix`]).
    pub(crate) fn install_prefix(&mut self, group: u32, tokens: u32, ready_at: Ns) {
        self.stepper.install_prefix(&mut self.hr, group, tokens, ready_at)
    }

    // -- the step loop ---------------------------------------------------

    /// Run one engine iteration — exactly
    /// [`crate::server::NodeStepper::step`], the same loop body
    /// `SimEngine::run` executes.
    pub(crate) fn step(&mut self) {
        crate::obs::trace::set_node(self.id as u32);
        self.stepper.step(&mut self.hr);
    }

    /// Finalize metrics at end of run (attach the prefetch ledger).
    pub(crate) fn finalize(&mut self) {
        self.stepper.finalize();
    }
}
