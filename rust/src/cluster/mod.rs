//! Scale-out cluster serving: multi-node sharding, affinity-aware
//! request routing, and cross-node harvest (ROADMAP "Scale-out
//! serving").
//!
//! Everything below this module simulates *one* server node. A
//! [`Cluster`] lifts that stack to N nodes:
//!
//! ```text
//!             arrivals (global virtual-time order)
//!                  │
//!              ┌───▼────┐   per-arrival NodeView snapshots
//!              │ Router │◄───────────────────────────────┐
//!              └───┬────┘                                │
//!     assign / shed│        ┌────────────────────────────┤
//!        ┌─────────┼────────┼──────────┐                 │
//!   ┌────▼───┐ ┌───▼────┐ ┌─▼──────┐   │            ┌────┴───┐
//!   │ node 0 │ │ node 1 │ │ node 2 │  ...           │ node N │
//!   │ HR+KV  │ │ HR+KV  │ │ HR+KV  │                │ HR+KV  │
//!   └────┬───┘ └───┬────┘ └─┬──────┘                └────┬───┘
//!        └───── NodeFabric (RDMA / Ethernet NICs) ───────┘
//!                 prefix-KV spillover migrations
//! ```
//!
//! * Every [`node::ClusterNode`] owns a full single-node stack — its own
//!   [`crate::memsim::SimNode`], [`crate::harvest::HarvestRuntime`], and
//!   a [`crate::server::NodeStepper`] (KV manager, scheduler, metrics) —
//!   stepped incrementally, one iteration of the *same* loop body
//!   [`crate::server::SimEngine`] runs (one stepper, diverge-proof by
//!   the differential tests).
//! * The cluster event loop is a conservative discrete-event scheduler
//!   over one shared virtual timeline, dispatched off an
//!   [`calendar::EventCalendar`] (binary heap keyed on time): at each
//!   turn it pops the earliest event — the next request arrival (routed
//!   against live node snapshots) or a node's next decode step — so node
//!   clocks advance in global order and routing decisions never see the
//!   future. Each dispatch costs O(log heap), not O(nodes).
//! * The [`router::Router`] picks a node per arrival (round-robin /
//!   least-loaded / prefix-affinity, TOML `cluster.router_policy`), and
//!   sheds when every node is saturated.
//! * Affinity spillover moves a session's prefix-KV blocks between nodes
//!   over the [`NodeFabric`]: the source node restores residency through
//!   its lease machinery and egresses to host staging, the NIC transfer
//!   rides the fabric link (FIFO per direction), and the target node
//!   rebuilds the blocks behind a `ready_at` gate that overlaps the
//!   remaining prefill.
//!
//! Per-node metrics roll up into one aggregate [`ServeMetrics`] whose
//! makespan is the union window — `tokens_per_sec` is genuine aggregate
//! cluster throughput, not a sum of per-node rates.

pub mod calendar;
pub mod node;
pub mod router;

pub use calendar::{Event, EventCalendar};
pub use node::{ClusterNode, NodeReport, SchedulerSpec};
pub use router::{NodeView, RouteDecision, Router, RouterPolicy};

use crate::control::AdmissionPolicy;
use crate::harvest::{HarvestConfig, HarvestRuntime, PlacementSpec};
use crate::kv::SeqId;
use crate::memsim::{NodeFabric, NodeFabricKind, NodeSpec, Ns, SimNode};
use crate::server::{Request, ServeMetrics, SimEngineConfig};
use crate::tenantsim::{TenantFleet, TenantMix};
use crate::util::json::{obj, Json};
use std::collections::{BTreeMap, VecDeque};

/// Live harvest bytes by tier class — one node's slice, or the cluster
/// rollup (the conservation property test pins per-node slices summing
/// exactly to the rollup).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierLedger {
    pub peer: u64,
    pub cxl: u64,
    pub host: u64,
    /// Bytes parked on the SSD cold tier (paged, compressed or not).
    pub ssd: u64,
}

impl TierLedger {
    pub fn total(&self) -> u64 {
        self.peer + self.cxl + self.host + self.ssd
    }

    pub fn accumulate(&mut self, other: &TierLedger) {
        self.peer += other.peer;
        self.cxl += other.cxl;
        self.host += other.host;
        self.ssd += other.ssd;
    }

    /// Register the live-bytes-by-tier snapshot into the unified metrics
    /// registry under `prefix` (e.g. `"ledger"`).
    pub fn register(&self, reg: &mut crate::obs::MetricsRegistry, prefix: &str) {
        reg.counter(&format!("{prefix}.peer_bytes"), self.peer);
        reg.counter(&format!("{prefix}.cxl_bytes"), self.cxl);
        reg.counter(&format!("{prefix}.host_bytes"), self.host);
        reg.counter(&format!("{prefix}.ssd_bytes"), self.ssd);
        reg.counter(&format!("{prefix}.total_bytes"), self.total());
    }

    /// Live harvest bytes by tier class on one runtime — a node's slice
    /// of the cluster ledger, and what the differential tests compare
    /// between a bare engine run and a 1-node cluster run.
    pub fn snapshot(hr: &HarvestRuntime) -> TierLedger {
        use crate::harvest::MemoryTier;
        TierLedger {
            peer: (0..hr.node.n_gpus()).map(|g| hr.live_bytes_on(g)).sum(),
            cxl: hr.live_bytes_on_tier(MemoryTier::CxlMem),
            host: hr.live_bytes_on_tier(MemoryTier::Host),
            ssd: hr.live_bytes_on_tier(MemoryTier::Ssd),
        }
    }
}

/// One entry of the cluster's dispatch log: what [`Cluster::run`]'s
/// event calendar dispatched, in order. The ordering property tests
/// assert over this — dispatch times never decrease, and no node steps
/// past an arrival that is still waiting to be routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// An arrival was routed to `node` at `at`.
    Route { at: Ns, node: usize },
    /// An arrival was shed at `at` (every node saturated).
    Shed { at: Ns },
    /// Node `node` ran one stepper iteration falling due at `at`.
    Step { at: Ns, node: usize },
}

impl Dispatch {
    /// The virtual time this dispatch fell due.
    pub fn at(&self) -> Ns {
        match *self {
            Dispatch::Route { at, .. } | Dispatch::Shed { at } | Dispatch::Step { at, .. } => at,
        }
    }
}

/// Cluster shape + routing knobs (materialized from
/// [`crate::config::DeploymentConfig`] in deployments).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Node count (1 = the single-node stack behind the same interface).
    pub nodes: usize,
    /// Shape of every node (homogeneous fleet).
    pub node: NodeSpec,
    /// Harvest controller config for every node.
    pub harvest: HarvestConfig,
    /// Inter-node link class.
    pub fabric: NodeFabricKind,
    pub router: RouterPolicy,
    /// Queue depth at which affinity routing spills off the prefix
    /// holder (migrating the prefix KV).
    pub spill_queue_depth: usize,
    /// Per-node queue depth at which a node stops accepting; when every
    /// node is there, arrivals are shed.
    ///
    /// **Deprecated shim** — the static spelling of what
    /// [`ClusterSpec::admission`] now controls. Honored only while
    /// `admission` is left at its default; see
    /// [`ClusterSpec::effective_admission`].
    pub shed_queue_depth: usize,
    /// Admission policy every node runs: the legacy static queue-depth
    /// gate, or the SLO control plane
    /// ([`crate::control::AdmissionController`]).
    pub admission: AdmissionPolicy,
    /// Harvest placement policy every node's runtime uses.
    pub placement: PlacementSpec,
    /// Co-tenant mix every node runs (None = no closed-loop tenants).
    pub tenants: Option<TenantMix>,
    /// Per-node mix overrides (node id → mix) on top of `tenants` —
    /// heterogeneous pressure across the fleet. An override with
    /// `enabled = false` turns that node's tenants off entirely.
    pub tenant_overrides: BTreeMap<usize, TenantMix>,
}

impl ClusterSpec {
    /// `nodes` × the paper's 2×H100 testbed, RDMA-wired, least-loaded
    /// routing, no shedding.
    pub fn new(nodes: usize) -> Self {
        Self {
            nodes,
            node: NodeSpec::h100x2(),
            harvest: HarvestConfig::for_node(2),
            fabric: NodeFabricKind::default(),
            router: RouterPolicy::default(),
            spill_queue_depth: 16,
            shed_queue_depth: usize::MAX,
            admission: AdmissionPolicy::default(),
            placement: PlacementSpec::default(),
            tenants: None,
            tenant_overrides: BTreeMap::new(),
        }
    }

    /// The admission policy the cluster actually runs: `admission`,
    /// except that a default (never-shed static) policy inherits the
    /// legacy `shed_queue_depth` knob — so old specs that only set
    /// `shed_queue_depth` keep working bit-for-bit.
    pub fn effective_admission(&self) -> AdmissionPolicy {
        match self.admission {
            AdmissionPolicy::StaticDepth { shed_queue_depth } if shed_queue_depth == usize::MAX => {
                AdmissionPolicy::StaticDepth { shed_queue_depth: self.shed_queue_depth }
            }
            other => other,
        }
    }

    /// The mix node `id` runs (override, else the fleet-wide mix).
    fn mix_for(&self, id: usize) -> Option<&TenantMix> {
        self.tenant_overrides.get(&id).or(self.tenants.as_ref())
    }
}

/// Cluster-level counters.
#[derive(Debug, Clone, Default)]
pub struct ClusterStats {
    /// Requests assigned to a node.
    pub routed: u64,
    /// Requests rejected at the router because every node was saturated
    /// (static admission only — the SLO control plane sheds at nodes).
    pub shed: u64,
    /// Requests shed *after* routing by per-node admission controllers
    /// (SLO admission only; filled in at report time).
    pub node_shed: u64,
    /// Prefix-KV spillover migrations performed over the node fabric.
    pub prefix_migrations: u64,
    /// Bytes those migrations moved node-to-node.
    pub migrated_bytes: u64,
}

/// Result of [`Cluster::run`].
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub per_node: Vec<NodeReport>,
    /// All nodes' metrics merged; makespan = earliest start → latest
    /// finish, so `aggregate.tokens_per_sec()` is cluster throughput.
    pub aggregate: ServeMetrics,
    pub stats: ClusterStats,
    /// Total bytes moved over the inter-node fabric (migrations).
    pub fabric_bytes: u64,
    /// Which node served each admitted request.
    pub assignments: BTreeMap<SeqId, usize>,
    /// Requests shed at the router.
    pub shed: Vec<SeqId>,
    pub router_policy: &'static str,
    /// Sum of the per-node ledgers.
    pub ledger: TierLedger,
    /// Cluster-wide rollup of the per-node attribution ledgers (None
    /// unless the engine config armed attribution). Deliberately *not*
    /// part of [`ClusterReport::to_json`] — the differential tests
    /// compare that JSON armed-vs-off; attribution surfaces through the
    /// metrics registry and `serve --report` instead.
    pub attribution: Option<crate::obs::AttributionReport>,
}

impl ClusterReport {
    /// The node that served `seq` (None if shed).
    pub fn node_of(&self, seq: SeqId) -> Option<usize> {
        self.assignments.get(&seq).copied()
    }

    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .per_node
            .iter()
            .map(|n| {
                let mut o = match n.metrics.to_json() {
                    Json::Obj(m) => m,
                    _ => unreachable!("metrics serialize to an object"),
                };
                o.insert("node".into(), Json::from(n.node));
                o.insert("routed".into(), Json::from(n.routed));
                o.insert("finished".into(), Json::from(n.finished));
                o.insert("prefix_hits".into(), Json::from(n.prefix_hits));
                o.insert("kv_reloads".into(), Json::from(n.kv_stats.reloads()));
                o.insert("sheds".into(), Json::from(n.sheds));
                Json::Obj(o)
            })
            .collect();
        obj([
            ("router_policy", Json::from(self.router_policy)),
            ("nodes", Json::from(self.per_node.len())),
            ("routed", Json::from(self.stats.routed)),
            ("shed", Json::from(self.stats.shed)),
            ("node_shed", Json::from(self.stats.node_shed)),
            ("prefix_migrations", Json::from(self.stats.prefix_migrations)),
            ("migrated_bytes", Json::from(self.stats.migrated_bytes)),
            ("fabric_bytes", Json::from(self.fabric_bytes)),
            ("aggregate", self.aggregate.to_json()),
            ("per_node", Json::Arr(nodes)),
        ])
    }
}

/// The multi-node deployment: N stepped nodes + router + node fabric,
/// dispatched off one [`EventCalendar`].
pub struct Cluster {
    nodes: Vec<ClusterNode>,
    fabric: NodeFabric,
    router: Router,
    stats: ClusterStats,
    assignments: BTreeMap<SeqId, usize>,
    shed: Vec<SeqId>,
    dispatches: Vec<Dispatch>,
    /// Router-view scratch, reused per arrival (no per-event allocs).
    views: Vec<NodeView>,
}

impl Cluster {
    pub fn new(spec: &ClusterSpec, engine: SimEngineConfig, sched: SchedulerSpec) -> Self {
        assert!(spec.nodes >= 1, "a cluster needs at least one node");
        let n_gpus = spec.node.gpus.len();
        let hbm_bytes = spec.node.gpus.first().map(|g| g.hbm_bytes).unwrap_or(0);
        let admission = spec.effective_admission();
        // SLO admission lives in the node steppers (the router only
        // steers toward accepting nodes); under static admission the
        // engine config passes through untouched (callers may still arm
        // a controller directly, as the differential tests do).
        let mut engine = engine;
        if let Some(acfg) = admission.admission_config() {
            engine.admission = Some(acfg);
        }
        let nodes = (0..spec.nodes)
            .map(|id| {
                // Per-node fleet, seeded with the node id so one mix
                // still yields decorrelated (heterogeneous) pressure.
                let fleet = spec.mix_for(id).map(|mix| {
                    TenantFleet::from_mix(mix, n_gpus, hbm_bytes, id as u64)
                });
                ClusterNode::new(
                    id,
                    SimNode::new(spec.node.clone()),
                    spec.harvest.clone(),
                    spec.placement,
                    engine,
                    sched,
                    fleet.filter(|f| !f.is_empty()),
                )
            })
            .collect();
        Self {
            nodes,
            fabric: NodeFabric::new(spec.nodes, spec.fabric),
            router: Router::with_admission(spec.router, spec.spill_queue_depth, admission),
            stats: ClusterStats::default(),
            assignments: BTreeMap::new(),
            shed: Vec::new(),
            dispatches: Vec::new(),
            views: Vec::new(),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, i: usize) -> &ClusterNode {
        &self.nodes[i]
    }

    pub fn fabric(&self) -> &NodeFabric {
        &self.fabric
    }

    pub fn router_policy(&self) -> RouterPolicy {
        self.router.policy()
    }

    /// The dispatch log of the last [`Cluster::run`]: every event the
    /// calendar dispatched, in dispatch order (the ordering property
    /// tests assert over this).
    pub fn dispatch_log(&self) -> &[Dispatch] {
        &self.dispatches
    }

    /// Serve `requests` to completion (or shed) across the cluster.
    /// Callable once per cluster; the nodes' state stays inspectable
    /// afterwards (tests verify ledgers against the live runtimes).
    ///
    /// Dispatch runs off an [`EventCalendar`]: the head arrival and
    /// every working node's next step share one binary heap, so each
    /// dispatched event costs O(log heap) instead of the old O(nodes)
    /// laggard scan. Semantics are unchanged — events dispatch in
    /// nondecreasing time, arrivals route before node steps at equal
    /// times (so routing never sees state older than the arrival
    /// instant), and lower node ids step first on ties.
    pub fn run(&mut self, mut requests: Vec<Request>) -> ClusterReport {
        requests.sort_by_key(|r| (r.arrival, r.id.0));
        let mut arrivals: VecDeque<Request> = requests.into();
        let mut cal = EventCalendar::new(self.nodes.len());
        if let Some(r) = arrivals.front() {
            cal.push_arrival(r.arrival);
        }
        while let Some((at, ev)) = cal.pop() {
            match ev {
                Event::Arrival => {
                    let req = arrivals.pop_front().expect("arrival event implies a queued request");
                    if let Some(next) = arrivals.front() {
                        cal.push_arrival(next.arrival);
                    }
                    self.route(at, req, &mut cal);
                }
                Event::NodeReady(id) => {
                    self.nodes[id].step();
                    self.dispatches.push(Dispatch::Step { at, node: id });
                    let n = &self.nodes[id];
                    cal.refresh_node(id, n.has_work(), n.next_event_time());
                }
            }
        }
        for n in &mut self.nodes {
            n.finalize();
        }
        self.report()
    }

    fn route(&mut self, at: Ns, req: Request, cal: &mut EventCalendar) {
        self.views.clear();
        self.views.extend(self.nodes.iter().map(|n| n.view(req.prefix_group)));
        match self.router.route(&req, &self.views) {
            RouteDecision::Shed => {
                crate::obs::trace::instant(
                    crate::obs::trace::Subsystem::Router,
                    "shed",
                    at,
                    &[("req", req.id.0)],
                );
                self.stats.shed += 1;
                self.shed.push(req.id);
                self.dispatches.push(Dispatch::Shed { at });
            }
            RouteDecision::Assign { node, migrate_prefix_from } => {
                crate::obs::trace::instant(
                    crate::obs::trace::Subsystem::Router,
                    "assign",
                    at,
                    &[
                        ("req", req.id.0),
                        ("node", node as u64),
                        ("queue", self.views[node].queue_depth as u64),
                        ("occ_pm", self.views[node].occupancy_pm as u64),
                    ],
                );
                let mut migration_src = None;
                if let (Some(from), Some(group)) = (migrate_prefix_from, req.prefix_group) {
                    if from != node && !self.nodes[node].holds_prefix(group) {
                        self.migrate_prefix(from, node, group);
                        migration_src = Some(from);
                    }
                }
                self.stats.routed += 1;
                self.assignments.insert(req.id, node);
                self.nodes[node].enqueue(req);
                self.dispatches.push(Dispatch::Route { at, node });
                // Re-key every node this arrival touched: the assigned
                // node gained work; a migration source's clock advanced
                // (residency restore + D2H egress).
                let n = &self.nodes[node];
                cal.refresh_node(node, n.has_work(), n.next_event_time());
                if let Some(src) = migration_src.filter(|&s| s != node) {
                    let n = &self.nodes[src];
                    cal.refresh_node(src, n.has_work(), n.next_event_time());
                }
            }
        }
    }

    /// Move a prefix group's KV blocks `from` → `to` over the node
    /// fabric: source-side residency restore + D2H egress (lease
    /// machinery), the NIC hop (FIFO contention per direction), then
    /// target-side rebuild gated on the delivery time.
    fn migrate_prefix(&mut self, from: usize, to: usize, group: u32) {
        crate::obs::trace::set_node(from as u32);
        let Some((tokens, bytes, src_ready)) = self.nodes[from].export_prefix(group) else {
            return;
        };
        let earliest = src_ready.max(self.nodes[to].now());
        let delivered = match self.fabric.schedule(from, to, bytes, earliest) {
            Some((_, end)) => end,
            None => earliest, // single-node degenerate case
        };
        crate::obs::trace::set_node(to as u32);
        crate::obs::trace::span(
            crate::obs::trace::Subsystem::Router,
            "migrate_prefix",
            earliest,
            delivered.max(earliest),
            &[("from", from as u64), ("to", to as u64), ("group", group as u64), ("bytes", bytes)],
        );
        self.nodes[to].install_prefix(group, tokens, delivered);
        self.stats.prefix_migrations += 1;
        self.stats.migrated_bytes += bytes;
    }

    fn report(&self) -> ClusterReport {
        let per_node: Vec<NodeReport> = self.nodes.iter().map(|n| n.report()).collect();
        let mut aggregate = ServeMetrics::new();
        let mut ledger = TierLedger::default();
        let mut stats = self.stats.clone();
        let mut attribution: Option<crate::obs::AttributionReport> = None;
        for n in &per_node {
            aggregate.merge(&n.metrics);
            ledger.accumulate(&n.ledger);
            stats.node_shed += n.sheds;
            if let Some(a) = &n.attribution {
                match attribution.as_mut() {
                    Some(rollup) => rollup.merge(a),
                    None => attribution = Some(a.clone()),
                }
            }
        }
        ClusterReport {
            per_node,
            aggregate,
            stats,
            fabric_bytes: self.fabric.total_bytes_moved(),
            assignments: self.assignments.clone(),
            shed: self.shed.clone(),
            router_policy: self.router.policy().name(),
            ledger,
            attribution,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::KvConfig;
    use crate::moe::find_kv_model;
    use crate::server::{WorkloadGen, WorkloadSpec};

    fn engine(cap_blocks: usize, slots: usize, max_running: usize) -> SimEngineConfig {
        let kv = KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap_blocks,
            use_harvest: true,
            host_backed_peer: false,
        };
        SimEngineConfig::new(kv, slots, max_running)
    }

    fn workload(n: usize, shared: f64, groups: usize, gap_ns: u64) -> Vec<Request> {
        WorkloadGen::new(WorkloadSpec {
            n_requests: n,
            mean_prompt_tokens: 64.0,
            max_new_tokens: 8,
            mean_interarrival_ns: gap_ns,
            shared_prefix_fraction: shared,
            shared_prefix_tokens: 32,
            n_prefix_groups: groups,
            ..Default::default()
        })
        .generate()
    }

    fn run_cluster(nodes: usize, policy: RouterPolicy, reqs: Vec<Request>) -> ClusterReport {
        let mut spec = ClusterSpec::new(nodes);
        spec.router = policy;
        let mut cluster = Cluster::new(&spec, engine(10_000, 8, 16), SchedulerSpec::Fcfs);
        cluster.run(reqs)
    }

    #[test]
    fn single_node_cluster_serves_everything() {
        let r = run_cluster(1, RouterPolicy::RoundRobin, workload(12, 0.0, 1, 0));
        assert_eq!(r.aggregate.requests_finished, 12);
        assert_eq!(r.aggregate.tokens_generated, 12 * 8);
        assert_eq!(r.stats.routed, 12);
        assert_eq!(r.stats.shed, 0);
        assert_eq!(r.per_node.len(), 1);
        assert!(r.aggregate.tokens_per_sec() > 0.0);
    }

    #[test]
    fn round_robin_spreads_requests_across_nodes() {
        let r = run_cluster(3, RouterPolicy::RoundRobin, workload(12, 0.0, 1, 0));
        assert_eq!(r.aggregate.requests_finished, 12);
        for n in &r.per_node {
            assert_eq!(n.routed, 4, "round-robin assigns evenly");
            assert_eq!(n.finished, 4);
        }
        // assignments cycle 0,1,2,0,1,2,... in arrival (= id) order
        assert_eq!(r.node_of(SeqId(0)), Some(0));
        assert_eq!(r.node_of(SeqId(1)), Some(1));
        assert_eq!(r.node_of(SeqId(2)), Some(2));
        assert_eq!(r.node_of(SeqId(3)), Some(0));
    }

    #[test]
    fn affinity_keeps_groups_together_and_hits_prefix_cache() {
        let reqs = workload(24, 1.0, 2, 2_000_000);
        let r = run_cluster(3, RouterPolicy::PrefixAffinity, reqs.clone());
        assert_eq!(r.aggregate.requests_finished, 24);
        // every request of a group landed on one node
        let mut group_node: BTreeMap<u32, usize> = BTreeMap::new();
        for req in &reqs {
            let g = req.prefix_group.expect("fraction 1.0");
            let node = r.node_of(req.id).expect("served");
            assert_eq!(*group_node.entry(g).or_insert(node), node, "group split across nodes");
        }
        // all admissions after the first per group reused the prefix
        let hits: u64 = r.per_node.iter().map(|n| n.prefix_hits).sum();
        assert_eq!(hits, 24 - group_node.len() as u64);
    }

    #[test]
    fn affinity_spills_and_migrates_prefix_over_fabric() {
        // One group and a spill threshold of 1: as soon as the holder
        // has any request queued or decoding, the next arrival spills —
        // which must move the prefix KV over the fabric. Arrivals are
        // staggered so the holder is established before the burst.
        let mut spec = ClusterSpec::new(2);
        spec.router = RouterPolicy::PrefixAffinity;
        spec.spill_queue_depth = 1;
        let mut cluster = Cluster::new(&spec, engine(10_000, 4, 4), SchedulerSpec::Fcfs);
        let r = cluster.run(workload(16, 1.0, 1, 2_000_000));
        assert_eq!(r.aggregate.requests_finished, 16);
        assert!(r.stats.prefix_migrations >= 1, "{:?}", r.stats);
        assert!(r.stats.migrated_bytes > 0);
        assert_eq!(r.fabric_bytes, r.stats.migrated_bytes, "only migrations ride the fabric");
        // both nodes ended up holding the group's prefix
        assert!(cluster.node(0).holds_prefix(0));
        assert!(cluster.node(1).holds_prefix(0));
    }

    #[test]
    fn shed_threshold_rejects_exactly_once_per_request() {
        let mut spec = ClusterSpec::new(2);
        spec.router = RouterPolicy::LeastLoaded;
        spec.shed_queue_depth = 3;
        // burst arrival: queues saturate instantly, later arrivals shed
        let mut cluster = Cluster::new(&spec, engine(10_000, 2, 4), SchedulerSpec::Fcfs);
        let r = cluster.run(workload(20, 0.0, 1, 0));
        assert!(r.stats.shed > 0, "burst must exceed 2 nodes x 3 queue slots");
        assert_eq!(r.stats.routed + r.stats.shed, 20);
        assert_eq!(r.aggregate.requests_finished, r.stats.routed);
        assert_eq!(r.shed.len() as u64, r.stats.shed);
        for id in &r.shed {
            assert!(r.node_of(*id).is_none(), "shed request must not be assigned");
        }
    }

    #[test]
    fn per_node_ledgers_sum_to_cluster_ledger() {
        // Tight pools force offload to harvest tiers; prefix seqs stay
        // cached past the run, so the end-of-run ledger is non-trivial.
        let mut spec = ClusterSpec::new(2);
        spec.router = RouterPolicy::PrefixAffinity;
        let mut cluster = Cluster::new(&spec, engine(24, 4, 8), SchedulerSpec::Fcfs);
        let r = cluster.run(workload(16, 0.5, 2, 0));
        assert_eq!(r.aggregate.requests_finished, 16);
        let mut sum = TierLedger::default();
        for (i, n) in r.per_node.iter().enumerate() {
            assert_eq!(n.ledger, cluster.node(i).ledger(), "report snapshots live state");
            sum.accumulate(&n.ledger);
        }
        assert_eq!(sum, r.ledger);
    }

    #[test]
    fn aggregate_throughput_scales_with_nodes() {
        let tps = |nodes| {
            run_cluster(nodes, RouterPolicy::LeastLoaded, workload(48, 0.0, 1, 0))
                .aggregate
                .tokens_per_sec()
        };
        let one = tps(1);
        let two = tps(2);
        let four = tps(4);
        assert!(two > one * 1.3, "2 nodes: {two:.0} <= 1.3x {one:.0}");
        assert!(four > two * 1.3, "4 nodes: {four:.0} <= 1.3x {two:.0}");
    }
}
