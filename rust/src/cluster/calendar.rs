//! The cluster's event calendar: a binary heap over `(time, class, id)`
//! replacing the old O(nodes) laggard scan per dispatched event.
//!
//! Two event classes share one timeline:
//!
//! * **Arrival** — the next request in the global arrival stream. Only
//!   the *head* arrival is ever in the heap (the stream is pre-sorted);
//!   popping it routes the request and pushes its successor.
//! * **NodeReady** — node `id` has work and its next step falls due at
//!   the keyed time ([`crate::cluster::ClusterNode::next_event_time`]).
//!
//! Node entries are invalidated *lazily*: touching a node (routing to
//! it, stepping it, using it as a migration source) bumps its
//! generation counter and pushes a fresh entry; stale entries are
//! discarded on pop. That keeps every operation O(log heap) with no
//! rebuilds.
//!
//! Tie-breaking preserves the laggard scan's semantics exactly: at
//! equal times an arrival dispatches before any node step (`Arrival`
//! compares below `NodeReady`), and earlier node ids step first. Pop
//! times are provably nondecreasing — refreshed node entries never key
//! earlier than the event that caused the refresh — which
//! `rust/tests/proptests.rs::prop_event_calendar_ordering` pins down.

use crate::memsim::Ns;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// What the calendar popped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Dispatch the head of the arrival stream.
    Arrival,
    /// Step node `.0`.
    NodeReady(usize),
}

/// Heap key: `(time, class, node-id, generation)`. Class 0 = arrival,
/// class 1 = node-ready, so arrivals win ties; node id breaks
/// node-vs-node ties like the old `min()` scan did.
type Key = (Ns, u8, usize, u64);

/// The calendar. See the module docs for semantics.
#[derive(Debug, Default)]
pub struct EventCalendar {
    heap: BinaryHeap<Reverse<Key>>,
    /// Current generation per node; heap entries carrying an older
    /// generation are stale and skipped on pop.
    node_gen: Vec<u64>,
}

impl EventCalendar {
    pub fn new(n_nodes: usize) -> Self {
        Self { heap: BinaryHeap::new(), node_gen: vec![0; n_nodes] }
    }

    /// Key the head of the arrival stream. Call once at startup and
    /// once after each [`Event::Arrival`] pop (with the new head).
    pub fn push_arrival(&mut self, at: Ns) {
        self.heap.push(Reverse((at, 0, 0, 0)));
    }

    /// Re-key node `id` after its state changed: its previous entry (if
    /// any) becomes stale; when `has_work`, a fresh entry lands at
    /// `at`. Call after routing to a node, stepping it, or advancing
    /// its clock as a migration source.
    pub fn refresh_node(&mut self, id: usize, has_work: bool, at: Ns) {
        self.node_gen[id] += 1;
        if has_work {
            self.heap.push(Reverse((at, 1, id, self.node_gen[id])));
        }
    }

    /// Pop the earliest live event, discarding stale node entries.
    /// Returns `None` when nothing is pending — with the push
    /// discipline above that means: no queued arrival and no node with
    /// work.
    pub fn pop(&mut self) -> Option<(Ns, Event)> {
        while let Some(Reverse((at, class, id, gen))) = self.heap.pop() {
            if class == 0 {
                return Some((at, Event::Arrival));
            }
            if gen == self.node_gen[id] {
                return Some((at, Event::NodeReady(id)));
            }
        }
        None
    }

    /// Live + stale entries currently heaped (bench/diagnostic).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_win_ties_and_ids_break_node_ties() {
        let mut cal = EventCalendar::new(3);
        cal.refresh_node(2, true, 10);
        cal.refresh_node(1, true, 10);
        cal.push_arrival(10);
        assert_eq!(cal.pop(), Some((10, Event::Arrival)));
        assert_eq!(cal.pop(), Some((10, Event::NodeReady(1))));
        assert_eq!(cal.pop(), Some((10, Event::NodeReady(2))));
        assert_eq!(cal.pop(), None);
    }

    #[test]
    fn refresh_invalidates_stale_entries() {
        let mut cal = EventCalendar::new(2);
        cal.refresh_node(0, true, 5);
        cal.refresh_node(0, true, 9); // state changed; 5 is stale
        cal.refresh_node(1, true, 7);
        assert_eq!(cal.pop(), Some((7, Event::NodeReady(1))));
        assert_eq!(cal.pop(), Some((9, Event::NodeReady(0))));
        assert_eq!(cal.pop(), None);
    }

    #[test]
    fn refresh_without_work_just_invalidates() {
        let mut cal = EventCalendar::new(1);
        cal.refresh_node(0, true, 3);
        cal.refresh_node(0, false, 0);
        assert_eq!(cal.pop(), None);
    }

    #[test]
    fn pop_times_nondecreasing_under_interleaving() {
        let mut cal = EventCalendar::new(4);
        cal.push_arrival(0);
        let mut last = 0;
        let mut clock = 0;
        let mut popped = 0;
        for i in 0..200 {
            let Some((at, ev)) = cal.pop() else { break };
            popped += 1;
            assert!(at >= last, "pop went backwards: {at} < {last}");
            last = at;
            clock = clock.max(at);
            match ev {
                Event::Arrival => {
                    let node = i % 4;
                    cal.refresh_node(node, true, clock);
                    if i < 40 {
                        cal.push_arrival(at + (i as u64 % 3));
                    }
                }
                Event::NodeReady(n) => {
                    clock += 2;
                    cal.refresh_node(n, i % 5 != 0, clock);
                }
            }
        }
        assert!(popped > 40, "interleaving exercised both event classes: {popped}");
    }
}
