//! Request router: which node of the cluster serves an arriving request.
//!
//! The router acts on a per-arrival snapshot of every node
//! ([`NodeView`]) and never inspects node internals — exactly the
//! information a production front-end would scrape (queue depth, free KV
//! budget, per-tier harvestable bytes, prefix-cache membership,
//! admission state). Four policies:
//!
//! | policy | decision rule |
//! |---|---|
//! | [`RouterPolicy::RoundRobin`] | next node in id order, skipping shed-saturated nodes |
//! | [`RouterPolicy::LeastLoaded`] | minimize queue depth relative to free KV budget (queue pressure × memory headroom) |
//! | [`RouterPolicy::PrefixAffinity`] | the node already holding the request's shared-prefix KV; spills to the least-loaded node (migrating the prefix blocks over the node fabric) when the holder's queue exceeds the spill threshold; least-loaded for prefix-less requests |
//! | [`RouterPolicy::HarvestPriced`] | maximize harvest-priced capacity per queued request: free KV blocks at full price plus per-tier harvestable bytes discounted by reload cost and demotion risk ([`crate::control::pricing`]) |
//!
//! How a saturated cluster sheds depends on the
//! [`AdmissionPolicy`](crate::control::AdmissionPolicy): under the
//! legacy `StaticDepth` shim the *router* sheds when every node's queue
//! sits at or above the threshold — the admission-control half of the
//! queueing-stability picture ("A Queueing-Theoretic Framework for
//! Stability Analysis of LLM Inference", PAPERS.md). Under
//! `SloOccupancy` the router never sheds: it only *prefers* nodes whose
//! admission controller is accepting, and each node's controller owns
//! the admit/defer/shed decision (so shed accounting lives in exactly
//! one place).

use crate::control::pricing::{price_order, PricingWeights};
use crate::control::AdmissionPolicy;
use crate::server::Request;
use std::cmp::Ordering;

/// Routing policy selector (TOML: `cluster.router_policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterPolicy {
    /// Cycle through nodes in id order regardless of load.
    RoundRobin,
    /// Pick the node with the lowest queue-pressure-per-free-HBM score.
    #[default]
    LeastLoaded,
    /// Prefer the node holding the request's shared-prefix KV blocks;
    /// fall back to least-loaded (with prefix migration) under overload.
    PrefixAffinity,
    /// Maximize harvest-priced capacity per queued request (free KV
    /// blocks + tier-discounted harvestable bytes, churn-discounted).
    HarvestPriced,
}

impl RouterPolicy {
    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "round-robin" | "rr" => Ok(RouterPolicy::RoundRobin),
            "least-loaded" | "ll" => Ok(RouterPolicy::LeastLoaded),
            "affinity" | "prefix-affinity" => Ok(RouterPolicy::PrefixAffinity),
            "harvest-priced" | "priced" => Ok(RouterPolicy::HarvestPriced),
            other => anyhow::bail!(
                "unknown router policy `{other}` (round-robin | least-loaded | affinity | harvest-priced)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::PrefixAffinity => "affinity",
            RouterPolicy::HarvestPriced => "harvest-priced",
        }
    }
}

/// Per-node load snapshot the router decides on.
///
/// Construct with [`NodeView::new`] and fill in the enriched fields you
/// have; the defaults (zero bytes everywhere, `accepting`) keep simple
/// policies working without the control-plane signals.
#[derive(Debug, Clone, Copy)]
pub struct NodeView {
    pub node: usize,
    /// Requests queued for admission plus requests decoding.
    pub queue_depth: usize,
    /// Free slots in the node's local KV pool.
    pub free_local_blocks: usize,
    /// Harvestable peer-HBM bytes across the node's GPUs right now.
    pub free_hbm_bytes: u64,
    /// Whether this node holds the arriving request's prefix-group KV.
    pub has_prefix: bool,
    /// KV-block pool occupancy, per-mille.
    pub occupancy_pm: u32,
    /// Bytes currently held by co-located tenants across the node's GPUs.
    pub tenant_held_bytes: u64,
    /// Harvestable host-DRAM bytes.
    pub harvest_host_bytes: u64,
    /// Harvestable CXL-expander bytes.
    pub harvest_cxl_bytes: u64,
    /// Harvestable SSD bytes.
    pub harvest_ssd_bytes: u64,
    /// Requests this node's admission controller has shed so far.
    pub sheds: u64,
    /// Harvest-lease demotions this node has performed (tenant churn).
    pub demotions: u64,
    /// Whether the node's admission controller is below its high
    /// watermark (always `true` for nodes without a controller).
    pub accepting: bool,
    /// Bytes per KV block (prices `free_local_blocks` against raw bytes).
    pub block_bytes: u64,
}

impl NodeView {
    /// A view with the load triple set and every enriched signal at its
    /// neutral default (no harvestable bytes, no churn, accepting).
    pub fn new(node: usize, queue_depth: usize, free_local_blocks: usize) -> Self {
        Self {
            node,
            queue_depth,
            free_local_blocks,
            free_hbm_bytes: 0,
            has_prefix: false,
            occupancy_pm: 0,
            tenant_held_bytes: 0,
            harvest_host_bytes: 0,
            harvest_cxl_bytes: 0,
            harvest_ssd_bytes: 0,
            sheds: 0,
            demotions: 0,
            accepting: true,
            block_bytes: 0,
        }
    }
}

/// Outcome of routing one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteDecision {
    Assign {
        node: usize,
        /// When set, the request's shared-prefix KV blocks should be
        /// migrated from this node to `node` over the node fabric
        /// before the request's prefill can reuse them.
        migrate_prefix_from: Option<usize>,
    },
    /// Every node is at or above the shed threshold: reject.
    Shed,
}

/// Total order on load: `(queue+1) / (free_blocks+1)` compared by exact
/// integer cross-multiplication (no float ties), node id as tiebreak.
fn load_order(a: &NodeView, b: &NodeView) -> Ordering {
    let lhs = (a.queue_depth as u128 + 1) * (b.free_local_blocks as u128 + 1);
    let rhs = (b.queue_depth as u128 + 1) * (a.free_local_blocks as u128 + 1);
    lhs.cmp(&rhs)
        .then_with(|| b.free_hbm_bytes.cmp(&a.free_hbm_bytes))
        .then_with(|| a.node.cmp(&b.node))
}

/// The router. Holds only policy state (the round-robin cursor); every
/// decision is a pure function of the views otherwise.
#[derive(Debug)]
pub struct Router {
    policy: RouterPolicy,
    /// Holder queue depth at which affinity routing spills elsewhere.
    spill_queue_depth: usize,
    /// How saturation is decided (and who sheds): see module docs.
    admission: AdmissionPolicy,
    weights: PricingWeights,
    rr_next: usize,
}

impl Router {
    /// Legacy constructor: static-depth admission (the `shed_queue_depth`
    /// shim). Equivalent to [`Router::with_admission`] with
    /// [`AdmissionPolicy::StaticDepth`].
    pub fn new(policy: RouterPolicy, spill_queue_depth: usize, shed_queue_depth: usize) -> Self {
        Self::with_admission(
            policy,
            spill_queue_depth,
            AdmissionPolicy::StaticDepth { shed_queue_depth },
        )
    }

    /// A router gated by the given admission policy.
    pub fn with_admission(
        policy: RouterPolicy,
        spill_queue_depth: usize,
        admission: AdmissionPolicy,
    ) -> Self {
        Self {
            policy,
            spill_queue_depth: spill_queue_depth.max(1),
            admission,
            weights: PricingWeights::default(),
            rr_next: 0,
        }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Whether this node is open to new work under the admission policy.
    fn node_open(&self, v: &NodeView) -> bool {
        match self.admission {
            AdmissionPolicy::StaticDepth { shed_queue_depth } => v.queue_depth < shed_queue_depth,
            AdmissionPolicy::SloOccupancy(_) => v.accepting,
        }
    }

    fn least_loaded(&self, views: &[NodeView], relaxed: bool) -> Option<usize> {
        views
            .iter()
            .filter(|v| relaxed || self.node_open(v))
            .min_by(|a, b| load_order(a, b))
            .map(|v| v.node)
    }

    /// Route one arriving request against the current node views (one
    /// [`NodeView`] per node, in node-id order).
    pub fn route(&mut self, req: &Request, views: &[NodeView]) -> RouteDecision {
        assert!(!views.is_empty(), "routing against an empty cluster");
        // `relaxed` means "ignore the per-node gate": set when no node
        // is open. Static admission sheds at the router instead; the
        // occupancy controller never sheds here — the chosen node's own
        // controller will defer or shed with full local information.
        let relaxed = !views.iter().any(|v| self.node_open(v));
        if relaxed && matches!(self.admission, AdmissionPolicy::StaticDepth { .. }) {
            return RouteDecision::Shed;
        }
        match self.policy {
            RouterPolicy::RoundRobin => {
                for _ in 0..views.len() {
                    let v = &views[self.rr_next % views.len()];
                    self.rr_next = (self.rr_next + 1) % views.len();
                    if relaxed || self.node_open(v) {
                        return RouteDecision::Assign { node: v.node, migrate_prefix_from: None };
                    }
                }
                RouteDecision::Shed
            }
            RouterPolicy::LeastLoaded => match self.least_loaded(views, relaxed) {
                Some(node) => RouteDecision::Assign { node, migrate_prefix_from: None },
                None => RouteDecision::Shed,
            },
            RouterPolicy::HarvestPriced => {
                let best = views
                    .iter()
                    .filter(|v| relaxed || self.node_open(v))
                    .min_by(|a, b| price_order(a, b, &self.weights));
                match best {
                    Some(v) => RouteDecision::Assign { node: v.node, migrate_prefix_from: None },
                    None => RouteDecision::Shed,
                }
            }
            RouterPolicy::PrefixAffinity => {
                let holder = req.prefix_group.and_then(|_| {
                    views
                        .iter()
                        .filter(|v| v.has_prefix && (relaxed || self.node_open(v)))
                        .min_by(|a, b| load_order(a, b))
                });
                match holder {
                    Some(h) if h.queue_depth < self.spill_queue_depth => {
                        RouteDecision::Assign { node: h.node, migrate_prefix_from: None }
                    }
                    Some(h) => {
                        // Holder overloaded: shed load to the least-loaded
                        // node and take the session's KV with it.
                        match self.least_loaded(views, relaxed) {
                            Some(node) if node != h.node => RouteDecision::Assign {
                                node,
                                migrate_prefix_from: Some(h.node),
                            },
                            Some(node) => {
                                RouteDecision::Assign { node, migrate_prefix_from: None }
                            }
                            None => RouteDecision::Shed,
                        }
                    }
                    None => match self.least_loaded(views, relaxed) {
                        Some(node) => RouteDecision::Assign { node, migrate_prefix_from: None },
                        None => RouteDecision::Shed,
                    },
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::AdmissionConfig;
    use crate::kv::SeqId;
    use crate::server::RequestState;

    fn req(group: Option<u32>) -> Request {
        Request {
            id: SeqId(0),
            arrival: 0,
            prompt_tokens: 100,
            max_new_tokens: 8,
            shared_prefix_tokens: if group.is_some() { 64 } else { 0 },
            prefix_group: group,
            state: RequestState::Queued,
            generated: 0,
            first_token_at: None,
            finished_at: None,
        }
    }

    fn view(node: usize, queue: usize, free: usize, has_prefix: bool) -> NodeView {
        let mut v = NodeView::new(node, queue, free);
        v.has_prefix = has_prefix;
        v
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 8, usize::MAX);
        let views = vec![view(0, 0, 10, false), view(1, 0, 10, false), view(2, 0, 10, false)];
        let picks: Vec<_> = (0..6)
            .map(|_| match r.route(&req(None), &views) {
                RouteDecision::Assign { node, .. } => node,
                RouteDecision::Shed => panic!("unexpected shed"),
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_queue_against_free_blocks() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 8, usize::MAX);
        // node 1 has a shorter queue relative to its free pool
        let views = vec![view(0, 4, 10, false), view(1, 2, 10, false)];
        assert_eq!(
            r.route(&req(None), &views),
            RouteDecision::Assign { node: 1, migrate_prefix_from: None }
        );
        // same queues: more free blocks wins
        let views = vec![view(0, 3, 5, false), view(1, 3, 50, false)];
        assert_eq!(
            r.route(&req(None), &views),
            RouteDecision::Assign { node: 1, migrate_prefix_from: None }
        );
        // exact tie: lowest id (deterministic)
        let views = vec![view(0, 3, 10, false), view(1, 3, 10, false)];
        assert_eq!(
            r.route(&req(None), &views),
            RouteDecision::Assign { node: 0, migrate_prefix_from: None }
        );
    }

    #[test]
    fn affinity_prefers_holder_until_spill_threshold() {
        let mut r = Router::new(RouterPolicy::PrefixAffinity, 4, usize::MAX);
        // holder busy but under the spill threshold: stay for the prefix
        let views = vec![view(0, 3, 10, true), view(1, 0, 10, false)];
        assert_eq!(
            r.route(&req(Some(7)), &views),
            RouteDecision::Assign { node: 0, migrate_prefix_from: None }
        );
        // holder at the threshold: spill to least-loaded, migrate the KV
        let views = vec![view(0, 4, 10, true), view(1, 0, 10, false)];
        assert_eq!(
            r.route(&req(Some(7)), &views),
            RouteDecision::Assign { node: 1, migrate_prefix_from: Some(0) }
        );
        // no prefix on the request: plain least-loaded
        let views = vec![view(0, 4, 10, true), view(1, 0, 10, false)];
        assert_eq!(
            r.route(&req(None), &views),
            RouteDecision::Assign { node: 1, migrate_prefix_from: None }
        );
    }

    #[test]
    fn shed_when_every_node_saturated() {
        for policy in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
            RouterPolicy::HarvestPriced,
        ] {
            let mut r = Router::new(policy, 4, 8);
            let views = vec![view(0, 8, 10, true), view(1, 9, 10, false)];
            assert_eq!(r.route(&req(Some(1)), &views), RouteDecision::Shed, "{policy:?}");
            // one node below the bound: served again
            let views = vec![view(0, 8, 10, true), view(1, 7, 10, false)];
            assert!(matches!(r.route(&req(Some(1)), &views), RouteDecision::Assign { .. }));
        }
    }

    #[test]
    fn harvest_priced_prefers_cheap_reloads() {
        let mut r = Router::new(RouterPolicy::HarvestPriced, 4, usize::MAX);
        // Equal queues and local pools; node 1 has host-harvestable
        // bytes, node 0 only SSD — host wins on reload cost.
        let mut v0 = view(0, 2, 10, false);
        v0.block_bytes = 4096;
        v0.harvest_ssd_bytes = 1 << 20;
        let mut v1 = view(1, 2, 10, false);
        v1.block_bytes = 4096;
        v1.harvest_host_bytes = 1 << 20;
        assert_eq!(
            r.route(&req(None), &[v0, v1]),
            RouteDecision::Assign { node: 1, migrate_prefix_from: None }
        );
        // Heavy demotion churn on node 1 discounts its harvest bytes
        // below node 0's SSD bytes.
        v1.demotions = 100_000;
        assert_eq!(
            r.route(&req(None), &[v0, v1]),
            RouteDecision::Assign { node: 0, migrate_prefix_from: None }
        );
    }

    #[test]
    fn occupancy_admission_prefers_accepting_but_never_sheds() {
        let admission = AdmissionPolicy::SloOccupancy(AdmissionConfig::default());
        let mut r = Router::with_admission(RouterPolicy::LeastLoaded, 4, admission);
        // Node 0 is the load-order winner but its controller is
        // pressured: route to the accepting node 1.
        let mut v0 = view(0, 0, 50, false);
        v0.accepting = false;
        let v1 = view(1, 3, 10, false);
        assert_eq!(
            r.route(&req(None), &[v0, v1]),
            RouteDecision::Assign { node: 1, migrate_prefix_from: None }
        );
        // Every controller pressured: still route (to the best node) —
        // the node-level controller owns the shed decision.
        let mut v1 = v1;
        v1.accepting = false;
        assert_eq!(
            r.route(&req(None), &[v0, v1]),
            RouteDecision::Assign { node: 0, migrate_prefix_from: None }
        );
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
            RouterPolicy::HarvestPriced,
        ] {
            assert_eq!(RouterPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RouterPolicy::parse("random").is_err());
    }
}
