//! Request router: which node of the cluster serves an arriving request.
//!
//! The router acts on a per-arrival snapshot of every node
//! ([`NodeView`]) and never inspects node internals — exactly the
//! information a production front-end would scrape (queue depth, free KV
//! budget, harvestable HBM, prefix-cache membership). Three policies:
//!
//! | policy | decision rule |
//! |---|---|
//! | [`RouterPolicy::RoundRobin`] | next node in id order, skipping shed-saturated nodes |
//! | [`RouterPolicy::LeastLoaded`] | minimize queue depth relative to free KV budget (queue pressure × memory headroom) |
//! | [`RouterPolicy::PrefixAffinity`] | the node already holding the request's shared-prefix KV; spills to the least-loaded node (migrating the prefix blocks over the node fabric) when the holder's queue exceeds the spill threshold; least-loaded for prefix-less requests |
//!
//! Every policy sheds (rejects) a request when *all* nodes sit at or
//! above the shed threshold — the admission-control half of the
//! queueing-stability picture ("A Queueing-Theoretic Framework for
//! Stability Analysis of LLM Inference", PAPERS.md): unbounded queues
//! under KV memory pressure destabilize every node at once, so the
//! router bounds them cluster-wide.

use crate::server::Request;
use std::cmp::Ordering;

/// Routing policy selector (TOML: `cluster.router_policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterPolicy {
    /// Cycle through nodes in id order regardless of load.
    RoundRobin,
    /// Pick the node with the lowest queue-pressure-per-free-HBM score.
    #[default]
    LeastLoaded,
    /// Prefer the node holding the request's shared-prefix KV blocks;
    /// fall back to least-loaded (with prefix migration) under overload.
    PrefixAffinity,
}

impl RouterPolicy {
    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "round-robin" | "rr" => Ok(RouterPolicy::RoundRobin),
            "least-loaded" | "ll" => Ok(RouterPolicy::LeastLoaded),
            "affinity" | "prefix-affinity" => Ok(RouterPolicy::PrefixAffinity),
            other => anyhow::bail!(
                "unknown router policy `{other}` (round-robin | least-loaded | affinity)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::PrefixAffinity => "affinity",
        }
    }
}

/// Per-node load snapshot the router decides on.
#[derive(Debug, Clone, Copy)]
pub struct NodeView {
    pub node: usize,
    /// Requests queued for admission plus requests decoding.
    pub queue_depth: usize,
    /// Free slots in the node's local KV pool.
    pub free_local_blocks: usize,
    /// Harvestable peer-HBM bytes across the node's GPUs right now.
    pub free_hbm_bytes: u64,
    /// Whether this node holds the arriving request's prefix-group KV.
    pub has_prefix: bool,
}

/// Outcome of routing one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteDecision {
    Assign {
        node: usize,
        /// When set, the request's shared-prefix KV blocks should be
        /// migrated from this node to `node` over the node fabric
        /// before the request's prefill can reuse them.
        migrate_prefix_from: Option<usize>,
    },
    /// Every node is at or above the shed threshold: reject.
    Shed,
}

/// Total order on load: `(queue+1) / (free_blocks+1)` compared by exact
/// integer cross-multiplication (no float ties), node id as tiebreak.
fn load_order(a: &NodeView, b: &NodeView) -> Ordering {
    let lhs = (a.queue_depth as u128 + 1) * (b.free_local_blocks as u128 + 1);
    let rhs = (b.queue_depth as u128 + 1) * (a.free_local_blocks as u128 + 1);
    lhs.cmp(&rhs)
        .then_with(|| b.free_hbm_bytes.cmp(&a.free_hbm_bytes))
        .then_with(|| a.node.cmp(&b.node))
}

/// The router. Holds only policy state (the round-robin cursor); every
/// decision is a pure function of the views otherwise.
#[derive(Debug)]
pub struct Router {
    policy: RouterPolicy,
    /// Holder queue depth at which affinity routing spills elsewhere.
    spill_queue_depth: usize,
    /// Per-node queue depth at which a node stops accepting; all nodes
    /// there ⇒ shed.
    shed_queue_depth: usize,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RouterPolicy, spill_queue_depth: usize, shed_queue_depth: usize) -> Self {
        Self { policy, spill_queue_depth: spill_queue_depth.max(1), shed_queue_depth, rr_next: 0 }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    fn least_loaded(&self, views: &[NodeView]) -> Option<usize> {
        views
            .iter()
            .filter(|v| v.queue_depth < self.shed_queue_depth)
            .min_by(|a, b| load_order(a, b))
            .map(|v| v.node)
    }

    /// Route one arriving request against the current node views (one
    /// [`NodeView`] per node, in node-id order).
    pub fn route(&mut self, req: &Request, views: &[NodeView]) -> RouteDecision {
        assert!(!views.is_empty(), "routing against an empty cluster");
        if views.iter().all(|v| v.queue_depth >= self.shed_queue_depth) {
            return RouteDecision::Shed;
        }
        match self.policy {
            RouterPolicy::RoundRobin => {
                for _ in 0..views.len() {
                    let v = &views[self.rr_next % views.len()];
                    self.rr_next = (self.rr_next + 1) % views.len();
                    if v.queue_depth < self.shed_queue_depth {
                        return RouteDecision::Assign { node: v.node, migrate_prefix_from: None };
                    }
                }
                RouteDecision::Shed
            }
            RouterPolicy::LeastLoaded => match self.least_loaded(views) {
                Some(node) => RouteDecision::Assign { node, migrate_prefix_from: None },
                None => RouteDecision::Shed,
            },
            RouterPolicy::PrefixAffinity => {
                let holder = req.prefix_group.and_then(|_| {
                    views
                        .iter()
                        .filter(|v| v.has_prefix && v.queue_depth < self.shed_queue_depth)
                        .min_by(|a, b| load_order(a, b))
                });
                match holder {
                    Some(h) if h.queue_depth < self.spill_queue_depth => {
                        RouteDecision::Assign { node: h.node, migrate_prefix_from: None }
                    }
                    Some(h) => {
                        // Holder overloaded: shed load to the least-loaded
                        // node and take the session's KV with it.
                        match self.least_loaded(views) {
                            Some(node) if node != h.node => RouteDecision::Assign {
                                node,
                                migrate_prefix_from: Some(h.node),
                            },
                            Some(node) => {
                                RouteDecision::Assign { node, migrate_prefix_from: None }
                            }
                            None => RouteDecision::Shed,
                        }
                    }
                    None => match self.least_loaded(views) {
                        Some(node) => RouteDecision::Assign { node, migrate_prefix_from: None },
                        None => RouteDecision::Shed,
                    },
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::SeqId;
    use crate::server::RequestState;

    fn req(group: Option<u32>) -> Request {
        Request {
            id: SeqId(0),
            arrival: 0,
            prompt_tokens: 100,
            max_new_tokens: 8,
            shared_prefix_tokens: if group.is_some() { 64 } else { 0 },
            prefix_group: group,
            state: RequestState::Queued,
            generated: 0,
            first_token_at: None,
            finished_at: None,
        }
    }

    fn view(node: usize, queue: usize, free: usize, has_prefix: bool) -> NodeView {
        NodeView {
            node,
            queue_depth: queue,
            free_local_blocks: free,
            free_hbm_bytes: 0,
            has_prefix,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 8, usize::MAX);
        let views = vec![view(0, 0, 10, false), view(1, 0, 10, false), view(2, 0, 10, false)];
        let picks: Vec<_> = (0..6)
            .map(|_| match r.route(&req(None), &views) {
                RouteDecision::Assign { node, .. } => node,
                RouteDecision::Shed => panic!("unexpected shed"),
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_queue_against_free_blocks() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 8, usize::MAX);
        // node 1 has a shorter queue relative to its free pool
        let views = vec![view(0, 4, 10, false), view(1, 2, 10, false)];
        assert_eq!(
            r.route(&req(None), &views),
            RouteDecision::Assign { node: 1, migrate_prefix_from: None }
        );
        // same queues: more free blocks wins
        let views = vec![view(0, 3, 5, false), view(1, 3, 50, false)];
        assert_eq!(
            r.route(&req(None), &views),
            RouteDecision::Assign { node: 1, migrate_prefix_from: None }
        );
        // exact tie: lowest id (deterministic)
        let views = vec![view(0, 3, 10, false), view(1, 3, 10, false)];
        assert_eq!(
            r.route(&req(None), &views),
            RouteDecision::Assign { node: 0, migrate_prefix_from: None }
        );
    }

    #[test]
    fn affinity_prefers_holder_until_spill_threshold() {
        let mut r = Router::new(RouterPolicy::PrefixAffinity, 4, usize::MAX);
        // holder busy but under the spill threshold: stay for the prefix
        let views = vec![view(0, 3, 10, true), view(1, 0, 10, false)];
        assert_eq!(
            r.route(&req(Some(7)), &views),
            RouteDecision::Assign { node: 0, migrate_prefix_from: None }
        );
        // holder at the threshold: spill to least-loaded, migrate the KV
        let views = vec![view(0, 4, 10, true), view(1, 0, 10, false)];
        assert_eq!(
            r.route(&req(Some(7)), &views),
            RouteDecision::Assign { node: 1, migrate_prefix_from: Some(0) }
        );
        // no prefix on the request: plain least-loaded
        let views = vec![view(0, 4, 10, true), view(1, 0, 10, false)];
        assert_eq!(
            r.route(&req(None), &views),
            RouteDecision::Assign { node: 1, migrate_prefix_from: None }
        );
    }

    #[test]
    fn shed_when_every_node_saturated() {
        for policy in
            [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::PrefixAffinity]
        {
            let mut r = Router::new(policy, 4, 8);
            let views = vec![view(0, 8, 10, true), view(1, 9, 10, false)];
            assert_eq!(r.route(&req(Some(1)), &views), RouteDecision::Shed, "{policy:?}");
            // one node below the bound: served again
            let views = vec![view(0, 8, 10, true), view(1, 7, 10, false)];
            assert!(matches!(r.route(&req(Some(1)), &views), RouteDecision::Assign { .. }));
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in
            [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::PrefixAffinity]
        {
            assert_eq!(RouterPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RouterPolicy::parse("random").is_err());
    }
}
