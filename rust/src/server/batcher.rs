//! Continuous batcher: admission control for the decode loop.
//!
//! Requests wait in an arrival-ordered queue; whenever a decode slot and
//! enough KV budget are free, the oldest eligible request is admitted
//! (vLLM-style continuous batching — no static batch boundaries).

use super::request::{Request, RequestState};
use crate::kv::SeqId;
use crate::memsim::Ns;

/// Admission controller.
#[derive(Debug, Default)]
pub struct ContinuousBatcher {
    pending: Vec<Request>, // arrival-sorted, front = next
    running: Vec<SeqId>,
    max_running: usize,
}

impl ContinuousBatcher {
    pub fn new(max_running: usize, mut requests: Vec<Request>) -> Self {
        requests.sort_by_key(|r| r.arrival);
        Self { pending: requests, running: Vec::new(), max_running }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn all_done(&self) -> bool {
        self.pending.is_empty() && self.running.is_empty()
    }

    /// Earliest pending arrival (to advance idle virtual time to).
    pub fn next_arrival(&self) -> Option<Ns> {
        self.pending.first().map(|r| r.arrival)
    }

    /// Admit arrived requests while slots remain and `fits` approves
    /// (e.g. KV block budget). Returns the admitted requests.
    pub fn admit<F: FnMut(&Request) -> bool>(&mut self, now: Ns, mut fits: F) -> Vec<Request> {
        let mut admitted = Vec::new();
        while self.running.len() < self.max_running {
            let Some(front) = self.pending.first() else { break };
            if front.arrival > now || !fits(front) {
                break;
            }
            let mut r = self.pending.remove(0);
            r.state = RequestState::Running;
            self.running.push(r.id);
            admitted.push(r);
        }
        admitted
    }

    /// A request completed; frees its slot.
    pub fn finish(&mut self, id: SeqId) {
        self.running.retain(|&s| s != id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::request::{WorkloadGen, WorkloadSpec};

    fn reqs(n: usize, gap: Ns) -> Vec<Request> {
        WorkloadGen::new(WorkloadSpec {
            n_requests: n,
            mean_interarrival_ns: gap,
            ..Default::default()
        })
        .generate()
    }

    #[test]
    fn admits_up_to_capacity() {
        let mut b = ContinuousBatcher::new(3, reqs(10, 0));
        let admitted = b.admit(0, |_| true);
        assert_eq!(admitted.len(), 3);
        assert_eq!(b.running(), 3);
        assert_eq!(b.pending(), 7);
        // no double admission
        assert!(b.admit(0, |_| true).is_empty());
    }

    #[test]
    fn respects_arrival_times() {
        let mut b = ContinuousBatcher::new(8, reqs(5, 1_000_000_000));
        let at0 = b.admit(0, |_| true);
        assert!(at0.len() < 5, "not everyone has arrived at t=0");
        let later = b.admit(u64::MAX / 2, |_| true);
        assert_eq!(at0.len() + later.len(), 5);
    }

    #[test]
    fn fits_predicate_gates_admission() {
        let mut b = ContinuousBatcher::new(8, reqs(4, 0));
        let admitted = b.admit(0, |r| r.prompt_tokens < 10);
        // lognormal(180) prompts: essentially never < 10 -> head blocks
        assert!(admitted.is_empty());
        assert_eq!(b.pending(), 4);
    }

    #[test]
    fn finish_frees_slot_for_next() {
        let mut b = ContinuousBatcher::new(1, reqs(2, 0));
        let first = b.admit(0, |_| true);
        assert_eq!(first.len(), 1);
        b.finish(first[0].id);
        let second = b.admit(0, |_| true);
        assert_eq!(second.len(), 1);
        assert_ne!(second[0].id, first[0].id);
        b.finish(second[0].id);
        assert!(b.all_done());
    }
}
