//! The end-to-end engine: real PJRT compute, continuous batching, paged
//! KV — proving the three layers compose (L1 Pallas kernels inside the
//! L2 decode graph, executed from the L3 coordinator with Python never on
//! the request path).
//!
//! The tiny AOT model's KV page pool lives inside the HLO state
//! (`runtime::ModelRuntime`); this engine owns the *physical page
//! allocator* over that pool and per-sequence page tables, runs
//! continuous batching over real requests, samples greedily from real
//! logits, and reports wall-clock latency/throughput — the serving-paper
//! analogue of "load a small real model and serve batched requests".

use super::batcher::ContinuousBatcher;
use super::metrics::ServeMetrics;
use super::request::Request;
use crate::kv::SeqId;
use crate::runtime::{DecodeSlot, ModelRuntime};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Physical page allocator over the model's KV pool. The last page is
/// reserved as the padding scratch page (see `runtime::ModelRuntime`).
#[derive(Debug)]
struct PagePool {
    free: Vec<i32>,
}

impl PagePool {
    /// `num_pages` must be >= 2: the last page is reserved as the padding
    /// scratch page, and a pool with no allocatable pages can never admit
    /// a request (callers would spin forever). Checked by
    /// [`RealEngine::new`].
    fn new(num_pages: usize) -> Self {
        debug_assert!(num_pages >= 2, "pool needs a padding page plus allocatable pages");
        // reserve the last page for padding slots
        Self { free: (0..num_pages as i32 - 1).rev().collect() }
    }

    fn available(&self) -> usize {
        self.free.len()
    }

    fn alloc(&mut self) -> Option<i32> {
        self.free.pop()
    }

    fn release(&mut self, pages: impl IntoIterator<Item = i32>) {
        self.free.extend(pages);
    }
}

struct LiveSeq {
    req: Request,
    /// Token ids: prompt then generated.
    tokens: Vec<i32>,
    /// Next position to feed (== tokens consumed so far).
    cursor: usize,
    pages: Vec<i32>,
    started: Instant,
}

impl LiveSeq {
    fn in_prefill(&self) -> bool {
        self.cursor < self.req.prompt_tokens as usize
    }
}

/// Per-run expert-usage accounting (drives MoE analyses with *real*
/// routing decisions from the gating network).
#[derive(Debug, Clone, Default)]
pub struct ExpertUsage {
    /// [layer][expert] activation counts.
    pub counts: Vec<Vec<u64>>,
}

impl ExpertUsage {
    fn record(&mut self, routed: &[Vec<Vec<i32>>]) {
        if self.counts.len() < routed.len() {
            self.counts.resize(routed.len(), Vec::new());
        }
        for (l, slots) in routed.iter().enumerate() {
            for ks in slots {
                for &e in ks {
                    let row = &mut self.counts[l];
                    if row.len() <= e as usize {
                        row.resize(e as usize + 1, 0);
                    }
                    row[e as usize] += 1;
                }
            }
        }
    }

    /// Layer-summed activation distribution.
    pub fn totals(&self) -> Vec<u64> {
        let width = self.counts.iter().map(|r| r.len()).max().unwrap_or(0);
        let mut out = vec![0u64; width];
        for row in &self.counts {
            for (e, &c) in row.iter().enumerate() {
                out[e] += c;
            }
        }
        out
    }
}

/// Wall-clock report of a real serving run.
#[derive(Debug)]
pub struct RealEngineReport {
    pub metrics: ServeMetrics,
    pub expert_usage: ExpertUsage,
    pub decode_steps: u64,
    /// Steps decoded while at least one request sat in the admission
    /// queue (blocked on free KV pages or batch slots) — the real
    /// engine's capacity analogue of the sim engine's
    /// `decode_stall_ns` bandwidth stall.
    pub admission_blocked_steps: u64,
    pub wall_seconds: f64,
    /// Generated token ids per request (for determinism checks).
    pub outputs: BTreeMap<u64, Vec<i32>>,
}

/// The engine.
pub struct RealEngine {
    rt: ModelRuntime,
    pool: PagePool,
    max_batch: usize,
}

impl RealEngine {
    pub fn new(rt: ModelRuntime) -> Result<Self> {
        let cfg = rt.config().clone();
        if cfg.num_pages < 2 {
            bail!(
                "model KV pool has {} page(s); need >= 2 (one padding page + \
                 at least one allocatable page)",
                cfg.num_pages
            );
        }
        let max_batch = rt.batch_variants().last().copied().unwrap_or(1);
        Ok(Self { rt, pool: PagePool::new(cfg.num_pages), max_batch })
    }

    pub fn model_runtime(&self) -> &ModelRuntime {
        &self.rt
    }

    fn pages_needed(&self, tokens: u32) -> usize {
        (tokens as usize).div_ceil(self.rt.config().page_size)
    }

    /// Serve `requests` to completion with continuous batching; prompts
    /// are synthesised deterministically from the request id.
    pub fn serve(&mut self, requests: Vec<Request>) -> Result<RealEngineReport> {
        let cfg = self.rt.config().clone();
        let max_ctx = cfg.max_context() as u32;
        for r in &requests {
            if r.prompt_tokens + r.max_new_tokens > max_ctx {
                bail!(
                    "request {:?} needs {} tokens > max context {max_ctx}",
                    r.id,
                    r.prompt_tokens + r.max_new_tokens
                );
            }
        }
        let wall_start = Instant::now();
        let mut metrics = ServeMetrics::new();
        metrics.on_start(0);
        let mut usage = ExpertUsage::default();
        let mut outputs = BTreeMap::new();
        let mut batcher = ContinuousBatcher::new(self.max_batch, requests);
        let mut live: BTreeMap<SeqId, LiveSeq> = BTreeMap::new();
        let mut steps = 0u64;
        let mut blocked_steps = 0u64;

        while !batcher.all_done() {
            // Admission: virtual arrivals are ignored on the real engine
            // (closed-loop); admit while pages + slots are free.
            let pool = &mut self.pool;
            let needed = |r: &Request| -> usize {
                (r.prompt_tokens + r.max_new_tokens).div_ceil(cfg.page_size as u32) as usize
            };
            let admitted = batcher.admit(u64::MAX, |r| needed(r) <= pool.available());
            for req in admitted {
                let total_pages = self.pages_needed(req.prompt_tokens + req.max_new_tokens);
                let pages: Vec<i32> =
                    (0..total_pages).map(|_| self.pool.alloc().expect("fits")).collect();
                let mut rng = Rng::new(0xBEEF ^ req.id.0);
                let tokens: Vec<i32> = (0..req.prompt_tokens)
                    .map(|_| rng.below(cfg.vocab as u64) as i32)
                    .collect();
                live.insert(
                    req.id,
                    LiveSeq { req, tokens, cursor: 0, pages, started: Instant::now() },
                );
            }
            if live.is_empty() {
                break;
            }
            if batcher.pending() > 0 {
                // This step decodes while someone queues for capacity.
                blocked_steps += 1;
            }
            // One step: every live sequence feeds its next token.
            let ids: Vec<SeqId> = live.keys().copied().collect();
            let mut slots = Vec::with_capacity(ids.len());
            for &id in &ids {
                let s = &live[&id];
                let mut pt = vec![0i32; cfg.max_pages_per_seq];
                for (i, &p) in s.pages.iter().enumerate() {
                    pt[i] = p;
                }
                // pad unused entries with the first page (harmless: they
                // are beyond seq_len and masked)
                for slot in pt.iter_mut().skip(s.pages.len()) {
                    *slot = s.pages[0];
                }
                slots.push(DecodeSlot {
                    token: s.tokens[s.cursor],
                    pos: s.cursor as i32,
                    page_table: pt,
                });
            }
            let step_t0 = Instant::now();
            let out = self.rt.decode(&slots)?;
            let step_ns = step_t0.elapsed().as_nanos() as u64;
            steps += 1;
            usage.record(&out.routed);

            for (i, &id) in ids.iter().enumerate() {
                let s = live.get_mut(&id).expect("live");
                s.cursor += 1;
                let prefill_done = !s.in_prefill();
                if prefill_done {
                    if s.cursor == s.req.prompt_tokens as usize {
                        metrics.on_first_token(0, s.started.elapsed().as_nanos() as u64);
                    }
                    if s.cursor >= s.tokens.len() {
                        // Sample greedily from the real logits with a
                        // total-order fold: NaNs never win (`>` is false),
                        // ties break to the lowest token id, and an
                        // all-NaN row deterministically yields token 0 —
                        // `partial_cmp(..).unwrap()` here used to panic
                        // the whole serve loop on a single NaN logit.
                        let logits = &out.logits[i];
                        let next = logits
                            .iter()
                            .enumerate()
                            .fold((0usize, f32::NEG_INFINITY), |best, (t, &v)| {
                                if v > best.1 { (t, v) } else { best }
                            })
                            .0 as i32;
                        s.tokens.push(next);
                        s.req.generated += 1;
                        metrics.on_token(step_ns / ids.len() as u64);
                    }
                }
                if s.req.generated >= s.req.max_new_tokens {
                    metrics.on_finish(0, s.started.elapsed().as_nanos() as u64, s.req.generated as u64);
                    let s = live.remove(&id).expect("live");
                    outputs.insert(
                        id.0,
                        s.tokens[s.req.prompt_tokens as usize..].to_vec(),
                    );
                    self.pool.release(s.pages);
                    batcher.finish(id);
                }
            }
        }
        Ok(RealEngineReport {
            metrics,
            expert_usage: usage,
            decode_steps: steps,
            admission_blocked_steps: blocked_steps,
            wall_seconds: wall_start.elapsed().as_secs_f64(),
            outputs,
        })
    }
}
