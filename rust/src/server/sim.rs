//! Virtual-time serving engine over the KV offload manager — the §6.3
//! fair-decoding study substrate.
//!
//! Decode slots are limited; the scheduler decides which sequences decode
//! each step. A sequence selected after sitting out has had its KV blocks
//! evicted by the interim working set, so re-scheduling it triggers
//! reloads ("preemption-induced reloads"). With Harvest, those reloads
//! come from peer HBM over NVLink; without, from host DRAM over PCIe —
//! the difference is the paper's "scheduler robustness" effect: finer-
//! grained fairness without the full throughput penalty of paging.

use super::batcher::ContinuousBatcher;
use super::metrics::ServeMetrics;
use super::request::Request;
use super::scheduler::Scheduler;
use crate::harvest::prefetch::PrefetchConfig;
use crate::harvest::HarvestRuntime;
use crate::kv::{KvConfig, KvOffloadManager, SeqId};
use crate::memsim::Ns;
use crate::tenantsim::{FleetStats, TenantFleet};
use std::collections::BTreeMap;

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimEngineConfig {
    pub kv: KvConfig,
    /// Sequences decoding per step (GPU batch capacity).
    pub decode_slots: usize,
    /// Max concurrently admitted requests.
    pub max_running: usize,
    /// Compute time of one batched decode step.
    pub step_compute_ns: Ns,
    /// Prefill compute time per prompt token.
    pub prefill_ns_per_token: Ns,
    /// Deadline-aware prefetch: overlap predicted reloads with each
    /// step's compute (None = demand fetching only, the pre-prefetch
    /// behavior).
    pub prefetch: Option<PrefetchConfig>,
}

impl SimEngineConfig {
    /// Defaults derived from the KV model's size.
    pub fn new(kv: KvConfig, decode_slots: usize, max_running: usize) -> Self {
        // decode step ≈ 2*active_params / eff_flops per token, batched.
        let per_tok = 2.0 * kv.model.active_params_b * 1e9 / 400e12 * 1e9;
        Self {
            kv,
            decode_slots,
            max_running,
            step_compute_ns: per_tok as Ns,
            prefill_ns_per_token: (per_tok / 4.0) as Ns,
            prefetch: None,
        }
    }

    /// Enable the prefetch pipeline.
    pub fn with_prefetch(mut self, cfg: PrefetchConfig) -> Self {
        self.prefetch = Some(cfg);
        self
    }
}

/// Run report. The prefetch outcome ledger lives in
/// [`ServeMetrics::prefetch`] (None when prefetch was disabled);
/// `tenant` carries the co-tenant fleet's counters when one ran.
#[derive(Debug, Clone)]
pub struct SimEngineReport {
    pub metrics: ServeMetrics,
    pub kv_stats: crate::kv::KvStats,
    pub scheduler: &'static str,
    pub use_harvest: bool,
    pub tenant: Option<FleetStats>,
}

/// The engine.
pub struct SimEngine {
    cfg: SimEngineConfig,
    kv: KvOffloadManager,
    scheduler: Box<dyn Scheduler>,
    /// Closed-loop co-tenants stepped on every time advance (None =
    /// exogenous-timeline mode, the pre-fleet behavior).
    tenants: Option<TenantFleet>,
}

impl SimEngine {
    pub fn new(cfg: SimEngineConfig, scheduler: Box<dyn Scheduler>, compute_gpu: usize) -> Self {
        let mut kv = KvOffloadManager::new(cfg.kv, compute_gpu);
        if let Some(p) = cfg.prefetch {
            kv = kv.with_prefetch(p);
        }
        Self { cfg, kv, scheduler, tenants: None }
    }

    pub fn with_kv(
        cfg: SimEngineConfig,
        scheduler: Box<dyn Scheduler>,
        kv: KvOffloadManager,
    ) -> Self {
        Self { cfg, kv, scheduler, tenants: None }
    }

    /// Attach a co-tenant fleet: every virtual-time advance in the run
    /// loop routes through [`TenantFleet::advance_to`], so tenant
    /// allocation churn and collective traffic land exactly where the
    /// serve path's own DMA does.
    pub fn with_tenants(mut self, fleet: TenantFleet) -> Self {
        self.tenants = Some(fleet);
        self
    }

    /// Advance virtual time, through the fleet when one is attached.
    fn advance(&mut self, hr: &mut HarvestRuntime, t: Ns) {
        match &mut self.tenants {
            Some(f) => f.advance_to(hr, t),
            None => {
                hr.advance_to(t);
            }
        }
    }

    /// Serve `requests` to completion in virtual time.
    pub fn run(&mut self, hr: &mut HarvestRuntime, requests: Vec<Request>) -> SimEngineReport {
        let scheduler_name = self.scheduler.name();
        let mut metrics = ServeMetrics::new();
        metrics.on_start(hr.node.clock.now());
        // Co-tenants exist from t=0 (persistent footprints, replay
        // timelines), not from the first time advance.
        if let Some(f) = self.tenants.as_mut() {
            f.install(hr);
        }
        let mut batcher = ContinuousBatcher::new(self.cfg.max_running, requests);
        let mut live: BTreeMap<SeqId, Request> = BTreeMap::new();

        while !batcher.all_done() {
            // Idle: jump to the next arrival.
            if self.scheduler.runnable() == 0 {
                if let Some(at) = batcher.next_arrival() {
                    let target = at.max(hr.node.clock.now());
                    self.advance(hr, target);
                }
            }
            // Admission + prefill.
            let now = hr.node.clock.now();
            for mut req in batcher.admit(now, |_| true) {
                let prefill_ns = self.cfg.prefill_ns_per_token * req.prompt_tokens as u64;
                let target = hr.node.clock.now() + prefill_ns;
                self.advance(hr, target);
                // Vectored admission: free the prompt's block footprint in
                // one all-or-nothing batch instead of evicting per token.
                let blocks = (req.prompt_tokens as usize).div_ceil(self.cfg.kv.block_tokens as usize);
                self.kv.reserve_local(hr, blocks);
                for _ in 0..req.prompt_tokens {
                    self.kv.append_token(hr, req.id);
                }
                req.first_token_at = Some(hr.node.clock.now());
                metrics.on_first_token(req.arrival, hr.node.clock.now());
                self.scheduler.admit(req.id);
                live.insert(req.id, req);
            }
            // One decode step for the scheduled cohort.
            let cohort = self.scheduler.select(self.cfg.decode_slots);
            if cohort.is_empty() {
                continue;
            }
            let step_start = hr.node.clock.now();
            // Tick boundary: drain revocations accumulated while time
            // advanced, then restore KV residency for the cohort (this
            // is where preemption churn costs).
            self.kv.sync(hr);
            for &seq in &cohort {
                self.kv.access_seq(hr, seq);
            }
            // Everything between step_start and here was waiting on KV
            // residency, not computing.
            metrics.on_stall(hr.node.clock.now() - step_start);
            // Overlap: while this step's compute runs, issue background
            // reloads for the sequences the scheduler predicts will
            // decode next. The deadline is the start of the next step —
            // the planner guarantees prefetch DMA is off every link
            // again by the time demand fetches can reappear. Predicted
            // blocks stuck on the host/CXL tiers (pressure demotions,
            // host spills) that the reload pass left behind are promoted
            // toward peer HBM in the same window, so their eventual
            // reload rides NVLink instead of PCIe.
            if let Some(pcfg) = self.cfg.prefetch {
                let predicted =
                    self.scheduler.lookahead(self.cfg.decode_slots, pcfg.horizon);
                let deadline = hr.node.clock.now() + self.cfg.step_compute_ns;
                self.kv.prefetch_seqs(hr, &predicted, deadline);
                self.kv.promote_blocks(hr, &predicted, deadline);
            }
            // Batched compute.
            let compute_end = hr.node.clock.now() + self.cfg.step_compute_ns;
            self.advance(hr, compute_end);
            let step_ns = hr.node.clock.now() - step_start;
            for &seq in &cohort {
                self.kv.append_token(hr, seq);
                let req = live.get_mut(&seq).expect("scheduled request is live");
                req.generated += 1;
                metrics.on_token(step_ns);
                if req.done() {
                    req.finished_at = Some(hr.node.clock.now());
                    metrics.on_finish(req.arrival, hr.node.clock.now());
                    self.scheduler.retire(seq);
                    batcher.finish(seq);
                    self.kv.finish_seq(hr, seq);
                    live.remove(&seq);
                }
            }
        }
        metrics.prefetch = self.kv.prefetch_stats().cloned();
        SimEngineReport {
            metrics,
            kv_stats: self.kv.stats.clone(),
            scheduler: scheduler_name,
            use_harvest: self.cfg.kv.use_harvest,
            tenant: self.tenants.as_ref().map(|f| f.stats()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::HarvestConfig;
    use crate::memsim::{NodeSpec, SimNode};
    use crate::moe::config::find_kv_model;
    use crate::server::request::{WorkloadGen, WorkloadSpec};
    use crate::server::scheduler::{CompletelyFair, Fcfs};

    fn kv_cfg(use_harvest: bool, cap_blocks: usize) -> KvConfig {
        KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap_blocks,
            use_harvest,
            host_backed_peer: false,
        }
    }

    fn workload(n: usize) -> Vec<Request> {
        WorkloadGen::new(WorkloadSpec {
            n_requests: n,
            mean_prompt_tokens: 64.0,
            max_new_tokens: 8,
            ..Default::default()
        })
        .generate()
    }

    fn run(
        use_harvest: bool,
        cap: usize,
        sched: Box<dyn Scheduler>,
        n: usize,
    ) -> SimEngineReport {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let cfg = SimEngineConfig::new(kv_cfg(use_harvest, cap), 8, 16);
        let mut eng = SimEngine::new(cfg, sched, 0);
        eng.run(&mut hr, workload(n))
    }

    #[test]
    fn completes_all_requests() {
        let r = run(true, 10_000, Box::new(Fcfs::new()), 12);
        assert_eq!(r.metrics.requests_finished, 12);
        assert_eq!(r.metrics.tokens_generated, 12 * 8);
        assert!(r.metrics.tokens_per_sec() > 0.0);
    }

    #[test]
    fn ample_memory_means_no_reloads() {
        let r = run(true, 10_000, Box::new(Fcfs::new()), 8);
        assert_eq!(r.kv_stats.reloads(), 0);
    }

    #[test]
    fn tight_memory_with_fair_scheduler_causes_churn() {
        // 16 running seqs of ~64+8 tokens (~5 blocks each) vs 24-block
        // pool: cohort rotation evicts and reloads constantly.
        let fair = run(true, 24, Box::new(CompletelyFair::new(1)), 16);
        assert!(fair.kv_stats.reloads() > 0, "CF under pressure must churn");
    }

    #[test]
    fn harvest_speeds_up_fair_decoding() {
        let with = run(true, 24, Box::new(CompletelyFair::new(1)), 16);
        let without = run(false, 24, Box::new(CompletelyFair::new(1)), 16);
        assert!(with.kv_stats.reloads() > 0 && without.kv_stats.reloads() > 0);
        assert!(
            with.metrics.tokens_per_sec() > without.metrics.tokens_per_sec(),
            "harvest {:.0} tps <= host {:.0} tps",
            with.metrics.tokens_per_sec(),
            without.metrics.tokens_per_sec()
        );
    }

    #[test]
    fn fcfs_churns_less_than_cf() {
        let fcfs = run(true, 24, Box::new(Fcfs::new()), 16);
        let cf = run(true, 24, Box::new(CompletelyFair::new(1)), 16);
        assert!(
            cf.kv_stats.reloads() > fcfs.kv_stats.reloads(),
            "token-level preemption amplifies KV churn (cf {} vs fcfs {})",
            cf.kv_stats.reloads(),
            fcfs.kv_stats.reloads()
        );
    }

    fn run_prefetch(
        cap: usize,
        slots: usize,
        n: usize,
        prefetch: bool,
    ) -> SimEngineReport {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let mut cfg = SimEngineConfig::new(kv_cfg(true, cap), slots, 16);
        if prefetch {
            cfg = cfg.with_prefetch(crate::harvest::prefetch::PrefetchConfig::default());
        }
        let mut eng = SimEngine::new(cfg, Box::new(CompletelyFair::new(1)), 0);
        eng.run(&mut hr, workload(n))
    }

    #[test]
    fn prefetch_reduces_decode_stall_under_cf_churn() {
        // 16 requests of ~5 blocks rotating through 8 slots against a
        // 60-block pool: every rotation reloads the incoming cohort.
        // With prefetch those reloads ride the compute window instead.
        let off = run_prefetch(60, 8, 16, false);
        let on = run_prefetch(60, 8, 16, true);
        assert!(off.metrics.decode_stall_ns > 0, "baseline must stall under churn");
        assert!(
            on.metrics.decode_stall_ns < off.metrics.decode_stall_ns,
            "prefetch on: stall {} >= off {}",
            on.metrics.decode_stall_ns,
            off.metrics.decode_stall_ns
        );
        let pf = on.metrics.prefetch.as_ref().expect("prefetch ledger present");
        assert!(pf.issued > 0 && pf.hits > 0, "{pf:?}");
        assert!(off.metrics.prefetch.is_none());
        // both complete everything; overlap must not cost throughput
        assert_eq!(on.metrics.requests_finished, 16);
        assert_eq!(off.metrics.requests_finished, 16);
        assert!(
            on.metrics.tokens_per_sec() >= off.metrics.tokens_per_sec() * 0.95,
            "prefetch must not cost throughput: on {:.0} vs off {:.0}",
            on.metrics.tokens_per_sec(),
            off.metrics.tokens_per_sec()
        );
    }

    #[test]
    fn prefetch_is_inert_with_ample_memory() {
        // Nothing is ever non-local, so the planner has nothing to do
        // and results match the non-prefetch run exactly.
        let off = run_prefetch(10_000, 8, 8, false);
        let on = run_prefetch(10_000, 8, 8, true);
        assert_eq!(on.kv_stats.reloads(), 0);
        assert_eq!(on.metrics.prefetch.as_ref().unwrap().issued, 0);
        assert_eq!(on.metrics.decode_stall_ns, off.metrics.decode_stall_ns);
        assert_eq!(on.metrics.tokens_generated, off.metrics.tokens_generated);
        assert_eq!(on.metrics.makespan_ns(), off.metrics.makespan_ns());
    }

    #[test]
    fn demotion_under_pressure_serves_all_requests_without_recompute() {
        // End-to-end RevocationAction::Demoted: tenant pressure
        // oscillates while the engine decodes; with demote_to_host the
        // controller migrates lossy peer blocks to host-tier leases
        // instead of dropping them, so the run never pays recompute and
        // still finishes everything.
        let run = |demote: bool| {
            let mut hcfg = HarvestConfig::for_node(2);
            hcfg.demote_to_host = demote;
            let mut hr =
                HarvestRuntime::new(SimNode::new(crate::memsim::NodeSpec::h100x2()), hcfg);
            const GIB: u64 = 1 << 30;
            let steps: Vec<(u64, u64)> = (0..40)
                .map(|i| (i * 5_000_000, if i % 2 == 1 { 80 * GIB } else { 0 }))
                .collect();
            hr.node.set_tenant_load(
                1,
                crate::memsim::TenantLoad::from_steps(80 * GIB, steps),
            );
            let cfg = SimEngineConfig::new(kv_cfg(true, 32), 4, 16);
            let mut eng = SimEngine::new(cfg, Box::new(CompletelyFair::new(1)), 0);
            let report = eng.run(&mut hr, workload(12));
            (report, hr.demotions)
        };
        let (dropped, demoted_ct) = run(false);
        assert_eq!(dropped.metrics.requests_finished, 12);
        assert_eq!(demoted_ct, 0);
        assert!(
            dropped.kv_stats.recomputes > 0,
            "baseline must lose lossy blocks under this pressure"
        );
        let (demoted, demoted_ct) = run(true);
        assert_eq!(demoted.metrics.requests_finished, 12);
        assert!(demoted_ct > 0, "pressure must exercise the demotion path");
        assert!(demoted.kv_stats.demotions > 0, "demotion events observed by the manager");
        assert_eq!(demoted.kv_stats.recomputes, 0, "demoted blocks are never lost");
        assert!(
            demoted.kv_stats.host_reloads > 0,
            "demoted blocks reload from their host-tier lease"
        );
    }

    #[test]
    fn promotion_prefetch_pulls_demoted_blocks_back_to_peer() {
        // With prefetch on, blocks the scheduler predicts for later
        // steps that sit on the host tier are background-migrated to
        // peer HBM — the promotion half of the demote/promote cycle.
        let mut hr =
            HarvestRuntime::new(SimNode::new(crate::memsim::NodeSpec::h100x2()), {
                let mut c = HarvestConfig::for_node(2);
                c.demote_to_host = true;
                c
            });
        const GIB: u64 = 1 << 30;
        // one early pressure spike demotes, then the peer frees up
        let steps = vec![(0u64, 0u64), (5_000_000, 80 * GIB), (10_000_000, 0)];
        hr.node.set_tenant_load(1, crate::memsim::TenantLoad::from_steps(80 * GIB, steps));
        let cfg = SimEngineConfig::new(kv_cfg(true, 32), 4, 16)
            .with_prefetch(crate::harvest::prefetch::PrefetchConfig::default());
        let mut eng = SimEngine::new(cfg, Box::new(CompletelyFair::new(1)), 0);
        let report = eng.run(&mut hr, workload(12));
        assert_eq!(report.metrics.requests_finished, 12);
        if report.kv_stats.demotions > 0 {
            assert!(
                report.kv_stats.promotions > 0,
                "demoted blocks should be promoted back: {:?}",
                report.kv_stats
            );
        }
    }

    #[test]
    fn staggered_arrivals_are_served() {
        let reqs = WorkloadGen::new(WorkloadSpec {
            n_requests: 6,
            mean_prompt_tokens: 32.0,
            max_new_tokens: 4,
            mean_interarrival_ns: 50_000_000,
            ..Default::default()
        })
        .generate();
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let cfg = SimEngineConfig::new(kv_cfg(true, 1_000), 4, 8);
        let mut eng = SimEngine::new(cfg, Box::new(Fcfs::new()), 0);
        let r = eng.run(&mut hr, reqs);
        assert_eq!(r.metrics.requests_finished, 6);
        assert!(r.metrics.ttft.count() == 6);
    }
}
