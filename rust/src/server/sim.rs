//! Virtual-time serving engine over the KV offload manager — the §6.3
//! fair-decoding study substrate.
//!
//! Decode slots are limited; the scheduler decides which sequences decode
//! each step. A sequence selected after sitting out has had its KV blocks
//! evicted by the interim working set, so re-scheduling it triggers
//! reloads ("preemption-induced reloads"). With Harvest, those reloads
//! come from peer HBM over NVLink; without, from host DRAM over PCIe —
//! the difference is the paper's "scheduler robustness" effect: finer-
//! grained fairness without the full throughput penalty of paging.
//!
//! The loop body itself lives in [`super::stepper::NodeStepper`] —
//! `SimEngine::run` just drives a stepper over a closed request list.
//! The cluster drives the same stepper incrementally, which is what
//! keeps single-node and cluster results diverge-proof.

use super::metrics::ServeMetrics;
use super::request::Request;
use super::scheduler::Scheduler;
use super::stepper::{AgingConfig, NodeStepper, RequestOutcome};
use crate::control::AdmissionConfig;
use crate::harvest::prefetch::PrefetchConfig;
use crate::harvest::HarvestRuntime;
use crate::kv::{KvConfig, KvOffloadManager};
use crate::memsim::Ns;
use crate::tenantsim::{FleetStats, TenantFleet};

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimEngineConfig {
    pub kv: KvConfig,
    /// Sequences decoding per step (GPU batch capacity).
    pub decode_slots: usize,
    /// Max concurrently admitted requests.
    pub max_running: usize,
    /// Compute time of one batched decode step.
    pub step_compute_ns: Ns,
    /// Prefill compute time per prompt token.
    pub prefill_ns_per_token: Ns,
    /// Deadline-aware prefetch: overlap predicted reloads with each
    /// step's compute (None = demand fetching only, the pre-prefetch
    /// behavior).
    pub prefetch: Option<PrefetchConfig>,
    /// Periodic idle-aging sweep over the cold-tier ladder (None = no
    /// background aging, the pre-ladder behavior). The stepper runs the
    /// sweep, so single-node and cluster runs share the cadence by
    /// construction.
    pub aging: Option<AgingConfig>,
    /// SLO feedback admission control (None = admit everything that
    /// fits, the legacy behavior). The stepper owns the controller, so
    /// single-node and cluster runs make identical decisions.
    pub admission: Option<AdmissionConfig>,
    /// Per-request causal latency attribution (see
    /// [`crate::obs::attrib`]). Observation-only: an armed run is
    /// bit-for-bit identical to an off run
    /// (`tests/obs_differential.rs`).
    pub attribution: bool,
}

impl SimEngineConfig {
    /// Defaults derived from the KV model's size.
    pub fn new(kv: KvConfig, decode_slots: usize, max_running: usize) -> Self {
        // decode step ≈ 2*active_params / eff_flops per token, batched.
        let per_tok = 2.0 * kv.model.active_params_b * 1e9 / 400e12 * 1e9;
        Self {
            kv,
            decode_slots,
            max_running,
            step_compute_ns: per_tok as Ns,
            prefill_ns_per_token: (per_tok / 4.0) as Ns,
            prefetch: None,
            aging: None,
            admission: None,
            attribution: false,
        }
    }

    /// Enable the prefetch pipeline.
    pub fn with_prefetch(mut self, cfg: PrefetchConfig) -> Self {
        self.prefetch = Some(cfg);
        self
    }

    /// Enable the background idle-aging sweep.
    pub fn with_aging(mut self, cfg: AgingConfig) -> Self {
        self.aging = Some(cfg);
        self
    }

    /// Enable SLO feedback admission control.
    pub fn with_admission(mut self, cfg: AdmissionConfig) -> Self {
        self.admission = Some(cfg);
        self
    }

    /// Enable per-request causal latency attribution.
    pub fn with_attribution(mut self) -> Self {
        self.attribution = true;
        self
    }
}

/// Run report. The prefetch outcome ledger lives in
/// [`ServeMetrics::prefetch`] (None when prefetch was disabled);
/// `tenant` carries the co-tenant fleet's counters when one ran.
#[derive(Debug, Clone)]
pub struct SimEngineReport {
    pub metrics: ServeMetrics,
    pub kv_stats: crate::kv::KvStats,
    pub scheduler: &'static str,
    pub use_harvest: bool,
    pub tenant: Option<FleetStats>,
    /// Per-request completion records in finish order — what the
    /// differential tests compare against a 1-node cluster run.
    pub completions: Vec<RequestOutcome>,
    /// Engine iterations the run took.
    pub steps: u64,
    /// Requests the admission controller shed, in decision order
    /// (empty without a controller).
    pub sheds: Vec<crate::kv::SeqId>,
    /// Admission-controller counters (None without a controller).
    pub admission: Option<crate::control::AdmissionStats>,
    /// Per-request latency attribution ledgers (None unless the config
    /// armed [`SimEngineConfig::with_attribution`]).
    pub attribution: Option<crate::obs::AttributionReport>,
}

/// The engine: a closed-loop driver over one [`NodeStepper`].
pub struct SimEngine {
    stepper: NodeStepper,
}

impl SimEngine {
    pub fn new(cfg: SimEngineConfig, scheduler: Box<dyn Scheduler>, compute_gpu: usize) -> Self {
        Self { stepper: NodeStepper::new(cfg, scheduler, compute_gpu) }
    }

    pub fn with_kv(
        cfg: SimEngineConfig,
        scheduler: Box<dyn Scheduler>,
        kv: KvOffloadManager,
    ) -> Self {
        Self { stepper: NodeStepper::from_parts(cfg, scheduler, kv, 0) }
    }

    /// Attach a co-tenant fleet: every virtual-time advance in the run
    /// loop routes through [`TenantFleet::advance_to`], so tenant
    /// allocation churn and collective traffic land exactly where the
    /// serve path's own DMA does.
    pub fn with_tenants(mut self, fleet: TenantFleet) -> Self {
        self.stepper.set_tenants(Some(fleet));
        self
    }

    /// The underlying stepper (inspection; the cluster drives its own).
    pub fn stepper(&self) -> &NodeStepper {
        &self.stepper
    }

    /// Serve `requests` to completion in virtual time. One run per
    /// engine: the stepper's queues and metrics carry across calls.
    pub fn run(&mut self, hr: &mut HarvestRuntime, requests: Vec<Request>) -> SimEngineReport {
        crate::obs::trace::set_node(0);
        self.stepper.install(hr);
        self.stepper.enqueue_all(requests);
        while self.stepper.has_work() {
            self.stepper.step(hr);
        }
        self.stepper.finalize();
        SimEngineReport {
            metrics: self.stepper.metrics().clone(),
            kv_stats: self.stepper.kv_manager().stats.clone(),
            scheduler: self.stepper.scheduler_name(),
            use_harvest: self.stepper.config().kv.use_harvest,
            tenant: self.stepper.tenant_stats(),
            completions: self.stepper.completions().to_vec(),
            steps: self.stepper.steps(),
            sheds: self.stepper.shed_ids().to_vec(),
            admission: self.stepper.admission_stats(),
            attribution: self.stepper.attribution_report(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::HarvestConfig;
    use crate::memsim::{NodeSpec, SimNode};
    use crate::moe::config::find_kv_model;
    use crate::server::request::{WorkloadGen, WorkloadSpec};
    use crate::server::scheduler::{CompletelyFair, Fcfs};

    fn kv_cfg(use_harvest: bool, cap_blocks: usize) -> KvConfig {
        KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap_blocks,
            use_harvest,
            host_backed_peer: false,
        }
    }

    fn workload(n: usize) -> Vec<Request> {
        WorkloadGen::new(WorkloadSpec {
            n_requests: n,
            mean_prompt_tokens: 64.0,
            max_new_tokens: 8,
            ..Default::default()
        })
        .generate()
    }

    fn run(
        use_harvest: bool,
        cap: usize,
        sched: Box<dyn Scheduler>,
        n: usize,
    ) -> SimEngineReport {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let cfg = SimEngineConfig::new(kv_cfg(use_harvest, cap), 8, 16);
        let mut eng = SimEngine::new(cfg, sched, 0);
        eng.run(&mut hr, workload(n))
    }

    #[test]
    fn completes_all_requests() {
        let r = run(true, 10_000, Box::new(Fcfs::new()), 12);
        assert_eq!(r.metrics.requests_finished, 12);
        assert_eq!(r.metrics.tokens_generated, 12 * 8);
        assert!(r.metrics.tokens_per_sec() > 0.0);
    }

    #[test]
    fn ample_memory_means_no_reloads() {
        let r = run(true, 10_000, Box::new(Fcfs::new()), 8);
        assert_eq!(r.kv_stats.reloads(), 0);
    }

    #[test]
    fn tight_memory_with_fair_scheduler_causes_churn() {
        // 16 running seqs of ~64+8 tokens (~5 blocks each) vs 24-block
        // pool: cohort rotation evicts and reloads constantly.
        let fair = run(true, 24, Box::new(CompletelyFair::new(1)), 16);
        assert!(fair.kv_stats.reloads() > 0, "CF under pressure must churn");
    }

    #[test]
    fn harvest_speeds_up_fair_decoding() {
        let with = run(true, 24, Box::new(CompletelyFair::new(1)), 16);
        let without = run(false, 24, Box::new(CompletelyFair::new(1)), 16);
        assert!(with.kv_stats.reloads() > 0 && without.kv_stats.reloads() > 0);
        assert!(
            with.metrics.tokens_per_sec() > without.metrics.tokens_per_sec(),
            "harvest {:.0} tps <= host {:.0} tps",
            with.metrics.tokens_per_sec(),
            without.metrics.tokens_per_sec()
        );
    }

    #[test]
    fn fcfs_churns_less_than_cf() {
        let fcfs = run(true, 24, Box::new(Fcfs::new()), 16);
        let cf = run(true, 24, Box::new(CompletelyFair::new(1)), 16);
        assert!(
            cf.kv_stats.reloads() > fcfs.kv_stats.reloads(),
            "token-level preemption amplifies KV churn (cf {} vs fcfs {})",
            cf.kv_stats.reloads(),
            fcfs.kv_stats.reloads()
        );
    }

    fn run_prefetch(
        cap: usize,
        slots: usize,
        n: usize,
        prefetch: bool,
    ) -> SimEngineReport {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let mut cfg = SimEngineConfig::new(kv_cfg(true, cap), slots, 16);
        if prefetch {
            cfg = cfg.with_prefetch(crate::harvest::prefetch::PrefetchConfig::default());
        }
        let mut eng = SimEngine::new(cfg, Box::new(CompletelyFair::new(1)), 0);
        eng.run(&mut hr, workload(n))
    }

    #[test]
    fn prefetch_reduces_decode_stall_under_cf_churn() {
        // 16 requests of ~5 blocks rotating through 8 slots against a
        // 60-block pool: every rotation reloads the incoming cohort.
        // With prefetch those reloads ride the compute window instead.
        let off = run_prefetch(60, 8, 16, false);
        let on = run_prefetch(60, 8, 16, true);
        assert!(off.metrics.decode_stall_ns > 0, "baseline must stall under churn");
        assert!(
            on.metrics.decode_stall_ns < off.metrics.decode_stall_ns,
            "prefetch on: stall {} >= off {}",
            on.metrics.decode_stall_ns,
            off.metrics.decode_stall_ns
        );
        let pf = on.metrics.prefetch.as_ref().expect("prefetch ledger present");
        assert!(pf.issued > 0 && pf.hits > 0, "{pf:?}");
        assert!(off.metrics.prefetch.is_none());
        // both complete everything; overlap must not cost throughput
        assert_eq!(on.metrics.requests_finished, 16);
        assert_eq!(off.metrics.requests_finished, 16);
        assert!(
            on.metrics.tokens_per_sec() >= off.metrics.tokens_per_sec() * 0.95,
            "prefetch must not cost throughput: on {:.0} vs off {:.0}",
            on.metrics.tokens_per_sec(),
            off.metrics.tokens_per_sec()
        );
    }

    #[test]
    fn prefetch_is_inert_with_ample_memory() {
        // Nothing is ever non-local, so the planner has nothing to do
        // and results match the non-prefetch run exactly.
        let off = run_prefetch(10_000, 8, 8, false);
        let on = run_prefetch(10_000, 8, 8, true);
        assert_eq!(on.kv_stats.reloads(), 0);
        assert_eq!(on.metrics.prefetch.as_ref().unwrap().issued, 0);
        assert_eq!(on.metrics.decode_stall_ns, off.metrics.decode_stall_ns);
        assert_eq!(on.metrics.tokens_generated, off.metrics.tokens_generated);
        assert_eq!(on.metrics.makespan_ns(), off.metrics.makespan_ns());
    }

    #[test]
    fn demotion_under_pressure_serves_all_requests_without_recompute() {
        // End-to-end RevocationAction::Demoted: tenant pressure
        // oscillates while the engine decodes; with demote_to_host the
        // controller migrates lossy peer blocks to host-tier leases
        // instead of dropping them, so the run never pays recompute and
        // still finishes everything.
        let run = |demote: bool| {
            let mut hcfg = HarvestConfig::for_node(2);
            hcfg.demote_to_host = demote;
            let mut hr =
                HarvestRuntime::new(SimNode::new(crate::memsim::NodeSpec::h100x2()), hcfg);
            const GIB: u64 = 1 << 30;
            let steps: Vec<(u64, u64)> = (0..40)
                .map(|i| (i * 5_000_000, if i % 2 == 1 { 80 * GIB } else { 0 }))
                .collect();
            hr.node.set_tenant_load(
                1,
                crate::memsim::TenantLoad::from_steps(80 * GIB, steps),
            );
            let cfg = SimEngineConfig::new(kv_cfg(true, 32), 4, 16);
            let mut eng = SimEngine::new(cfg, Box::new(CompletelyFair::new(1)), 0);
            let report = eng.run(&mut hr, workload(12));
            (report, hr.demotions)
        };
        let (dropped, demoted_ct) = run(false);
        assert_eq!(dropped.metrics.requests_finished, 12);
        assert_eq!(demoted_ct, 0);
        assert!(
            dropped.kv_stats.recomputes > 0,
            "baseline must lose lossy blocks under this pressure"
        );
        let (demoted, demoted_ct) = run(true);
        assert_eq!(demoted.metrics.requests_finished, 12);
        assert!(demoted_ct > 0, "pressure must exercise the demotion path");
        assert!(demoted.kv_stats.demotions > 0, "demotion events observed by the manager");
        assert_eq!(demoted.kv_stats.recomputes, 0, "demoted blocks are never lost");
        assert!(
            demoted.kv_stats.host_reloads > 0,
            "demoted blocks reload from their host-tier lease"
        );
    }

    #[test]
    fn promotion_prefetch_pulls_demoted_blocks_back_to_peer() {
        // With prefetch on, blocks the scheduler predicts for later
        // steps that sit on the host tier are background-migrated to
        // peer HBM — the promotion half of the demote/promote cycle.
        let mut hr =
            HarvestRuntime::new(SimNode::new(crate::memsim::NodeSpec::h100x2()), {
                let mut c = HarvestConfig::for_node(2);
                c.demote_to_host = true;
                c
            });
        const GIB: u64 = 1 << 30;
        // one early pressure spike demotes, then the peer frees up
        let steps = vec![(0u64, 0u64), (5_000_000, 80 * GIB), (10_000_000, 0)];
        hr.node.set_tenant_load(1, crate::memsim::TenantLoad::from_steps(80 * GIB, steps));
        let cfg = SimEngineConfig::new(kv_cfg(true, 32), 4, 16)
            .with_prefetch(crate::harvest::prefetch::PrefetchConfig::default());
        let mut eng = SimEngine::new(cfg, Box::new(CompletelyFair::new(1)), 0);
        let report = eng.run(&mut hr, workload(12));
        assert_eq!(report.metrics.requests_finished, 12);
        if report.kv_stats.demotions > 0 {
            assert!(
                report.kv_stats.promotions > 0,
                "demoted blocks should be promoted back: {:?}",
                report.kv_stats
            );
        }
    }

    #[test]
    fn staggered_arrivals_are_served() {
        let reqs = WorkloadGen::new(WorkloadSpec {
            n_requests: 6,
            mean_prompt_tokens: 32.0,
            max_new_tokens: 4,
            mean_interarrival_ns: 50_000_000,
            ..Default::default()
        })
        .generate();
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let cfg = SimEngineConfig::new(kv_cfg(true, 1_000), 4, 8);
        let mut eng = SimEngine::new(cfg, Box::new(Fcfs::new()), 0);
        let r = eng.run(&mut hr, reqs);
        assert_eq!(r.metrics.requests_finished, 6);
        assert!(r.metrics.ttft.count() == 6);
    }
}
