//! Serving metrics: TTFT, end-to-end latency, throughput, decode-stall
//! attribution and prefetch outcomes; JSON export.

use crate::harvest::prefetch::PrefetchStats;
use crate::memsim::Ns;
use crate::obs::{LogHistogram, MetricsRegistry};
use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// Aggregated serving metrics for one run.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Time-to-first-token per request (ns).
    pub ttft: Summary,
    /// End-to-end latency per request (ns).
    pub e2e: Summary,
    /// Per-token decode latencies (ns).
    pub per_token: Summary,
    pub tokens_generated: u64,
    pub requests_finished: u64,
    /// Tokens belonging to *completed* requests only — the numerator of
    /// [`ServeMetrics::goodput_tok_s`]. Work spent on requests that
    /// never finish (still live at run end) is excluded, so an
    /// admission controller gets no goodput credit for half-served
    /// requests.
    pub tokens_completed: u64,
    /// Requests rejected by admission control (router static-depth shed
    /// or the node controller's shed decision).
    pub requests_shed: u64,
    /// Requests that were deferred by admission control at least once
    /// before being admitted.
    pub deferred_admissions: u64,
    /// Total wait accrued across deferred admissions (arrival →
    /// admission). Informational: this wait is *already counted in
    /// TTFT*, which is measured from arrival — deferral cannot game the
    /// latency metric.
    pub deferred_wait_ns: Ns,
    /// Total decode time spent waiting on KV residency (reload DMA /
    /// recompute) rather than computing — the quantity the prefetch
    /// pipeline exists to shrink.
    pub decode_stall_ns: Ns,
    /// Prefetch outcome ledger, when the engine ran with prefetch on.
    pub prefetch: Option<PrefetchStats>,
    /// Full TTFT distribution in fixed log₂ buckets — unlike the
    /// percentile points above, bucket counts merge exactly across
    /// nodes ([`ServeMetrics::merge`] sums buckets, never averages
    /// percentiles).
    pub ttft_hist: LogHistogram,
    /// Full time-between-tokens (per decode step) distribution, same
    /// bucketing as [`ServeMetrics::ttft_hist`].
    pub tbt_hist: LogHistogram,
    start: Option<Ns>,
    end: Ns,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_start(&mut self, now: Ns) {
        if self.start.is_none() {
            self.start = Some(now);
        }
    }

    pub fn on_first_token(&mut self, arrival: Ns, now: Ns) {
        self.ttft.add((now - arrival) as f64);
        self.ttft_hist.record(now - arrival);
    }

    pub fn on_token(&mut self, step_ns: Ns) {
        self.per_token.add(step_ns as f64);
        self.tbt_hist.record(step_ns);
        self.tokens_generated += 1;
    }

    /// Record a completion; `tokens` is what the request generated end
    /// to end and accrues to the completed-only goodput counter.
    pub fn on_finish(&mut self, arrival: Ns, now: Ns, tokens: u64) {
        self.e2e.add((now - arrival) as f64);
        self.requests_finished += 1;
        self.tokens_completed += tokens;
        self.end = self.end.max(now);
    }

    /// Record a request rejected by admission control.
    pub fn on_shed(&mut self) {
        self.requests_shed += 1;
    }

    /// Record a request admitted after deferral, with the wait it
    /// accrued between arrival and admission. TTFT is measured from
    /// arrival, so this wait is already inside the TTFT samples — the
    /// counter only attributes it.
    pub fn on_deferred_admit(&mut self, wait_ns: Ns) {
        self.deferred_admissions += 1;
        self.deferred_wait_ns += wait_ns;
    }

    /// Record time a decode step spent blocked on KV residency before
    /// its compute could start.
    pub fn on_stall(&mut self, stall_ns: Ns) {
        self.decode_stall_ns += stall_ns;
    }

    /// Fold another run's metrics into this one — the per-node →
    /// cluster rollup ([`crate::cluster::ClusterReport`]). Sample
    /// summaries concatenate (percentiles stay exact), counters add, and
    /// the makespan window becomes the union: earliest start to latest
    /// finish, so [`ServeMetrics::tokens_per_sec`] reports *aggregate*
    /// cluster throughput over wall (virtual) time, not a sum of
    /// per-node rates.
    pub fn merge(&mut self, other: &ServeMetrics) {
        for &x in other.ttft.samples() {
            self.ttft.add(x);
        }
        for &x in other.e2e.samples() {
            self.e2e.add(x);
        }
        for &x in other.per_token.samples() {
            self.per_token.add(x);
        }
        self.tokens_generated += other.tokens_generated;
        self.requests_finished += other.requests_finished;
        self.tokens_completed += other.tokens_completed;
        self.requests_shed += other.requests_shed;
        self.deferred_admissions += other.deferred_admissions;
        self.deferred_wait_ns += other.deferred_wait_ns;
        self.decode_stall_ns += other.decode_stall_ns;
        self.ttft_hist.merge(&other.ttft_hist);
        self.tbt_hist.merge(&other.tbt_hist);
        self.prefetch = match (self.prefetch.take(), &other.prefetch) {
            (None, None) => None,
            (Some(p), None) => Some(p),
            (None, Some(q)) => Some(q.clone()),
            (Some(mut p), Some(q)) => {
                p.planned += q.planned;
                p.issued += q.issued;
                p.yielded += q.yielded;
                p.stale_plans += q.stale_plans;
                p.hits += q.hits;
                p.late += q.late;
                p.wasted += q.wasted;
                p.bytes_prefetched += q.bytes_prefetched;
                p.bytes_wasted += q.bytes_wasted;
                Some(p)
            }
        };
        self.start = match (self.start, other.start) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.end = self.end.max(other.end);
    }

    pub fn makespan_ns(&self) -> Ns {
        self.end.saturating_sub(self.start.unwrap_or(0))
    }

    /// Decode throughput over the whole run.
    pub fn tokens_per_sec(&self) -> f64 {
        let span = self.makespan_ns();
        if span == 0 {
            0.0
        } else {
            self.tokens_generated as f64 / (span as f64 / 1e9)
        }
    }

    /// Completed-only throughput: tokens of *finished* requests over
    /// the makespan. The SLO controller's goodput floor steers on this.
    pub fn goodput_tok_s(&self) -> f64 {
        let span = self.makespan_ns();
        if span == 0 {
            0.0
        } else {
            self.tokens_completed as f64 / (span as f64 / 1e9)
        }
    }

    /// Fraction of terminated requests (finished + shed) that were
    /// shed. `0.0` when nothing has terminated.
    pub fn shed_rate(&self) -> f64 {
        let total = self.requests_finished + self.requests_shed;
        if total == 0 {
            0.0
        } else {
            self.requests_shed as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&'static str, Json)> = vec![
            ("tokens_generated", self.tokens_generated.into()),
            ("requests_finished", self.requests_finished.into()),
            ("makespan_ns", self.makespan_ns().into()),
            ("throughput_tps", self.tokens_per_sec().into()),
            ("tokens_completed", self.tokens_completed.into()),
            ("goodput_tok_s", self.goodput_tok_s().into()),
            ("requests_shed", self.requests_shed.into()),
            ("shed_rate", self.shed_rate().into()),
            ("deferred_admissions", self.deferred_admissions.into()),
            ("deferred_wait_ns", self.deferred_wait_ns.into()),
            ("ttft_p50_ns", self.ttft.percentile(50.0).into()),
            ("ttft_p99_ns", self.ttft.percentile(99.0).into()),
            ("e2e_p50_ns", self.e2e.percentile(50.0).into()),
            ("e2e_p99_ns", self.e2e.percentile(99.0).into()),
            ("per_token_mean_ns", self.per_token.mean().into()),
            ("decode_stall_ns", self.decode_stall_ns.into()),
        ];
        if let Some(p) = &self.prefetch {
            pairs.push(("prefetch_issued", p.issued.into()));
            pairs.push(("prefetch_hits", p.hits.into()));
            pairs.push(("prefetch_late", p.late.into()));
            pairs.push(("prefetch_wasted", p.wasted.into()));
            pairs.push(("prefetch_yielded", p.yielded.into()));
            pairs.push(("prefetch_bytes", p.bytes_prefetched.into()));
        }
        obj(pairs)
    }

    /// Register this run's serving metrics into the unified registry
    /// under `prefix` (e.g. `"serve"`): the headline counters and
    /// gauges, the full TTFT/TBT histograms, and the prefetch ledger
    /// when one is attached.
    pub fn register(&self, reg: &mut MetricsRegistry, prefix: &str) {
        reg.counter(&format!("{prefix}.tokens_generated"), self.tokens_generated);
        reg.counter(&format!("{prefix}.requests_finished"), self.requests_finished);
        reg.counter(&format!("{prefix}.tokens_completed"), self.tokens_completed);
        reg.counter(&format!("{prefix}.requests_shed"), self.requests_shed);
        reg.counter(&format!("{prefix}.deferred_admissions"), self.deferred_admissions);
        reg.counter(&format!("{prefix}.deferred_wait_ns"), self.deferred_wait_ns);
        reg.counter(&format!("{prefix}.decode_stall_ns"), self.decode_stall_ns);
        reg.counter(&format!("{prefix}.makespan_ns"), self.makespan_ns());
        reg.gauge(&format!("{prefix}.throughput_tps"), self.tokens_per_sec());
        reg.gauge(&format!("{prefix}.goodput_tok_s"), self.goodput_tok_s());
        reg.gauge(&format!("{prefix}.shed_rate"), self.shed_rate());
        reg.hist(&format!("{prefix}.ttft_ns"), &self.ttft_hist);
        reg.hist(&format!("{prefix}.tbt_ns"), &self.tbt_hist);
        if let Some(p) = &self.prefetch {
            p.register(reg, &format!("{prefix}.prefetch"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accumulates() {
        let mut m = ServeMetrics::new();
        m.on_start(100);
        m.on_first_token(0, 150);
        m.on_token(50);
        m.on_token(50);
        m.on_finish(0, 200, 2);
        assert_eq!(m.tokens_generated, 2);
        assert_eq!(m.requests_finished, 1);
        assert_eq!(m.makespan_ns(), 100);
        assert!((m.tokens_per_sec() - 2.0 / 100e-9).abs() < 1.0);
    }

    #[test]
    fn start_latches_first_value() {
        let mut m = ServeMetrics::new();
        m.on_start(100);
        m.on_start(999);
        m.on_finish(0, 300, 0);
        assert_eq!(m.makespan_ns(), 200);
    }

    #[test]
    fn merge_unions_window_and_concatenates_samples() {
        let mut a = ServeMetrics::new();
        a.on_start(100);
        a.on_first_token(0, 150);
        a.on_token(50);
        a.on_finish(0, 200, 1);
        let mut b = ServeMetrics::new();
        b.on_start(50);
        b.on_first_token(0, 90);
        b.on_token(40);
        b.on_token(40);
        b.on_stall(7);
        b.on_finish(0, 400, 2);
        a.merge(&b);
        assert_eq!(a.tokens_generated, 3);
        assert_eq!(a.requests_finished, 2);
        assert_eq!(a.decode_stall_ns, 7);
        assert_eq!(a.ttft.count(), 2);
        assert_eq!(a.makespan_ns(), 350, "earliest start .. latest finish");
        // aggregate throughput over the union window
        assert!((a.tokens_per_sec() - 3.0 / 350e-9).abs() < 1.0);
        // merging into an empty rollup is identity
        let mut empty = ServeMetrics::new();
        empty.merge(&a);
        assert_eq!(empty.makespan_ns(), a.makespan_ns());
        assert_eq!(empty.tokens_generated, a.tokens_generated);
        // prefetch ledgers add when present
        let mut p = ServeMetrics::new();
        p.prefetch = Some(PrefetchStats { issued: 2, hits: 1, ..Default::default() });
        let mut q = ServeMetrics::new();
        q.prefetch = Some(PrefetchStats { issued: 3, hits: 2, ..Default::default() });
        p.merge(&q);
        let pf = p.prefetch.unwrap();
        assert_eq!(pf.issued, 5);
        assert_eq!(pf.hits, 3);
    }

    #[test]
    fn json_has_headline_fields() {
        let mut m = ServeMetrics::new();
        m.on_start(0);
        m.on_token(10);
        m.on_finish(0, 10, 1);
        let j = m.to_json();
        assert!(j.get("throughput_tps").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("tokens_generated").unwrap().as_u64().unwrap(), 1);
    }

    #[test]
    fn stall_and_prefetch_surface_in_json() {
        let mut m = ServeMetrics::new();
        m.on_start(0);
        m.on_stall(40);
        m.on_stall(2);
        m.on_finish(0, 100, 0);
        assert_eq!(m.decode_stall_ns, 42);
        let j = m.to_json();
        assert_eq!(j.get("decode_stall_ns").unwrap().as_u64().unwrap(), 42);
        assert!(j.get("prefetch_hits").is_err(), "absent without prefetch");
        m.prefetch = Some(crate::harvest::prefetch::PrefetchStats {
            issued: 3,
            hits: 2,
            ..Default::default()
        });
        let j = m.to_json();
        assert_eq!(j.get("prefetch_hits").unwrap().as_u64().unwrap(), 2);
        assert_eq!(j.get("prefetch_issued").unwrap().as_u64().unwrap(), 3);
    }

    #[test]
    fn goodput_shed_rate_and_deferrals() {
        let mut m = ServeMetrics::new();
        m.on_start(0);
        // Two finished requests (8 tokens each), one shed, one deferral.
        for _ in 0..20 {
            m.on_token(5);
        }
        m.on_finish(0, 50, 8);
        m.on_finish(0, 100, 8);
        m.on_shed();
        m.on_deferred_admit(30);
        // Goodput counts completed tokens (16), not all generated (20).
        assert!((m.goodput_tok_s() - 16.0 / 100e-9).abs() < 1.0);
        assert!(m.goodput_tok_s() < m.tokens_per_sec());
        assert!((m.shed_rate() - 1.0 / 3.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("tokens_completed").unwrap().as_u64().unwrap(), 16);
        assert_eq!(j.get("requests_shed").unwrap().as_u64().unwrap(), 1);
        assert!(j.get("goodput_tok_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("shed_rate").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("deferred_admissions").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("deferred_wait_ns").unwrap().as_u64().unwrap(), 30);
        // New counters roll up through merge.
        let mut rollup = ServeMetrics::new();
        rollup.merge(&m);
        rollup.merge(&m);
        assert_eq!(rollup.tokens_completed, 32);
        assert_eq!(rollup.requests_shed, 2);
        assert_eq!(rollup.deferred_admissions, 2);
        assert_eq!(rollup.deferred_wait_ns, 60);
    }

    #[test]
    fn histograms_record_and_merge_bucketwise() {
        // Node A: 99 fast first tokens. Node B: one slow outlier.
        let mut a = ServeMetrics::new();
        for _ in 0..99 {
            a.on_first_token(0, 1_000);
        }
        let mut b = ServeMetrics::new();
        b.on_first_token(0, 1_000_000);
        a.merge(&b);
        assert_eq!(a.ttft_hist.count(), 100);
        // Bucket-wise merge keeps the outlier at the tail: the merged
        // p100 must sit at the slow sample's magnitude. Averaging two
        // per-node p99 points (1 µs and 1 ms) could not recover this.
        assert!(a.ttft_hist.percentile(100.0) >= 1_000_000);
        assert!(a.ttft_hist.percentile(50.0) < 2_048);
    }

    #[test]
    fn register_exposes_counters_and_histograms() {
        let mut m = ServeMetrics::new();
        m.on_start(0);
        m.on_first_token(0, 100);
        m.on_token(10);
        m.on_finish(0, 110, 1);
        let mut reg = MetricsRegistry::new();
        m.register(&mut reg, "serve");
        match reg.get("serve.tokens_generated") {
            Some(crate::obs::Metric::Counter(1)) => {}
            other => panic!("unexpected metric: {other:?}"),
        }
        match reg.get("serve.ttft_ns") {
            Some(crate::obs::Metric::Hist(h)) => assert_eq!(h.count(), 1),
            other => panic!("unexpected metric: {other:?}"),
        }
        assert!(reg.get("serve.prefetch.issued").is_none(), "no ledger attached");
    }
}
