//! Serving coordinator: requests, batching, scheduling, engines, metrics.
//!
//! Two engines share the coordinator pieces:
//!
//! * [`engine::RealEngine`] — the end-to-end path: real PJRT compute on
//!   the AOT-compiled tiny MoE transformer (`crate::runtime`), with a
//!   physical page pool and continuous batching. Wall-clock, Python-free.
//! * [`sim::SimEngine`] — the paper-scale path: virtual-time decode over
//!   the `KvOffloadManager`, used for the §6.3 fair-decoding study where
//!   token-level preemption churns the KV working set.
//!
//! Schedulers ([`scheduler`]): FCFS continuous batching (vLLM-style) and
//! Completely-Fair decoding (token-level preemption, §6.3).
//!
//! The sim engine's loop body lives in [`stepper::NodeStepper`] — one
//! shared per-iteration pipeline that [`sim::SimEngine`] drives to
//! completion and [`crate::cluster::ClusterNode`] drives incrementally,
//! so single-node and cluster serving can never diverge.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod sim;
pub mod stepper;

pub use batcher::ContinuousBatcher;
pub use engine::RealEngine;
pub use metrics::ServeMetrics;
pub use request::{Request, RequestState, WorkloadGen, WorkloadSpec};
pub use scheduler::{CompletelyFair, Fcfs, Scheduler};
pub use sim::{SimEngine, SimEngineConfig, SimEngineReport};
pub use stepper::{AgingConfig, NodeStepper, RequestOutcome};
