//! Decode schedulers.
//!
//! * [`Fcfs`] — vLLM-style continuous batching: admitted requests run to
//!   completion; new requests join as slots free up.
//! * [`CompletelyFair`] — §6.3 "Completely Fair Decoding": token-level
//!   preemption. All admitted requests share decode slots round-robin
//!   with a token quantum; a preempted request's KV cache becomes
//!   eviction fodder, which "can amplify churn in the KV working set" —
//!   exactly the regime where peer-HBM offload acts as a *scheduler
//!   robustness mechanism*.
//!
//! Schedulers only decide *which* sequences decode next; KV residency and
//! memory movement is the manager's job.

use crate::kv::SeqId;
use std::collections::VecDeque;

/// Pick the set of sequences that decode the next token.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    /// A request became runnable (admitted / finished prefill).
    fn admit(&mut self, seq: SeqId);
    /// A request finished (or was cancelled).
    fn retire(&mut self, seq: SeqId);
    /// Select up to `slots` sequences for the next decode step.
    fn select(&mut self, slots: usize) -> Vec<SeqId>;
    /// Number of runnable sequences.
    fn runnable(&self) -> usize;

    /// Predict which sequences decode within the next `horizon` steps of
    /// `slots` each, nearest first, without mutating scheduler state.
    /// This is the prefetch pipeline's demand signal
    /// ([`crate::harvest::prefetch`]): the KV manager reloads these
    /// sequences' blocks in the background while the current step's
    /// compute runs. Predictions are best-effort — admissions and
    /// retirements between now and then can change the real cohort; a
    /// misprediction costs wasted prefetch bandwidth, never correctness.
    /// The default declines to predict.
    fn lookahead(&self, slots: usize, horizon: usize) -> Vec<SeqId> {
        let _ = (slots, horizon);
        Vec::new()
    }

    /// Allocation-free [`Scheduler::select`]: write the cohort into
    /// `out` (cleared first) instead of returning a fresh `Vec`. The
    /// stepper calls this once per iteration with a reused scratch
    /// buffer. The default delegates to `select`; the built-in
    /// schedulers override it to write `out` directly.
    fn select_into(&mut self, slots: usize, out: &mut Vec<SeqId>) {
        out.clear();
        out.extend(self.select(slots));
    }

    /// Allocation-free [`Scheduler::lookahead`], same contract as
    /// [`Scheduler::select_into`].
    fn lookahead_into(&self, slots: usize, horizon: usize, out: &mut Vec<SeqId>) {
        out.clear();
        out.extend(self.lookahead(slots, horizon));
    }
}

/// First-come-first-served continuous batching: the oldest `slots`
/// runnable sequences decode every step (stable set until one finishes).
#[derive(Debug, Default)]
pub struct Fcfs {
    queue: VecDeque<SeqId>,
}

impl Fcfs {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn admit(&mut self, seq: SeqId) {
        self.queue.push_back(seq);
    }

    fn retire(&mut self, seq: SeqId) {
        self.queue.retain(|&s| s != seq);
    }

    fn select(&mut self, slots: usize) -> Vec<SeqId> {
        self.queue.iter().take(slots).copied().collect()
    }

    fn runnable(&self) -> usize {
        self.queue.len()
    }

    /// FCFS keeps a stable head set; queued sequences join only as slots
    /// free up. The next cohort is exactly the head `slots`; in the
    /// worst case an entire cohort retires each step (common when
    /// requests admitted together finish together) and the next `slots`
    /// queued sequences move up, so `slots * horizon` is the tight
    /// over-bound on what can decode within `horizon` steps.
    fn lookahead(&self, slots: usize, horizon: usize) -> Vec<SeqId> {
        let n = slots.saturating_mul(horizon.max(1));
        self.queue.iter().take(n).copied().collect()
    }

    fn select_into(&mut self, slots: usize, out: &mut Vec<SeqId>) {
        out.clear();
        out.extend(self.queue.iter().take(slots).copied());
    }

    fn lookahead_into(&self, slots: usize, horizon: usize, out: &mut Vec<SeqId>) {
        out.clear();
        let n = slots.saturating_mul(horizon.max(1));
        out.extend(self.queue.iter().take(n).copied());
    }
}

/// Token-level round-robin with a quantum: after a sequence has decoded
/// `quantum` consecutive tokens it rotates to the back, so every runnable
/// sequence makes progress (maximal fairness at quantum=1).
#[derive(Debug)]
pub struct CompletelyFair {
    queue: VecDeque<SeqId>,
    quantum: u32,
    /// Tokens the current head-of-line set has consumed in this round.
    used: u32,
}

impl CompletelyFair {
    pub fn new(quantum: u32) -> Self {
        Self { queue: VecDeque::new(), quantum: quantum.max(1), used: 0 }
    }
}

impl Scheduler for CompletelyFair {
    fn name(&self) -> &'static str {
        "completely-fair"
    }

    fn admit(&mut self, seq: SeqId) {
        self.queue.push_back(seq);
    }

    fn retire(&mut self, seq: SeqId) {
        self.queue.retain(|&s| s != seq);
    }

    fn select(&mut self, slots: usize) -> Vec<SeqId> {
        let mut out = Vec::new();
        self.select_into(slots, &mut out);
        out
    }

    fn select_into(&mut self, slots: usize, out: &mut Vec<SeqId>) {
        out.clear();
        out.extend(self.queue.iter().take(slots).copied());
        self.used += 1;
        if self.used >= self.quantum && self.queue.len() > slots {
            // Rotate the whole served set to the back: the *next* cohort
            // gets the slots (token-level preemption).
            for _ in 0..out.len().min(self.queue.len()) {
                if let Some(s) = self.queue.pop_front() {
                    self.queue.push_back(s);
                }
            }
            self.used = 0;
        }
    }

    fn runnable(&self) -> usize {
        self.queue.len()
    }

    /// Exact rotation replay on a scratch copy of the queue: absent
    /// admissions/retirements, the prediction for step *k* equals what
    /// the *k*-th future [`Scheduler::select`] will return. This is
    /// what makes prefetch effective under token-level preemption — the
    /// *next* cohort is usually a different set whose KV was just
    /// evicted.
    fn lookahead(&self, slots: usize, horizon: usize) -> Vec<SeqId> {
        let mut out = Vec::new();
        self.lookahead_into(slots, horizon, &mut out);
        out
    }

    fn lookahead_into(&self, slots: usize, horizon: usize, out: &mut Vec<SeqId>) {
        out.clear();
        let mut q = self.queue.clone();
        let mut used = self.used;
        for _ in 0..horizon.max(1) {
            for s in q.iter().take(slots) {
                if !out.contains(s) {
                    out.push(*s);
                }
            }
            used += 1;
            if used >= self.quantum && q.len() > slots {
                for _ in 0..slots.min(q.len()) {
                    if let Some(s) = q.pop_front() {
                        q.push_back(s);
                    }
                }
                used = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(i: u64) -> SeqId {
        SeqId(i)
    }

    #[test]
    fn fcfs_keeps_stable_set_until_retire() {
        let mut f = Fcfs::new();
        for i in 0..4 {
            f.admit(s(i));
        }
        assert_eq!(f.select(2), vec![s(0), s(1)]);
        assert_eq!(f.select(2), vec![s(0), s(1)], "stable");
        f.retire(s(0));
        assert_eq!(f.select(2), vec![s(1), s(2)]);
        assert_eq!(f.runnable(), 3);
    }

    #[test]
    fn cf_rotates_every_quantum() {
        let mut c = CompletelyFair::new(1);
        for i in 0..4 {
            c.admit(s(i));
        }
        assert_eq!(c.select(2), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(2), s(3)], "rotated after quantum=1");
        assert_eq!(c.select(2), vec![s(0), s(1)], "round robin wraps");
    }

    #[test]
    fn cf_quantum_bigger_than_one() {
        let mut c = CompletelyFair::new(3);
        for i in 0..4 {
            c.admit(s(i));
        }
        assert_eq!(c.select(2), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(2), s(3)], "rotates after 3 tokens");
    }

    #[test]
    fn cf_no_rotation_when_everyone_fits() {
        let mut c = CompletelyFair::new(1);
        for i in 0..2 {
            c.admit(s(i));
        }
        assert_eq!(c.select(4), vec![s(0), s(1)]);
        assert_eq!(c.select(4), vec![s(0), s(1)], "no preemption if all served");
    }

    #[test]
    fn cf_every_sequence_makes_progress() {
        let mut c = CompletelyFair::new(1);
        for i in 0..6 {
            c.admit(s(i));
        }
        let mut served = std::collections::BTreeSet::new();
        for _ in 0..3 {
            for x in c.select(2) {
                served.insert(x);
            }
        }
        assert_eq!(served.len(), 6, "all sequences served within 3 rounds");
    }

    #[test]
    fn fcfs_lookahead_covers_head_and_bounded_tail() {
        let mut f = Fcfs::new();
        for i in 0..6 {
            f.admit(s(i));
        }
        assert_eq!(f.lookahead(2, 1), vec![s(0), s(1)], "horizon 1 = next cohort");
        // worst case: the whole cohort retires each step, so two more
        // steps can reach the next 2*2 queued sequences
        assert_eq!(f.lookahead(2, 3), vec![s(0), s(1), s(2), s(3), s(4), s(5)]);
        // prediction matches the next select exactly at horizon 1
        assert_eq!(f.lookahead(2, 1), f.select(2));
    }

    #[test]
    fn cf_lookahead_replays_rotation_exactly() {
        let mut c = CompletelyFair::new(1);
        for i in 0..6 {
            c.admit(s(i));
        }
        // Predict three steps ahead, then confirm against real selects.
        let predicted = c.lookahead(2, 3);
        assert_eq!(predicted, vec![s(0), s(1), s(2), s(3), s(4), s(5)]);
        let mut actual: Vec<SeqId> = Vec::new();
        for _ in 0..3 {
            for x in c.select(2) {
                if !actual.contains(&x) {
                    actual.push(x);
                }
            }
        }
        assert_eq!(predicted, actual, "lookahead must replay select's rotation");
    }

    #[test]
    fn cf_lookahead_is_pure() {
        let mut c = CompletelyFair::new(2);
        for i in 0..4 {
            c.admit(s(i));
        }
        c.select(2); // used = 1, mid-quantum
        let a = c.lookahead(2, 4);
        let b = c.lookahead(2, 4);
        assert_eq!(a, b, "lookahead must not mutate state");
        // and it respects the partially consumed quantum
        assert_eq!(c.lookahead(2, 1), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(0), s(1)], "prediction matches next select");
    }

    #[test]
    fn retire_mid_rotation_is_safe() {
        let mut c = CompletelyFair::new(1);
        for i in 0..3 {
            c.admit(s(i));
        }
        c.select(1);
        c.retire(s(1));
        // keeps functioning with remaining sequences
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            for x in c.select(1) {
                seen.insert(x);
            }
        }
        assert!(seen.contains(&s(0)) && seen.contains(&s(2)));
        assert!(!seen.contains(&s(1)));
    }
}
