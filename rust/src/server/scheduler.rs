//! Decode schedulers.
//!
//! * [`Fcfs`] — vLLM-style continuous batching: admitted requests run to
//!   completion; new requests join as slots free up.
//! * [`CompletelyFair`] — §6.3 "Completely Fair Decoding": token-level
//!   preemption. All admitted requests share decode slots round-robin
//!   with a token quantum; a preempted request's KV cache becomes
//!   eviction fodder, which "can amplify churn in the KV working set" —
//!   exactly the regime where peer-HBM offload acts as a *scheduler
//!   robustness mechanism*.
//!
//! Schedulers only decide *which* sequences decode next; KV residency and
//! memory movement is the manager's job.

use crate::kv::SeqId;
use std::collections::VecDeque;

/// Pick the set of sequences that decode the next token.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    /// A request became runnable (admitted / finished prefill).
    fn admit(&mut self, seq: SeqId);
    /// A request finished (or was cancelled).
    fn retire(&mut self, seq: SeqId);
    /// Select up to `slots` sequences for the next decode step.
    fn select(&mut self, slots: usize) -> Vec<SeqId>;
    /// Number of runnable sequences.
    fn runnable(&self) -> usize;
}

/// First-come-first-served continuous batching: the oldest `slots`
/// runnable sequences decode every step (stable set until one finishes).
#[derive(Debug, Default)]
pub struct Fcfs {
    queue: VecDeque<SeqId>,
}

impl Fcfs {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn admit(&mut self, seq: SeqId) {
        self.queue.push_back(seq);
    }

    fn retire(&mut self, seq: SeqId) {
        self.queue.retain(|&s| s != seq);
    }

    fn select(&mut self, slots: usize) -> Vec<SeqId> {
        self.queue.iter().take(slots).copied().collect()
    }

    fn runnable(&self) -> usize {
        self.queue.len()
    }
}

/// Token-level round-robin with a quantum: after a sequence has decoded
/// `quantum` consecutive tokens it rotates to the back, so every runnable
/// sequence makes progress (maximal fairness at quantum=1).
#[derive(Debug)]
pub struct CompletelyFair {
    queue: VecDeque<SeqId>,
    quantum: u32,
    /// Tokens the current head-of-line set has consumed in this round.
    used: u32,
}

impl CompletelyFair {
    pub fn new(quantum: u32) -> Self {
        Self { queue: VecDeque::new(), quantum: quantum.max(1), used: 0 }
    }
}

impl Scheduler for CompletelyFair {
    fn name(&self) -> &'static str {
        "completely-fair"
    }

    fn admit(&mut self, seq: SeqId) {
        self.queue.push_back(seq);
    }

    fn retire(&mut self, seq: SeqId) {
        self.queue.retain(|&s| s != seq);
    }

    fn select(&mut self, slots: usize) -> Vec<SeqId> {
        let picked: Vec<SeqId> = self.queue.iter().take(slots).copied().collect();
        self.used += 1;
        if self.used >= self.quantum && self.queue.len() > slots {
            // Rotate the whole served set to the back: the *next* cohort
            // gets the slots (token-level preemption).
            for _ in 0..picked.len().min(self.queue.len()) {
                if let Some(s) = self.queue.pop_front() {
                    self.queue.push_back(s);
                }
            }
            self.used = 0;
        }
        picked
    }

    fn runnable(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(i: u64) -> SeqId {
        SeqId(i)
    }

    #[test]
    fn fcfs_keeps_stable_set_until_retire() {
        let mut f = Fcfs::new();
        for i in 0..4 {
            f.admit(s(i));
        }
        assert_eq!(f.select(2), vec![s(0), s(1)]);
        assert_eq!(f.select(2), vec![s(0), s(1)], "stable");
        f.retire(s(0));
        assert_eq!(f.select(2), vec![s(1), s(2)]);
        assert_eq!(f.runnable(), 3);
    }

    #[test]
    fn cf_rotates_every_quantum() {
        let mut c = CompletelyFair::new(1);
        for i in 0..4 {
            c.admit(s(i));
        }
        assert_eq!(c.select(2), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(2), s(3)], "rotated after quantum=1");
        assert_eq!(c.select(2), vec![s(0), s(1)], "round robin wraps");
    }

    #[test]
    fn cf_quantum_bigger_than_one() {
        let mut c = CompletelyFair::new(3);
        for i in 0..4 {
            c.admit(s(i));
        }
        assert_eq!(c.select(2), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(0), s(1)]);
        assert_eq!(c.select(2), vec![s(2), s(3)], "rotates after 3 tokens");
    }

    #[test]
    fn cf_no_rotation_when_everyone_fits() {
        let mut c = CompletelyFair::new(1);
        for i in 0..2 {
            c.admit(s(i));
        }
        assert_eq!(c.select(4), vec![s(0), s(1)]);
        assert_eq!(c.select(4), vec![s(0), s(1)], "no preemption if all served");
    }

    #[test]
    fn cf_every_sequence_makes_progress() {
        let mut c = CompletelyFair::new(1);
        for i in 0..6 {
            c.admit(s(i));
        }
        let mut served = std::collections::BTreeSet::new();
        for _ in 0..3 {
            for x in c.select(2) {
                served.insert(x);
            }
        }
        assert_eq!(served.len(), 6, "all sequences served within 3 rounds");
    }

    #[test]
    fn retire_mid_rotation_is_safe() {
        let mut c = CompletelyFair::new(1);
        for i in 0..3 {
            c.admit(s(i));
        }
        c.select(1);
        c.retire(s(1));
        // keeps functioning with remaining sequences
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            for x in c.select(1) {
                seen.insert(x);
            }
        }
        assert!(seen.contains(&s(0)) && seen.contains(&s(2)));
        assert!(!seen.contains(&s(1)));
    }
}
