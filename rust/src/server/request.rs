//! Requests and workload generation.
//!
//! The paper's MoE evaluation draws prompts from MTBench (§4.4) and the
//! §6.2 discussion keys on prefix reuse. The real MTBench text is not
//! needed (and not available offline) — what matters to every simulator
//! here is the *length and arrival* distribution, so [`WorkloadGen`]
//! produces MTBench-like multi-turn lengths (lognormal, mean ≈ 180
//! prompt tokens) with Poisson arrivals, plus a configurable shared-
//! prefix fraction for the reuse studies.

use crate::kv::SeqId;
use crate::memsim::Ns;
use crate::util::rng::Rng;

/// Lifecycle of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    /// Prefill done, decoding; `generated` counts decoded tokens.
    Running,
    /// Preempted by the scheduler (KV possibly swapped out).
    Preempted,
    Finished,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: SeqId,
    pub arrival: Ns,
    pub prompt_tokens: u32,
    pub max_new_tokens: u32,
    /// Leading tokens shared with other requests (prefix-reuse studies).
    pub shared_prefix_tokens: u32,
    /// Which shared prefix this request reuses, when it has one. All
    /// requests with the same group id share one prompt prefix; the
    /// cluster router's affinity policy uses this to steer a request to
    /// the node already holding the group's prefix KV blocks.
    pub prefix_group: Option<u32>,
    pub state: RequestState,
    pub generated: u32,
    pub first_token_at: Option<Ns>,
    pub finished_at: Option<Ns>,
}

impl Request {
    pub fn total_context(&self) -> u32 {
        self.prompt_tokens + self.generated
    }

    pub fn done(&self) -> bool {
        self.generated >= self.max_new_tokens
    }
}

/// Workload shape.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    /// Mean prompt length (lognormal; MTBench-like ≈ 180).
    pub mean_prompt_tokens: f64,
    /// Lognormal sigma of prompt lengths.
    pub prompt_sigma: f64,
    pub max_new_tokens: u32,
    /// Mean inter-arrival gap (exponential). 0 = all arrive at t=0.
    pub mean_interarrival_ns: Ns,
    /// Fraction of requests sharing a common prompt prefix (§6.2).
    pub shared_prefix_fraction: f64,
    pub shared_prefix_tokens: u32,
    /// How many distinct shared prefixes exist (each shared request is
    /// assigned to one uniformly). 1 = the pre-cluster behavior of a
    /// single global prefix.
    pub n_prefix_groups: usize,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        Self {
            n_requests: 64,
            mean_prompt_tokens: 180.0,
            prompt_sigma: 0.6,
            max_new_tokens: 32,
            mean_interarrival_ns: 0,
            shared_prefix_fraction: 0.0,
            shared_prefix_tokens: 0,
            n_prefix_groups: 1,
            seed: 0,
        }
    }
}

/// Deterministic workload generator.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    spec: WorkloadSpec,
}

impl WorkloadGen {
    pub fn new(spec: WorkloadSpec) -> Self {
        Self { spec }
    }

    /// Generate the full request list, sorted by arrival.
    pub fn generate(&self) -> Vec<Request> {
        let s = &self.spec;
        let mut rng = Rng::new(s.seed);
        let mut t: Ns = 0;
        let mu = s.mean_prompt_tokens.ln() - s.prompt_sigma * s.prompt_sigma / 2.0;
        (0..s.n_requests)
            .map(|i| {
                if s.mean_interarrival_ns > 0 {
                    t += rng.exp(1.0 / s.mean_interarrival_ns as f64) as Ns;
                }
                let prompt = rng.lognormal(mu, s.prompt_sigma).round().max(1.0) as u32;
                let (shared, group) = if rng.bool(s.shared_prefix_fraction) {
                    let g = rng.below(s.n_prefix_groups.max(1) as u64) as u32;
                    (s.shared_prefix_tokens.min(prompt), Some(g))
                } else {
                    (0, None)
                };
                Request {
                    id: SeqId(i as u64),
                    arrival: t,
                    prompt_tokens: prompt,
                    max_new_tokens: s.max_new_tokens,
                    shared_prefix_tokens: shared,
                    prefix_group: if shared > 0 { group } else { None },
                    state: RequestState::Queued,
                    generated: 0,
                    first_token_at: None,
                    finished_at: None,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn generates_requested_count_sorted_by_arrival() {
        let gen = WorkloadGen::new(WorkloadSpec {
            n_requests: 50,
            mean_interarrival_ns: 1_000_000,
            ..Default::default()
        });
        let reqs = gen.generate();
        assert_eq!(reqs.len(), 50);
        assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(reqs.iter().all(|r| r.prompt_tokens >= 1));
    }

    #[test]
    fn prompt_lengths_match_target_mean() {
        let gen = WorkloadGen::new(WorkloadSpec { n_requests: 5_000, ..Default::default() });
        let lens: Vec<f64> = gen.generate().iter().map(|r| r.prompt_tokens as f64).collect();
        let mean = stats::mean(&lens);
        assert!((150.0..210.0).contains(&mean), "mean={mean}");
    }

    #[test]
    fn zero_interarrival_means_batch_arrival() {
        let gen = WorkloadGen::new(WorkloadSpec::default());
        assert!(gen.generate().iter().all(|r| r.arrival == 0));
    }

    #[test]
    fn shared_prefix_fraction_respected() {
        let gen = WorkloadGen::new(WorkloadSpec {
            n_requests: 2_000,
            shared_prefix_fraction: 0.5,
            shared_prefix_tokens: 64,
            ..Default::default()
        });
        let reqs = gen.generate();
        let with = reqs.iter().filter(|r| r.shared_prefix_tokens > 0).count();
        let frac = with as f64 / reqs.len() as f64;
        assert!((0.45..0.55).contains(&frac), "frac={frac}");
        assert!(reqs.iter().all(|r| r.shared_prefix_tokens <= r.prompt_tokens));
    }

    #[test]
    fn prefix_groups_partition_shared_requests() {
        let gen = WorkloadGen::new(WorkloadSpec {
            n_requests: 2_000,
            shared_prefix_fraction: 0.6,
            shared_prefix_tokens: 64,
            n_prefix_groups: 4,
            ..Default::default()
        });
        let reqs = gen.generate();
        let mut per_group = [0usize; 4];
        for r in &reqs {
            match r.prefix_group {
                Some(g) => {
                    assert!(r.shared_prefix_tokens > 0);
                    per_group[g as usize] += 1;
                }
                None => assert_eq!(r.shared_prefix_tokens, 0),
            }
        }
        // every group is used, roughly uniformly
        assert!(per_group.iter().all(|&c| c > 150), "{per_group:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WorkloadGen::new(WorkloadSpec::default()).generate();
        let b = WorkloadGen::new(WorkloadSpec::default()).generate();
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.prompt_tokens == y.prompt_tokens));
    }
}
