//! The one serving loop body — shared by the single-node engine and the
//! cluster.
//!
//! [`NodeStepper`] owns everything one serving node iterates over:
//! pending/live request queues, the decode scheduler, the
//! [`KvOffloadManager`], the optional co-tenant fleet, the shared-prefix
//! cache, and serving metrics. One call to [`NodeStepper::step`] is one
//! engine iteration:
//!
//! ```text
//!   idle? ── jump to next arrival ─┐
//!                                  ▼
//!   admit arrived requests (SLO admission control: admit/defer/shed;
//!                           prefill, prefix-cache aware)
//!                                  ▼
//!   select cohort ── sync (drain revocations) ── idle-age sweep
//!                                  ▼
//!   restore KV residency (prefix blocks + cohort) → decode stall
//!                                  ▼
//!   overlap deadline-aware prefetch/promotion with compute
//!                                  ▼
//!   advance one step of compute (tenant fleet wakes ride along)
//!                                  ▼
//!   decode one token per cohort member; retire finished requests
//! ```
//!
//! [`crate::server::SimEngine::run`] drives a stepper to completion over
//! a closed request list; [`crate::cluster::ClusterNode`] drives the
//! *same* stepper incrementally under the cluster's event calendar, so
//! the loop body exists exactly once and single-node and cluster
//! results cannot silently diverge (`rust/tests/differential.rs` pins
//! the equivalence bit-for-bit).
//!
//! # Example
//!
//! ```
//! use harvest::harvest::{HarvestConfig, HarvestRuntime};
//! use harvest::kv::KvConfig;
//! use harvest::memsim::{NodeSpec, SimNode};
//! use harvest::moe::find_kv_model;
//! use harvest::server::{Fcfs, NodeStepper, SimEngineConfig, WorkloadGen, WorkloadSpec};
//!
//! let mut hr =
//!     HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
//! let kv = KvConfig {
//!     model: find_kv_model("deepseek").unwrap(),
//!     block_tokens: 16,
//!     local_capacity_blocks: 10_000,
//!     use_harvest: true,
//!     host_backed_peer: false,
//! };
//! let cfg = SimEngineConfig::new(kv, 8, 16);
//! let mut stepper = NodeStepper::new(cfg, Box::new(Fcfs::new()), 0);
//! stepper.install(&mut hr);
//! let spec = WorkloadSpec { n_requests: 4, max_new_tokens: 4, ..Default::default() };
//! stepper.enqueue_all(WorkloadGen::new(spec).generate());
//! while stepper.has_work() {
//!     stepper.step(&mut hr);
//! }
//! assert_eq!(stepper.completions().len(), 4);
//! assert!(stepper.steps() >= 4);
//! ```

use super::metrics::ServeMetrics;
use super::request::Request;
use super::scheduler::Scheduler;
use super::sim::SimEngineConfig;
use crate::control::{AdmissionController, AdmissionDecision, AdmissionSignals, AdmissionStats};
use crate::harvest::{HarvestRuntime, Transfer};
use crate::kv::{KvOffloadManager, SeqId};
use crate::memsim::{DeviceId, Ns};
use crate::obs::attrib::{AttribTracker, Component};
use crate::obs::profile::{self, Phase};
use crate::obs::trace::{self, Subsystem};
use crate::obs::{flight, FlightSignals};
use crate::tenantsim::{FleetStats, TenantFleet};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Sequence-id namespace for prefix-cache sequences, far above any
/// request id the workload generator produces.
pub const PREFIX_SEQ_BASE: u64 = 1 << 40;

/// Periodic idle-aging sweep: every `sweep_ns` of virtual time the
/// stepper runs one [`KvOffloadManager::age_idle_blocks`] rung over
/// blocks idle for at least `idle_ns`, demoting `ratio_pct` percent of
/// them one tier down the cold ladder. Both the single-node engine and
/// every cluster node inherit the cadence from the same config, so the
/// ladder can never tick at different rates on the two paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AgingConfig {
    /// Virtual-time period between sweeps.
    pub sweep_ns: Ns,
    /// A block must have been untouched this long to age.
    pub idle_ns: Ns,
    /// Fraction of eligible blocks each sweep demotes (1..=99).
    pub ratio_pct: u32,
}

impl Default for AgingConfig {
    fn default() -> Self {
        Self { sweep_ns: 2_000_000, idle_ns: 4_000_000, ratio_pct: 50 }
    }
}

/// Per-request completion record — the differential-equivalence tests
/// compare these bit-for-bit between a bare [`crate::server::SimEngine`]
/// run and a 1-node [`crate::cluster::Cluster`] run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestOutcome {
    pub id: SeqId,
    pub arrival: Ns,
    pub first_token_at: Ns,
    pub finished_at: Ns,
    /// Tokens decoded for this request.
    pub generated: u32,
}

/// A cached shared-prefix: its KV lives under `seq` in this node's KV
/// manager; `ready_at` gates reuse while the blocks are still arriving
/// (initial build or fabric migration).
#[derive(Debug, Clone, Copy)]
struct PrefixEntry {
    seq: SeqId,
    tokens: u32,
    ready_at: Ns,
}

/// One serving node's complete stepping state. See the module docs for
/// the per-iteration pipeline.
pub struct NodeStepper {
    cfg: SimEngineConfig,
    kv: KvOffloadManager,
    scheduler: Box<dyn Scheduler>,
    /// Closed-loop co-tenants stepped on every time advance (None =
    /// exogenous-timeline mode).
    tenants: Option<TenantFleet>,
    /// GPU whose HBM stages prefix-cache export/install raw transfers.
    compute_gpu: usize,
    /// Arrived-or-routed, not yet admitted (kept arrival-sorted).
    pending: VecDeque<Request>,
    /// Admitted, decoding.
    live: BTreeMap<SeqId, Request>,
    prefix_cache: BTreeMap<u32, PrefixEntry>,
    next_prefix_seq: u64,
    metrics: ServeMetrics,
    completions: Vec<RequestOutcome>,
    prefix_hits: u64,
    steps: u64,
    next_sweep: Ns,
    installed: bool,
    /// Feedback admission control (None = admit everything that fits,
    /// the legacy behaviour).
    admission: Option<AdmissionController>,
    /// Requests currently deferred by the controller (only ever the
    /// queue front, but deferral can repeat across steps).
    deferred: BTreeSet<SeqId>,
    /// High-water mark of arrivals already fed to the monitor window,
    /// as the `(arrival, id)` dispatch key.
    noted_upto: Option<(Ns, u64)>,
    /// Requests shed by the controller, in decision order.
    sheds: Vec<SeqId>,
    // Scratch buffers reused across steps — the hot path allocates
    // nothing per iteration.
    cohort: Vec<SeqId>,
    predicted: Vec<SeqId>,
    groups: Vec<u32>,
    /// Per-request causal latency attribution (None = off, the
    /// default). Observation-only: reads the clock and KV counters at
    /// phase boundaries, never advances time or steers a decision.
    attrib: Option<AttribTracker>,
}

impl NodeStepper {
    /// Build a stepper with a fresh KV manager (prefetch wired in when
    /// the config asks for it). `compute_gpu` is the GPU whose HBM the
    /// KV manager allocates from.
    pub fn new(cfg: SimEngineConfig, scheduler: Box<dyn Scheduler>, compute_gpu: usize) -> Self {
        let mut kv = KvOffloadManager::new(cfg.kv, compute_gpu);
        if let Some(p) = cfg.prefetch {
            kv = kv.with_prefetch(p);
        }
        Self::from_parts(cfg, scheduler, kv, compute_gpu)
    }

    /// Build a stepper around an existing KV manager (ablations hand in
    /// specially configured managers).
    pub fn from_parts(
        cfg: SimEngineConfig,
        scheduler: Box<dyn Scheduler>,
        kv: KvOffloadManager,
        compute_gpu: usize,
    ) -> Self {
        Self {
            cfg,
            kv,
            scheduler,
            tenants: None,
            compute_gpu,
            pending: VecDeque::new(),
            live: BTreeMap::new(),
            prefix_cache: BTreeMap::new(),
            next_prefix_seq: 0,
            metrics: ServeMetrics::new(),
            completions: Vec::new(),
            prefix_hits: 0,
            steps: 0,
            next_sweep: 0,
            installed: false,
            admission: cfg.admission.map(AdmissionController::new),
            deferred: BTreeSet::new(),
            noted_upto: None,
            sheds: Vec::new(),
            cohort: Vec::new(),
            predicted: Vec::new(),
            groups: Vec::new(),
            attrib: cfg.attribution.then(AttribTracker::new),
        }
    }

    /// Attach (or detach) a co-tenant fleet. Call before
    /// [`NodeStepper::install`].
    pub fn set_tenants(&mut self, tenants: Option<TenantFleet>) {
        self.tenants = tenants;
    }

    /// Latch the metrics start time and install the co-tenant fleet
    /// (tenants exist from t=0 — persistent footprints, replay
    /// timelines — not from the first time advance). Idempotent.
    pub fn install(&mut self, hr: &mut HarvestRuntime) {
        if self.installed {
            return;
        }
        self.installed = true;
        self.metrics.on_start(hr.node.clock.now());
        self.next_sweep = hr.node.clock.now();
        if let Some(f) = self.tenants.as_mut() {
            f.install(hr);
        }
    }

    /// Advance virtual time, through the fleet when one is attached.
    /// Free-standing over the split-off fields so callers can hold
    /// disjoint borrows of the rest of the stepper.
    fn advance_time(tenants: &mut Option<TenantFleet>, hr: &mut HarvestRuntime, t: Ns) {
        match tenants {
            Some(f) => f.advance_to(hr, t),
            None => {
                hr.advance_to(t);
            }
        }
    }

    fn advance(&mut self, hr: &mut HarvestRuntime, t: Ns) {
        Self::advance_time(&mut self.tenants, hr, t);
    }

    // -- queue entry points ----------------------------------------------

    /// Hand over one routed request (callers feed arrivals in global
    /// arrival order, so the pending queue stays arrival-sorted).
    pub fn enqueue(&mut self, req: Request) {
        self.pending.push_back(req);
    }

    /// Load a closed request list, sorting it into canonical
    /// `(arrival, id)` dispatch order — the same order the cluster
    /// routes arrivals in.
    pub fn enqueue_all(&mut self, mut requests: Vec<Request>) {
        requests.sort_by_key(|r| (r.arrival, r.id.0));
        self.pending.extend(requests);
    }

    // -- introspection ---------------------------------------------------

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.live.is_empty()
    }

    /// Requests waiting or decoding here.
    pub fn queue_depth(&self) -> usize {
        self.pending.len() + self.live.len()
    }

    /// The virtual time of this stepper's next step (only meaningful
    /// while [`NodeStepper::has_work`]).
    pub fn next_event_time(&self, hr: &HarvestRuntime) -> Ns {
        let now = hr.node.clock.now();
        if !self.live.is_empty() {
            return now;
        }
        match self.pending.front() {
            Some(r) => now.max(r.arrival),
            None => now,
        }
    }

    pub fn holds_prefix(&self, group: u32) -> bool {
        self.prefix_cache.contains_key(&group)
    }

    /// The KV sequence holding `group`'s prefix blocks on this node.
    pub fn prefix_seq(&self, group: u32) -> Option<SeqId> {
        self.prefix_cache.get(&group).map(|e| e.seq)
    }

    pub fn kv_manager(&self) -> &KvOffloadManager {
        &self.kv
    }

    pub fn config(&self) -> &SimEngineConfig {
        &self.cfg
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// Completion records in finish order.
    pub fn completions(&self) -> &[RequestOutcome] {
        &self.completions
    }

    /// Engine iterations executed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Admissions whose prefill reused the cached prefix KV.
    pub fn prefix_hits(&self) -> u64 {
        self.prefix_hits
    }

    /// Requests served to completion.
    pub fn finished(&self) -> u64 {
        self.completions.len() as u64
    }

    /// This stepper's co-tenant fleet counters, when one is attached.
    pub fn tenant_stats(&self) -> Option<FleetStats> {
        self.tenants.as_ref().map(|f| f.stats())
    }

    /// `false` while the admission controller sits in its `Pressured`
    /// hysteresis state; always `true` without a controller. Routers
    /// prefer accepting nodes.
    pub fn admission_accepting(&self) -> bool {
        self.admission.as_ref().is_none_or(|c| c.accepting())
    }

    /// Controller decision counters, when a controller is attached.
    pub fn admission_stats(&self) -> Option<AdmissionStats> {
        self.admission.as_ref().map(|c| c.stats())
    }

    /// Finished-request attribution ledgers, when attribution is armed
    /// (see [`crate::obs::attrib`]).
    pub fn attribution_report(&self) -> Option<crate::obs::AttributionReport> {
        self.attrib.as_ref().map(|a| a.report())
    }

    /// Requests shed by the admission controller, in decision order.
    pub fn shed_ids(&self) -> &[SeqId] {
        &self.sheds
    }

    /// KV-block pool occupancy, per-mille.
    pub fn occupancy_pm(&self) -> u32 {
        let cap = self.cfg.kv.local_capacity_blocks.max(1);
        (self.kv.local_blocks().min(cap) as u128 * 1000 / cap as u128) as u32
    }

    /// Tenant-held fraction of total GPU HBM at `hr`'s current virtual
    /// time, per-mille.
    pub fn tenant_pressure_pm(hr: &HarvestRuntime) -> u32 {
        let now = hr.node.clock.now();
        let (mut held, mut cap) = (0u64, 0u64);
        for g in &hr.node.gpus {
            held += g.tenant_used_at(now);
            cap += g.hbm.capacity();
        }
        if cap == 0 { 0 } else { (held.min(cap) as u128 * 1000 / cap as u128) as u32 }
    }

    // -- prefix-cache migration (cluster spillover) ----------------------

    /// Read out `group`'s blocks for a fabric migration: restore
    /// residency (lease-addressed reloads for anything on a harvest
    /// tier), then egress compute-GPU → host staging for the NIC.
    /// Returns the token count, byte count and the virtual time the
    /// payload is ready to leave.
    pub fn export_prefix(&mut self, hr: &mut HarvestRuntime, group: u32) -> Option<(u32, u64, Ns)> {
        let entry = *self.prefix_cache.get(&group)?;
        let ready = self.kv.access_seq(hr, entry.seq);
        let blocks = self.kv.table().seq_blocks(entry.seq).len() as u64;
        let bytes = blocks * self.cfg.kv.block_bytes();
        if bytes == 0 {
            return Some((entry.tokens, 0, ready));
        }
        let report = Transfer::new()
            .raw(DeviceId::Gpu(self.compute_gpu), DeviceId::Host, bytes)
            .submit(hr)
            .expect("raw transfer cannot go stale");
        Some((entry.tokens, bytes, report.end.max(ready)))
    }

    /// Land a migrated prefix: build the group's blocks in this node's
    /// KV manager and gate reuse on the later of `ready_at` (the fabric
    /// delivery time) and the host-staging → HBM ingress completing on
    /// the local PCIe link. (The ingress is scheduled when the migration
    /// is decided rather than at NIC delivery — a deliberate
    /// simplification that can occupy the link early; the *gate* is
    /// never early, so reuse always pays both hops.)
    pub fn install_prefix(&mut self, hr: &mut HarvestRuntime, group: u32, tokens: u32, ready_at: Ns) {
        if self.prefix_cache.contains_key(&group) {
            return;
        }
        let seq = self.build_prefix(hr, group, tokens);
        let blocks = self.kv.table().seq_blocks(seq).len() as u64;
        let bytes = blocks * self.cfg.kv.block_bytes();
        let mut gate = ready_at;
        if bytes > 0 {
            let ingress = Transfer::new()
                .raw(DeviceId::Host, DeviceId::Gpu(self.compute_gpu), bytes)
                .submit(hr)
                .expect("raw transfer cannot go stale");
            gate = gate.max(ingress.end);
        }
        if let Some(e) = self.prefix_cache.get_mut(&group) {
            e.ready_at = gate;
        }
    }

    /// Create the prefix sequence and append its tokens (no compute is
    /// charged here — the caller accounts prefill or fabric time).
    fn build_prefix(&mut self, hr: &mut HarvestRuntime, group: u32, tokens: u32) -> SeqId {
        let seq = SeqId(PREFIX_SEQ_BASE + self.next_prefix_seq);
        self.next_prefix_seq += 1;
        let bt = self.cfg.kv.block_tokens as usize;
        self.kv.reserve_local(hr, (tokens as usize).div_ceil(bt));
        for _ in 0..tokens {
            self.kv.append_token(hr, seq);
        }
        self.prefix_cache
            .insert(group, PrefixEntry { seq, tokens, ready_at: hr.node.clock.now() });
        seq
    }

    // -- the step body ---------------------------------------------------

    /// Feed every arrived-but-unseen request's arrival time into the
    /// controller's monitor window (exactly once per request). Pending
    /// stays `(arrival, id)`-sorted and is only popped from the front,
    /// so the unseen requests form a suffix past `noted_upto`.
    fn note_arrivals(&mut self, now: Ns) {
        let Some(ctl) = self.admission.as_mut() else { return };
        for r in &self.pending {
            let key = (r.arrival, r.id.0);
            if self.noted_upto.is_some_and(|hi| key <= hi) {
                continue;
            }
            if r.arrival > now {
                break;
            }
            ctl.note_arrival(r.arrival);
            self.noted_upto = Some(key);
        }
    }

    /// Admission + prefill for every arrived request that fits. The
    /// admission cutoff is the *rolling* clock: a request arriving while
    /// an earlier admission's prefill advanced time joins the same
    /// admission round instead of waiting a full decode step.
    ///
    /// With an [`AdmissionController`] attached, each front request gets
    /// a tri-state verdict: admit (prefill now — TTFT still counts from
    /// arrival, so any deferral wait already paid is inside the metric),
    /// defer (leave the FIFO intact and re-examine next step), or shed
    /// (pop, record, never serve).
    fn admit_ready(&mut self, hr: &mut HarvestRuntime) {
        self.note_arrivals(hr.node.clock.now());
        while self.live.len() < self.cfg.max_running {
            let Some(front) = self.pending.front() else { break };
            if front.arrival > hr.node.clock.now() {
                break;
            }
            let (id, arrival) = (front.id, front.arrival);
            let decision = match self.admission.is_some() {
                false => AdmissionDecision::Admit,
                true => {
                    let sig = AdmissionSignals {
                        occupancy_pm: self.occupancy_pm(),
                        tenant_pressure_pm: Self::tenant_pressure_pm(hr),
                        queue_depth: self.pending.len() + self.live.len(),
                        live: self.live.len(),
                    };
                    let ctl = self.admission.as_mut().expect("checked admission");
                    let d = ctl.decide(hr.node.clock.now(), arrival, &sig);
                    if trace::is_enabled() {
                        let name = match d {
                            AdmissionDecision::Admit => "admit",
                            AdmissionDecision::Defer => "defer",
                            AdmissionDecision::Shed => "shed",
                        };
                        trace::instant(
                            Subsystem::Admission,
                            name,
                            hr.node.clock.now(),
                            &[
                                ("occ_pm", sig.occupancy_pm as u64),
                                ("tenant_pm", sig.tenant_pressure_pm as u64),
                                ("queue", sig.queue_depth as u64),
                                ("predicted_ttft_ns", ctl.last_predicted_ttft_ns()),
                            ],
                        );
                    }
                    d
                }
            };
            match decision {
                AdmissionDecision::Admit => {
                    let mut req = self.pending.pop_front().expect("checked front");
                    if self.deferred.remove(&id) {
                        let wait = hr.node.clock.now().saturating_sub(arrival);
                        self.metrics.on_deferred_admit(wait);
                    }
                    if let Some(a) = self.attrib.as_mut() {
                        a.note_admit(id.0, arrival, hr.node.clock.now());
                    }
                    self.prefill(hr, &mut req);
                    self.scheduler.admit(req.id);
                    self.live.insert(req.id, req);
                }
                AdmissionDecision::Defer => {
                    if let Some(a) = self.attrib.as_mut() {
                        a.note_defer(id.0, hr.node.clock.now());
                    }
                    self.deferred.insert(id);
                    break;
                }
                AdmissionDecision::Shed => {
                    self.pending.pop_front();
                    self.deferred.remove(&id);
                    if let Some(a) = self.attrib.as_mut() {
                        a.note_shed(id.0);
                    }
                    self.metrics.on_shed();
                    self.sheds.push(id);
                }
            }
        }
    }

    /// Prefill one request. A cached prefix group shrinks the prefill to
    /// the unshared suffix (the affinity win); reuse waits for the
    /// prefix's `ready_at` when its blocks are still in flight over the
    /// node fabric — the wait overlaps the suffix prefill.
    fn prefill(&mut self, hr: &mut HarvestRuntime, req: &mut Request) {
        let _t = profile::timer(Phase::Prefill);
        let prefill_start = hr.node.clock.now();
        let (cached, gate) = match req.prefix_group.and_then(|g| self.prefix_cache.get(&g)) {
            Some(e) => (e.tokens.min(req.shared_prefix_tokens), e.ready_at),
            None => (0, 0),
        };
        if cached > 0 {
            self.prefix_hits += 1;
        }
        let fresh = req.prompt_tokens - cached;
        let prefill_ns = self.cfg.prefill_ns_per_token * fresh as u64;
        let target = hr.node.clock.now() + prefill_ns;
        self.advance(hr, target);
        if let Some(a) = self.attrib.as_mut() {
            a.charge(req.id.0, Component::PrefillCompute, hr.node.clock.now());
        }
        self.advance(hr, gate);
        if let Some(a) = self.attrib.as_mut() {
            a.charge(req.id.0, Component::PrefixFabric, hr.node.clock.now());
        }
        let kv_before = self.attrib.as_ref().map(|_| self.kv.stats.clone());
        let bt = self.cfg.kv.block_tokens as usize;
        // Vectored admission: free the suffix's block footprint in one
        // all-or-nothing batch instead of evicting per token.
        self.kv.reserve_local(hr, (fresh as usize).div_ceil(bt));
        for _ in 0..fresh {
            self.kv.append_token(hr, req.id);
        }
        if cached == 0 && req.shared_prefix_tokens > 0 {
            if let Some(g) = req.prefix_group {
                // First request of the group on this node: its prefill
                // (charged above, full-length) built the prefix KV —
                // retain it as the group cache.
                self.build_prefix(hr, g, req.shared_prefix_tokens);
            }
        }
        if let Some(a) = self.attrib.as_mut() {
            let now = hr.node.clock.now();
            let before = kv_before.as_ref().expect("snapshot taken when armed");
            a.charge_kv(req.id.0, now, before, &self.kv.stats);
            a.note_first_token(req.id.0, now);
        }
        req.first_token_at = Some(hr.node.clock.now());
        self.metrics.on_first_token(req.arrival, hr.node.clock.now());
        trace::span(
            Subsystem::Stepper,
            "prefill",
            prefill_start,
            hr.node.clock.now(),
            &[("req", req.id.0), ("fresh", fresh as u64), ("cached", cached as u64)],
        );
    }

    /// Run one engine iteration (see the module docs for the pipeline).
    /// Progress is guaranteed whenever [`NodeStepper::has_work`]: an
    /// idle stepper jumps to its next arrival and admits it; a busy one
    /// decodes a token per cohort member.
    pub fn step(&mut self, hr: &mut HarvestRuntime) {
        let _t_total = profile::timer(Phase::Total);
        let sheds_before = self.sheds.len();
        let v_enter = hr.node.clock.now();
        trace::set_time(v_enter);
        {
            let _t = profile::timer(Phase::Admission);
            // Idle: jump to the next arrival.
            if self.live.is_empty() {
                if let Some(at) = self.pending.front().map(|r| r.arrival) {
                    let target = at.max(hr.node.clock.now());
                    self.advance(hr, target);
                }
            }
            self.admit_ready(hr);
        }
        trace::span(Subsystem::Stepper, "admit", v_enter, hr.node.clock.now(), &[]);
        {
            let _t = profile::timer(Phase::Select);
            self.scheduler.select_into(self.cfg.decode_slots, &mut self.cohort);
        }
        if self.cohort.is_empty() {
            self.flight_check(hr, sheds_before);
            return;
        }
        self.steps += 1;
        let step_start = hr.node.clock.now();
        if let Some(a) = self.attrib.as_mut() {
            // Everything since each member's last charge (its own
            // append last step, or its first token) was waiting for
            // this cohort slot.
            a.charge_many(self.cohort.iter().map(|s| s.0), Component::SchedulerWait, step_start);
        }
        // Tick boundary: fold in revocations accumulated while time
        // advanced, then run the idle-aging ladder at its cadence.
        let kv_sync_before = self.attrib.as_ref().map(|_| self.kv.stats.clone());
        {
            let _t = profile::timer(Phase::KvSync);
            self.kv.sync(hr);
        }
        let v_synced = hr.node.clock.now();
        if let Some(a) = self.attrib.as_mut() {
            let before = kv_sync_before.as_ref().expect("snapshot taken when armed");
            a.charge_kv_many(self.cohort.iter().map(|s| s.0), v_synced, before, &self.kv.stats);
        }
        trace::span(Subsystem::Stepper, "kv_sync", step_start, v_synced, &[]);
        {
            let _t = profile::timer(Phase::Aging);
            if let Some(a) = self.cfg.aging {
                if step_start >= self.next_sweep {
                    let stepped = self.kv.age_idle_blocks(hr, a.idle_ns, a.ratio_pct);
                    self.next_sweep = step_start + a.sweep_ns;
                    trace::span(
                        Subsystem::Stepper,
                        "aging_sweep",
                        v_synced,
                        hr.node.clock.now(),
                        &[("aged", stepped as u64)],
                    );
                }
            }
        }
        let v_aged = hr.node.clock.now();
        if let Some(a) = self.attrib.as_mut() {
            a.charge_many(self.cohort.iter().map(|s| s.0), Component::AgingSweep, v_aged);
        }
        let kv_resid_before = self.attrib.as_ref().map(|_| self.kv.stats.clone());
        {
            let _t = profile::timer(Phase::Residency);
            // Restore residency — the prefix blocks decode attends over,
            // then the cohort's own blocks (this is where preemption and
            // offload churn cost).
            self.groups.clear();
            for i in 0..self.cohort.len() {
                let seq = self.cohort[i];
                let Some(g) = self.live.get(&seq).and_then(|r| r.prefix_group) else {
                    continue;
                };
                if self.groups.contains(&g) {
                    continue;
                }
                self.groups.push(g);
                if let Some(pseq) = self.prefix_cache.get(&g).map(|e| e.seq) {
                    self.kv.access_seq(hr, pseq);
                }
            }
            for i in 0..self.cohort.len() {
                let seq = self.cohort[i];
                self.kv.access_seq(hr, seq);
            }
        }
        trace::span(Subsystem::Stepper, "residency", v_aged, hr.node.clock.now(), &[]);
        if let Some(a) = self.attrib.as_mut() {
            let now = hr.node.clock.now();
            let before = kv_resid_before.as_ref().expect("snapshot taken when armed");
            a.charge_kv_many(self.cohort.iter().map(|s| s.0), now, before, &self.kv.stats);
        }
        // Everything between step_start and here was waiting on KV
        // residency, not computing.
        self.metrics.on_stall(hr.node.clock.now() - step_start);
        // Overlap: while this step's compute runs, issue background
        // reloads for the sequences the scheduler predicts will decode
        // next. The deadline is the start of the next step — the
        // planner guarantees prefetch DMA is off every link again by
        // the time demand fetches can reappear. Predicted blocks stuck
        // on the host/CXL tiers are promoted toward peer HBM in the
        // same window, so their eventual reload rides NVLink instead of
        // PCIe.
        {
            let _t = profile::timer(Phase::Prefetch);
            if let Some(pcfg) = self.cfg.prefetch {
                self.scheduler.lookahead_into(
                    self.cfg.decode_slots,
                    pcfg.horizon,
                    &mut self.predicted,
                );
                let deadline = hr.node.clock.now() + self.cfg.step_compute_ns;
                self.kv.prefetch_seqs(hr, &self.predicted, deadline);
                self.kv.promote_blocks(hr, &self.predicted, deadline);
            }
        }
        // Batched compute.
        let v_compute = hr.node.clock.now();
        if let Some(a) = self.attrib.as_mut() {
            // Prefetch submission is background-only, so this window is
            // normally empty; anything that did land is KV bookkeeping.
            a.charge_many(self.cohort.iter().map(|s| s.0), Component::KvOther, v_compute);
        }
        {
            let _t = profile::timer(Phase::Compute);
            let compute_end = v_compute + self.cfg.step_compute_ns;
            Self::advance_time(&mut self.tenants, hr, compute_end);
        }
        if let Some(a) = self.attrib.as_mut() {
            let now = hr.node.clock.now();
            a.charge_many(self.cohort.iter().map(|s| s.0), Component::Compute, now);
        }
        trace::span(
            Subsystem::Stepper,
            "compute",
            v_compute,
            hr.node.clock.now(),
            &[("cohort", self.cohort.len() as u64)],
        );
        let step_ns = hr.node.clock.now() - step_start;
        let v_decode = hr.node.clock.now();
        {
            let _t = profile::timer(Phase::Decode);
            for i in 0..self.cohort.len() {
                let seq = self.cohort[i];
                if let Some(a) = self.attrib.as_mut() {
                    // Earlier cohort members' appends were queueing
                    // ahead of this member's.
                    a.charge(seq.0, Component::SchedulerWait, hr.node.clock.now());
                }
                let kv_before = self.attrib.as_ref().map(|_| self.kv.stats.clone());
                self.kv.append_token(hr, seq);
                let now = hr.node.clock.now();
                if let Some(a) = self.attrib.as_mut() {
                    let before = kv_before.as_ref().expect("snapshot taken when armed");
                    a.charge_kv(seq.0, now, before, &self.kv.stats);
                }
                let req = self.live.get_mut(&seq).expect("scheduled request is live");
                req.generated += 1;
                self.metrics.on_token(step_ns);
                if req.done() {
                    req.finished_at = Some(now);
                    let outcome = RequestOutcome {
                        id: req.id,
                        arrival: req.arrival,
                        first_token_at: req.first_token_at.unwrap_or(now),
                        finished_at: now,
                        generated: req.generated,
                    };
                    self.metrics.on_finish(outcome.arrival, now, outcome.generated as u64);
                    if let Some(ctl) = self.admission.as_mut() {
                        let ttft = outcome.first_token_at.saturating_sub(outcome.arrival);
                        ctl.note_finish(now, ttft, outcome.generated as u64);
                    }
                    if let Some(a) = self.attrib.as_mut() {
                        a.note_finish(seq.0, now);
                    }
                    self.scheduler.retire(seq);
                    self.kv.finish_seq(hr, seq);
                    self.live.remove(&seq);
                    self.completions.push(outcome);
                }
            }
        }
        trace::span(Subsystem::Stepper, "decode", v_decode, hr.node.clock.now(), &[]);
        trace::span(
            Subsystem::Stepper,
            "step",
            v_enter,
            hr.node.clock.now(),
            &[("steps", self.steps), ("live", self.live.len() as u64)],
        );
        self.flight_check(hr, sheds_before);
    }

    /// Feed this step's end-of-step signals to the flight recorder (a
    /// no-op unless one is armed). Reads only: TTFT p99 comes from the
    /// controller's monitor (whose lazy window prune is query-idempotent
    /// — every monitor read prunes first, so observing here changes no
    /// later answer) and the OOM counter from the tenant broker.
    fn flight_check(&mut self, hr: &HarvestRuntime, sheds_before: usize) {
        if !flight::is_armed() {
            return;
        }
        let now = hr.node.clock.now();
        let (p99, target) = match self.admission.as_mut() {
            Some(ctl) => {
                let target = ctl.config().slo.ttft_p99_ns;
                (ctl.monitor_mut().ttft_p99(now).unwrap_or(0), target)
            }
            None => (0, 0),
        };
        let oom = self.tenants.as_ref().map_or(0, |f| f.broker().stats.oom_with_harvest);
        flight::observe(
            trace::current_node(),
            now,
            &FlightSignals {
                ttft_p99_ns: p99,
                ttft_target_ns: target,
                new_sheds: (self.sheds.len() - sheds_before) as u64,
                oom_with_harvest: oom,
            },
        );
    }

    /// Finalize metrics at end of run (attach the prefetch ledger).
    pub fn finalize(&mut self) {
        self.metrics.prefetch = self.kv.prefetch_stats().cloned();
    }
}
