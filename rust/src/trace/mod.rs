//! Cluster-trace synthesis — the Fig. 2 substrate.
//!
//! The paper motivates harvesting with the Alibaba Cluster Trace Program's
//! `gpu-v2020` dataset: GPU memory usage across 6,500 GPUs on 1,800
//! machines, 959,080 machine snapshots. The real trace is not available on
//! this image, so we synthesise an equivalent: machines with a persistent
//! per-machine utilisation *level* (drawn from [`UtilizationModel`]) plus
//! temporally-correlated noise, snapshotted periodically. The synthesis is
//! calibrated so the snapshot CDF reproduces the paper's quoted stats
//! (§2.1: ~68% of machines ≤ 20% memory used, ~87% ≤ 50%).

use crate::memsim::tenant::UtilizationModel;
use crate::util::rng::Rng;
use crate::util::stats;

/// One machine snapshot: total GPU memory utilisation fraction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Snapshot {
    pub machine: u32,
    pub step: u32,
    pub util: f64,
}

/// Shape of the synthetic cluster.
#[derive(Debug, Clone, Copy)]
pub struct TraceSpec {
    pub machines: usize,
    /// GPUs per machine (gpu-v2020 averages ~3.6; we draw 2/4/8).
    pub snapshots_per_machine: usize,
    /// Std-dev of the temporal noise around each machine's level.
    pub temporal_jitter: f64,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        // Full-scale Fig. 2 reproduction: 1,800 machines and enough steps
        // to produce ~959k snapshots (1800 * 533 = 959,400).
        Self { machines: 1_800, snapshots_per_machine: 533, temporal_jitter: 0.05, seed: 2020 }
    }
}

impl TraceSpec {
    /// A smaller spec for unit tests.
    pub fn small() -> Self {
        Self { machines: 100, snapshots_per_machine: 50, temporal_jitter: 0.05, seed: 2020 }
    }

    pub fn total_snapshots(&self) -> usize {
        self.machines * self.snapshots_per_machine
    }
}

/// The synthesised trace.
#[derive(Debug, Clone)]
pub struct ClusterTrace {
    pub spec: TraceSpec,
    utils: Vec<f64>, // flattened machine-major [machine][step]
}

impl ClusterTrace {
    /// Synthesise the trace. Each machine gets a stationary level `u_m ~
    /// UtilizationModel`; each snapshot adds mean-reverting jitter, so a
    /// machine's snapshots are correlated in time (as in the real trace)
    /// while the cross-machine distribution stays calibrated.
    pub fn synthesize(spec: TraceSpec) -> Self {
        let model = UtilizationModel::gpu_v2020();
        let mut rng = Rng::new(spec.seed);
        let mut utils = Vec::with_capacity(spec.total_snapshots());
        for _m in 0..spec.machines {
            let level = model.sample(&mut rng);
            let mut cur = level;
            for _s in 0..spec.snapshots_per_machine {
                // AR(1) around the machine level.
                cur = level + 0.7 * (cur - level) + rng.normal() * spec.temporal_jitter;
                utils.push(cur.clamp(0.0, 1.0));
            }
        }
        Self { spec, utils }
    }

    pub fn len(&self) -> usize {
        self.utils.len()
    }

    pub fn is_empty(&self) -> bool {
        self.utils.is_empty()
    }

    pub fn snapshots(&self) -> impl Iterator<Item = Snapshot> + '_ {
        let per = self.spec.snapshots_per_machine;
        self.utils.iter().enumerate().map(move |(i, &util)| Snapshot {
            machine: (i / per) as u32,
            step: (i % per) as u32,
            util,
        })
    }

    /// Fraction of snapshots with utilisation ≤ `u` (the Fig. 2 y-axis).
    pub fn cdf_at(&self, u: f64) -> f64 {
        stats::cdf_at(&self.utils, u)
    }

    /// The full CDF curve evaluated at `points` utilisation levels.
    pub fn cdf_curve(&self, points: &[f64]) -> Vec<(f64, f64)> {
        let mut sorted = self.utils.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        points
            .iter()
            .map(|&u| {
                let n = sorted.partition_point(|&s| s <= u);
                (u, n as f64 / sorted.len() as f64)
            })
            .collect()
    }

    /// Mean snapshot utilisation.
    pub fn mean_util(&self) -> f64 {
        stats::mean(&self.utils)
    }

    /// Per-machine mean utilisation (for heterogeneity analyses).
    pub fn machine_means(&self) -> Vec<f64> {
        let per = self.spec.snapshots_per_machine;
        self.utils.chunks(per).map(stats::mean).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_trace_matches_paper_anchors() {
        let spec = TraceSpec { machines: 2_000, snapshots_per_machine: 20, ..TraceSpec::small() };
        let t = ClusterTrace::synthesize(spec);
        let p20 = t.cdf_at(0.20);
        let p50 = t.cdf_at(0.50);
        // jitter smears the anchor slightly; stay within ±5pp
        assert!((p20 - 0.68).abs() < 0.05, "P(u<=0.2)={p20}");
        assert!((p50 - 0.87).abs() < 0.05, "P(u<=0.5)={p50}");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = ClusterTrace::synthesize(TraceSpec::small());
        let b = ClusterTrace::synthesize(TraceSpec::small());
        assert_eq!(a.utils, b.utils);
    }

    #[test]
    fn snapshot_indexing() {
        let t = ClusterTrace::synthesize(TraceSpec::small());
        assert_eq!(t.len(), 100 * 50);
        let snaps: Vec<_> = t.snapshots().collect();
        assert_eq!(snaps[0].machine, 0);
        assert_eq!(snaps[49].machine, 0);
        assert_eq!(snaps[50].machine, 1);
        assert_eq!(snaps[50].step, 0);
    }

    #[test]
    fn utils_in_range_and_temporally_correlated() {
        let t = ClusterTrace::synthesize(TraceSpec::small());
        assert!(t.snapshots().all(|s| (0.0..=1.0).contains(&s.util)));
        // Temporal correlation: within-machine variance << cross-machine.
        let machine_means = t.machine_means();
        let cross = crate::util::stats::stddev(&machine_means);
        let within: f64 = {
            let per = t.spec.snapshots_per_machine;
            let devs: Vec<f64> = t
                .utils
                .chunks(per)
                .flat_map(|c| {
                    let m = stats::mean(c);
                    c.iter().map(move |x| x - m).collect::<Vec<_>>()
                })
                .collect();
            crate::util::stats::stddev(&devs)
        };
        assert!(within < cross, "within={within} cross={cross}");
    }

    #[test]
    fn cdf_curve_monotone() {
        let t = ClusterTrace::synthesize(TraceSpec::small());
        let pts: Vec<f64> = (0..=20).map(|i| i as f64 / 20.0).collect();
        let curve = t.cdf_curve(&pts);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(curve.last().unwrap().1, 1.0);
    }

    #[test]
    fn default_spec_is_full_scale() {
        let spec = TraceSpec::default();
        assert_eq!(spec.machines, 1_800);
        assert!((spec.total_snapshots() as i64 - 959_080).abs() < 1_000);
    }
}
