//! Peer-availability monitoring (§3.1: "The Harvest runtime monitors peer
//! memory availability").
//!
//! [`PeerMonitor`] maintains, per cache tier, the statistics placement
//! policies consult: instantaneous harvestable bytes, largest
//! allocatable segment, recent tenant *churn* (how often / how much
//! co-tenant usage moved — the stability policy's signal), and recent
//! link bandwidth demand (the interference policy's signal). Traffic is
//! tracked per tier slot — one per GPU, plus host DRAM, CXL, and the SSD
//! cold tier — so the
//! unified tier placement
//! ([`crate::harvest::policy::PlacementPolicy::place_tiered`]) sees
//! host/CXL link pressure exactly like peer link pressure, with the
//! demand/prefetch attribution split preserved on every slot.

use super::api::MemoryTier;
use crate::memsim::{Ns, SimNode};
use std::collections::VecDeque;

/// Snapshot of one peer GPU as seen by placement policies.
#[derive(Debug, Clone, Copy)]
pub struct PeerView {
    pub device: usize,
    /// Bytes harvestable right now (capacity − tenant − our allocations),
    /// clamped to the MIG partition if one is configured.
    pub harvestable: u64,
    /// Largest contiguous free segment in our arena view.
    pub largest_free: u64,
    /// Tenant churn rate over the sliding window: mean absolute usage
    /// change per second, as a fraction of capacity (0 = placid peer).
    pub churn_per_sec: f64,
    /// Bytes/sec recently moved over links touching this device.
    pub bw_demand: f64,
    /// Bytes this monitor's owner already holds on the device, per the
    /// fairness accounting.
    pub our_bytes: u64,
}

/// Sliding-window churn/bandwidth tracker. Slot layout: `0..n_gpus` are
/// the GPUs, then host DRAM, then CXL, then SSD.
#[derive(Debug, Clone)]
pub struct PeerMonitor {
    window: Ns,
    n_gpus: usize,
    /// Per slot: (time, |usage delta| in bytes) events (GPU slots only —
    /// host/CXL carry no co-tenant timeline).
    churn_events: Vec<VecDeque<(Ns, u64)>>,
    /// Per slot: (time, bytes transferred) events.
    bw_events: Vec<VecDeque<(Ns, u64)>>,
    last_seen_used: Vec<u64>,
    /// Cumulative bytes of *demand* traffic per slot (critical-path
    /// populates/fetches/migrations).
    demand_bytes: Vec<u64>,
    /// Cumulative bytes of *background prefetch* traffic per slot.
    /// Prefetch traffic still lands in `bw_events` — the interference
    /// policy must see total link pressure either way — but the split
    /// lets metrics attribute hit/waste bandwidth to the prefetch
    /// pipeline.
    prefetch_bytes: Vec<u64>,
}

impl PeerMonitor {
    pub fn new(n_gpus: usize, window: Ns) -> Self {
        let slots = n_gpus + 3; // + host, + cxl, + ssd
        Self {
            window,
            n_gpus,
            churn_events: vec![VecDeque::new(); slots],
            bw_events: vec![VecDeque::new(); slots],
            last_seen_used: vec![0; slots],
            demand_bytes: vec![0; slots],
            prefetch_bytes: vec![0; slots],
        }
    }

    fn slot(&self, tier: MemoryTier) -> usize {
        match tier {
            MemoryTier::PeerHbm(g) => g,
            MemoryTier::Host => self.n_gpus,
            MemoryTier::CxlMem => self.n_gpus + 1,
            MemoryTier::Ssd => self.n_gpus + 2,
            MemoryTier::LocalHbm => unreachable!("local HBM traffic is not harvest traffic"),
        }
    }

    /// Observe the current tenant usage on all devices (called by the
    /// controller whenever virtual time advances past tenant events).
    pub fn observe(&mut self, node: &SimNode) {
        let now = node.clock.now();
        for (i, gpu) in node.gpus.iter().enumerate() {
            // Timeline *and* actor-held segments: closed-loop tenant
            // allocation churn feeds the stability signal exactly like
            // replayed timeline churn.
            let used = gpu.tenant_used_at(now);
            let prev = self.last_seen_used[i];
            if used != prev {
                let delta = used.abs_diff(prev);
                self.churn_events[i].push_back((now, delta));
                self.last_seen_used[i] = used;
            }
            Self::expire(&mut self.churn_events[i], now, self.window);
        }
        for q in &mut self.bw_events {
            Self::expire(q, now, self.window);
        }
    }

    /// Record demand link traffic touching peer GPU `device` (for
    /// interference scoring).
    pub fn record_transfer(&mut self, device: usize, at: Ns, bytes: u64) {
        self.record_tier_transfer(MemoryTier::PeerHbm(device), at, bytes);
    }

    /// Record background *prefetch* traffic touching peer GPU `device`.
    pub fn record_prefetch_transfer(&mut self, device: usize, at: Ns, bytes: u64) {
        self.record_tier_prefetch(MemoryTier::PeerHbm(device), at, bytes);
    }

    /// Record demand link traffic touching `tier`. Counted in the
    /// sliding bandwidth window the interference policy consults.
    pub fn record_tier_transfer(&mut self, tier: MemoryTier, at: Ns, bytes: u64) {
        let s = self.slot(tier);
        self.bw_events[s].push_back((at, bytes));
        self.demand_bytes[s] += bytes;
    }

    /// Record background *prefetch* traffic touching `tier`. Counted in
    /// the same sliding bandwidth window as demand traffic (interference
    /// policies must steer away from links our own prefetches saturate
    /// too), but attributed separately in the cumulative counters.
    pub fn record_tier_prefetch(&mut self, tier: MemoryTier, at: Ns, bytes: u64) {
        let s = self.slot(tier);
        self.bw_events[s].push_back((at, bytes));
        self.prefetch_bytes[s] += bytes;
    }

    /// Cumulative demand bytes recorded against peer GPU `device`.
    pub fn demand_bytes_on(&self, device: usize) -> u64 {
        self.demand_bytes[device]
    }

    /// Cumulative prefetch bytes recorded against peer GPU `device`.
    pub fn prefetch_bytes_on(&self, device: usize) -> u64 {
        self.prefetch_bytes[device]
    }

    /// Cumulative demand bytes recorded against `tier`.
    pub fn demand_bytes_on_tier(&self, tier: MemoryTier) -> u64 {
        self.demand_bytes[self.slot(tier)]
    }

    /// Cumulative prefetch bytes recorded against `tier`.
    pub fn prefetch_bytes_on_tier(&self, tier: MemoryTier) -> u64 {
        self.prefetch_bytes[self.slot(tier)]
    }

    /// Recent bytes/sec moved over links touching `tier` (demand +
    /// prefetch) — the interference signal the tier cost model consults.
    pub fn bw_demand_on_tier(&self, tier: MemoryTier) -> f64 {
        Self::rate_per_sec(&self.bw_events[self.slot(tier)], self.window)
    }

    fn expire(q: &mut VecDeque<(Ns, u64)>, now: Ns, window: Ns) {
        while let Some(&(t, _)) = q.front() {
            if t + window < now {
                q.pop_front();
            } else {
                break;
            }
        }
    }

    fn rate_per_sec(q: &VecDeque<(Ns, u64)>, window: Ns) -> f64 {
        let total: u64 = q.iter().map(|&(_, b)| b).sum();
        total as f64 / (window as f64 / 1e9)
    }

    /// Build the policy view. `partition_limit[i]` caps the harvestable
    /// report (MIG); `our_bytes[i]` is the fairness ledger.
    pub fn views(
        &self,
        node: &SimNode,
        partition_limit: &[Option<u64>],
        our_bytes: &[u64],
    ) -> Vec<PeerView> {
        (0..node.n_gpus())
            .map(|i| {
                let cap = node.gpus[i].hbm.capacity();
                let mut harvestable = node.harvestable_now(i);
                if let Some(limit) = partition_limit[i] {
                    // The MIG partition caps *harvest* bytes; tenant
                    // actors' arena segments don't count against it.
                    let harvest_used =
                        node.gpus[i].hbm.used().saturating_sub(node.gpus[i].tenant_held);
                    harvestable = harvestable.min(limit.saturating_sub(harvest_used));
                }
                PeerView {
                    device: i,
                    harvestable,
                    largest_free: node.gpus[i].hbm.largest_free().min(harvestable),
                    churn_per_sec: Self::rate_per_sec(&self.churn_events[i], self.window)
                        / cap.max(1) as f64,
                    bw_demand: Self::rate_per_sec(&self.bw_events[i], self.window),
                    our_bytes: our_bytes[i],
                }
            })
            .collect()
    }

    pub fn last_observed_tenant_used(&self, device: usize) -> u64 {
        self.last_seen_used[device]
    }

    /// Register the cumulative per-tier demand/prefetch traffic split
    /// into the unified metrics registry under `prefix` (e.g.
    /// `"harvest.tiers"`). Peer traffic is the sum over GPU slots;
    /// host/CXL/SSD report their own slots.
    pub fn register(&self, reg: &mut crate::obs::MetricsRegistry, prefix: &str) {
        let gpu_sum = |v: &[u64]| -> u64 { v[..self.n_gpus].iter().sum() };
        let tiers: [(&str, usize); 3] = [
            ("host", self.n_gpus),
            ("cxl", self.n_gpus + 1),
            ("ssd", self.n_gpus + 2),
        ];
        reg.counter(&format!("{prefix}.peer.demand_bytes"), gpu_sum(&self.demand_bytes));
        reg.counter(&format!("{prefix}.peer.prefetch_bytes"), gpu_sum(&self.prefetch_bytes));
        for (name, slot) in tiers {
            reg.counter(&format!("{prefix}.{name}.demand_bytes"), self.demand_bytes[slot]);
            reg.counter(&format!("{prefix}.{name}.prefetch_bytes"), self.prefetch_bytes[slot]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::{NodeSpec, SimNode};

    const GIB: u64 = 1 << 30;

    #[test]
    fn views_report_harvestable_and_partition_cap() {
        let mut node = SimNode::new(NodeSpec::default());
        node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 20 * GIB));
        let mon = PeerMonitor::new(2, 1_000_000_000);
        let views = mon.views(&node, &[None, Some(10 * GIB)], &[0, 0]);
        assert_eq!(views[1].harvestable, 10 * GIB, "MIG partition caps harvest");
        let views = mon.views(&node, &[None, None], &[0, 0]);
        assert_eq!(views[1].harvestable, 60 * GIB);
    }

    #[test]
    fn churn_rate_reflects_tenant_changes() {
        let mut node = SimNode::new(NodeSpec::default());
        node.set_tenant_load(
            1,
            TenantLoad::from_steps(
                80 * GIB,
                vec![(0, 0), (100_000_000, 8 * GIB), (200_000_000, 0)],
            ),
        );
        let mut mon = PeerMonitor::new(2, 1_000_000_000);
        mon.observe(&node);
        node.clock.advance_to(100_000_000);
        mon.observe(&node);
        node.clock.advance_to(200_000_000);
        mon.observe(&node);
        let views = mon.views(&node, &[None, None], &[0, 0]);
        assert!(views[1].churn_per_sec > 0.0);
        assert_eq!(views[0].churn_per_sec, 0.0, "placid peer has zero churn");
    }

    #[test]
    fn churn_events_expire_out_of_window() {
        let mut node = SimNode::new(NodeSpec::default());
        node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000, 8 * GIB)]),
        );
        let mut mon = PeerMonitor::new(2, 1_000_000); // 1 ms window
        node.clock.advance_to(1_000);
        mon.observe(&node);
        let v = mon.views(&node, &[None, None], &[0, 0]);
        assert!(v[1].churn_per_sec > 0.0);
        node.clock.advance_to(10_000_000); // 10 ms later
        mon.observe(&node);
        let v = mon.views(&node, &[None, None], &[0, 0]);
        assert_eq!(v[1].churn_per_sec, 0.0, "old churn expired");
    }

    #[test]
    fn bw_demand_tracks_recorded_transfers() {
        let node = SimNode::new(NodeSpec::default());
        let mut mon = PeerMonitor::new(2, 1_000_000_000);
        mon.record_transfer(0, 0, 500_000_000);
        let v = mon.views(&node, &[None, None], &[0, 0]);
        assert!((v[0].bw_demand - 0.5e9).abs() < 1.0);
        assert_eq!(v[1].bw_demand, 0.0);
    }

    #[test]
    fn prefetch_traffic_split_but_visible_to_interference_signal() {
        let node = SimNode::new(NodeSpec::default());
        let mut mon = PeerMonitor::new(2, 1_000_000_000);
        mon.record_transfer(1, 0, 100);
        mon.record_prefetch_transfer(1, 0, 400);
        // attribution is split...
        assert_eq!(mon.demand_bytes_on(1), 100);
        assert_eq!(mon.prefetch_bytes_on(1), 400);
        assert_eq!(mon.demand_bytes_on(0), 0);
        // ...but the policy-facing bandwidth signal sees the sum
        let v = mon.views(&node, &[None, None], &[0, 0]);
        assert!((v[1].bw_demand - 500.0).abs() < 1.0);
    }

    #[test]
    fn host_and_cxl_slots_track_independently() {
        let mut mon = PeerMonitor::new(2, 1_000_000_000);
        mon.record_tier_transfer(MemoryTier::Host, 0, 1_000);
        mon.record_tier_prefetch(MemoryTier::Host, 0, 500);
        mon.record_tier_transfer(MemoryTier::CxlMem, 0, 7_000);
        mon.record_tier_transfer(MemoryTier::Ssd, 0, 3_000);
        // demand/prefetch split preserved on the host slot
        assert_eq!(mon.demand_bytes_on_tier(MemoryTier::Host), 1_000);
        assert_eq!(mon.prefetch_bytes_on_tier(MemoryTier::Host), 500);
        assert_eq!(mon.demand_bytes_on_tier(MemoryTier::CxlMem), 7_000);
        assert_eq!(mon.demand_bytes_on_tier(MemoryTier::Ssd), 3_000);
        assert!((mon.bw_demand_on_tier(MemoryTier::Ssd) - 3_000.0).abs() < 1.0);
        // gpu slots untouched
        assert_eq!(mon.demand_bytes_on(0) + mon.demand_bytes_on(1), 0);
        // tier bandwidth signal sums demand + prefetch
        assert!((mon.bw_demand_on_tier(MemoryTier::Host) - 1_500.0).abs() < 1.0);
        assert!((mon.bw_demand_on_tier(MemoryTier::CxlMem) - 7_000.0).abs() < 1.0);
    }

    #[test]
    fn register_reports_per_tier_traffic_split() {
        use crate::obs::{Metric, MetricsRegistry};
        let mut mon = PeerMonitor::new(2, 1_000_000_000);
        mon.record_transfer(0, 0, 100);
        mon.record_prefetch_transfer(1, 0, 400);
        mon.record_tier_transfer(MemoryTier::Host, 0, 1_000);
        mon.record_tier_prefetch(MemoryTier::Ssd, 0, 3_000);
        let mut reg = MetricsRegistry::new();
        mon.register(&mut reg, "tiers");
        assert_eq!(reg.get("tiers.peer.demand_bytes"), Some(&Metric::Counter(100)));
        assert_eq!(reg.get("tiers.peer.prefetch_bytes"), Some(&Metric::Counter(400)));
        assert_eq!(reg.get("tiers.host.demand_bytes"), Some(&Metric::Counter(1_000)));
        assert_eq!(reg.get("tiers.ssd.prefetch_bytes"), Some(&Metric::Counter(3_000)));
        assert_eq!(reg.get("tiers.cxl.demand_bytes"), Some(&Metric::Counter(0)));
    }
}
