//! MIG-style isolation (§3.2 "Isolation with MIG").
//!
//! On real hardware Harvest reserves one MIG instance per peer GPU as the
//! cache device so harvested allocations cannot thrash co-tenants. Here
//! the partition is a per-GPU byte budget the controller refuses to
//! exceed, plus an "external reclaim" switch that models an operator
//! shrinking/destroying the instance for a higher-priority workload
//! (which revokes everything inside it). §3.2 also notes some driver
//! configurations restrict P2P for MIG devices — modelled as a deployment
//! flag that disables harvesting on the device entirely.

/// Per-GPU partition configuration.
// serde is not in the offline crate set; the derive activates once a
// vendored copy is added behind the `serde` feature.
#[cfg_attr(feature = "serde", derive(serde::Deserialize))]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigConfig {
    /// No MIG: harvest may use all tenant-free HBM (the paper treats MIG
    /// as a deployment choice, not a functional requirement).
    Disabled,
    /// A reserved cache instance of this many bytes.
    CachePartition { bytes: u64 },
    /// Driver configuration forbids cross-GPU P2P with MIG on — the
    /// device cannot be harvested at all.
    P2pRestricted,
}

impl Default for MigConfig {
    fn default() -> Self {
        MigConfig::Disabled
    }
}

impl MigConfig {
    /// The harvestable-byte cap this partition imposes (`None` = no cap).
    pub fn harvest_limit(&self) -> Option<u64> {
        match self {
            MigConfig::Disabled => None,
            MigConfig::CachePartition { bytes } => Some(*bytes),
            MigConfig::P2pRestricted => Some(0),
        }
    }

    pub fn allows_harvest(&self) -> bool {
        !matches!(self, MigConfig::P2pRestricted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn limits() {
        assert_eq!(MigConfig::Disabled.harvest_limit(), None);
        assert_eq!(MigConfig::CachePartition { bytes: 7 }.harvest_limit(), Some(7));
        assert_eq!(MigConfig::P2pRestricted.harvest_limit(), Some(0));
        assert!(MigConfig::Disabled.allows_harvest());
        assert!(!MigConfig::P2pRestricted.allows_harvest());
    }
}
