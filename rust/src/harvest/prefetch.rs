//! Deadline-aware prefetch planning — the §5 transfer-pipeline idea
//! turned into a subsystem.
//!
//! Harvest's speedup comes from hiding data movement behind compute, but
//! a reload issued *at the moment of use* still lands its latency on the
//! decode critical path. The prefetch pipeline closes that gap:
//!
//! 1. A predictor names what decode will need next — the scheduler's
//!    [`crate::server::scheduler::Scheduler::lookahead`] for KV blocks,
//!    the router's [`crate::moe::router::RouterSim::predict_activations`]
//!    for expert weights.
//! 2. The consumer (the KV manager's
//!    [`crate::kv::manager::KvOffloadManager::plan_prefetch`] /
//!    [`crate::kv::manager::KvOffloadManager::submit_prefetch`], the
//!    rebalancer's
//!    [`crate::moe::rebalancer::ExpertRebalancer::prefetch_experts`])
//!    turns the prediction into concrete background transfers, each with
//!    a **deadline**: the virtual time by which the data must be resident
//!    (typically the start of the next decode step or layer).
//! 3. The [`PrefetchPlanner`] performs admission control against the
//!    simulated interconnect: a background transfer is issued only when
//!    the link carries no queued *demand* traffic and
//!    [`crate::memsim::Topology::earliest_completion`] (plus a safety
//!    slack) meets the deadline. Prefetch traffic therefore never delays
//!    a demand fetch — it either rides an idle window or yields.
//! 4. Issued transfers are submitted through the
//!    [`crate::harvest::session::Transfer`] builder in *background* mode:
//!    recorded as prefetch bandwidth in the
//!    [`crate::harvest::monitor::PeerMonitor`], and still covered by the
//!    §3.2 drain-before-free barrier (their lease tags are kept, so a
//!    revocation never frees bytes under an in-flight copy). Consumers
//!    keep that barrier off the hot path by deferring lease release
//!    until the background copy has completed. A prefetch invalidated
//!    before use is wasted bandwidth, never a correctness bug.
//!
//! The planner also keeps the outcome ledger: **hits** (prefetched and
//! consumed on time), **late** (consumed before the background copy
//! finished — a partial stall), and **wasted** (revoked, preempted or
//! evicted before use).
//!
//! # Example
//!
//! ```
//! use harvest::harvest::prefetch::{PrefetchConfig, PrefetchPlanner};
//! use harvest::memsim::{DeviceId, NodeSpec, SimNode};
//!
//! let node = SimNode::new(NodeSpec::h100x2());
//! let mut planner = PrefetchPlanner::new(PrefetchConfig::default());
//! let (src, dst) = (DeviceId::Gpu(1), DeviceId::Gpu(0));
//!
//! // An idle NVLink and a comfortable deadline: admitted.
//! assert!(planner.admit(&node.topo, src, dst, 1 << 20, None, 1_000_000));
//! planner.record_issued(7, 1 << 20, 40_000, 1_000_000);
//!
//! // Consumed after the copy finished: a hit.
//! assert!(planner.mark_used(7, 50_000));
//! assert_eq!(planner.stats().hits, 1);
//!
//! // An impossible deadline yields instead of queueing.
//! assert!(!planner.admit(&node.topo, src, dst, 1 << 30, None, 10));
//! assert_eq!(planner.stats().yielded, 1);
//! ```

use crate::memsim::{DeviceId, Ns, Topology};
use crate::obs::trace::{self, Subsystem};
use std::collections::BTreeMap;

/// Tuning knobs for the prefetch pipeline.
#[derive(Debug, Clone, Copy)]
pub struct PrefetchConfig {
    /// How many future decode steps the scheduler lookahead covers.
    pub horizon: usize,
    /// Cap on concurrently tracked in-flight prefetches.
    pub max_inflight: usize,
    /// Safety margin: an admitted transfer must complete this long
    /// before its deadline (absorbs estimate error on real hardware;
    /// the simulator's estimates are exact, so the default is 0).
    pub slack_ns: Ns,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        Self { horizon: 2, max_inflight: 256, slack_ns: 0 }
    }
}

/// Outcome ledger of the prefetch pipeline. `planned` counts admission
/// attempts; every attempt ends as exactly one of `issued` or `yielded`,
/// and every issue eventually resolves as a hit, a late arrival, or
/// waste.
#[derive(Debug, Clone, Default)]
pub struct PrefetchStats {
    /// Admission-control consultations.
    pub planned: u64,
    /// Background transfers actually issued.
    pub issued: u64,
    /// Skipped by admission control (busy link / unmeetable deadline /
    /// in-flight cap).
    pub yielded: u64,
    /// Entries skipped at submit without any transfer: invalidated
    /// between plan and submit (a revocation raced in), or not yet
    /// fetchable (the copy that would be read is still being written).
    pub stale_plans: u64,
    /// Prefetched data consumed after its background copy completed:
    /// the reload left the critical path entirely.
    pub hits: u64,
    /// Prefetched data consumed while the copy was still in flight —
    /// a shortened, but not eliminated, stall.
    pub late: u64,
    /// Prefetched data invalidated before use (revocation, preemption,
    /// eviction): wasted bandwidth, never a correctness hazard.
    pub wasted: u64,
    /// Total bytes moved by issued prefetches.
    pub bytes_prefetched: u64,
    /// Bytes of prefetched data that were wasted.
    pub bytes_wasted: u64,
}

impl PrefetchStats {
    /// Fraction of issued prefetches that were consumed on time.
    pub fn hit_rate(&self) -> f64 {
        if self.issued == 0 {
            0.0
        } else {
            self.hits as f64 / self.issued as f64
        }
    }

    /// Fraction of issued prefetches whose bytes were wasted.
    pub fn waste_rate(&self) -> f64 {
        if self.bytes_prefetched == 0 {
            0.0
        } else {
            self.bytes_wasted as f64 / self.bytes_prefetched as f64
        }
    }

    /// Register the outcome ledger into the unified metrics registry
    /// under `prefix` (e.g. `"serve.prefetch"`).
    pub fn register(&self, reg: &mut crate::obs::MetricsRegistry, prefix: &str) {
        reg.counter(&format!("{prefix}.planned"), self.planned);
        reg.counter(&format!("{prefix}.issued"), self.issued);
        reg.counter(&format!("{prefix}.yielded"), self.yielded);
        reg.counter(&format!("{prefix}.stale_plans"), self.stale_plans);
        reg.counter(&format!("{prefix}.hits"), self.hits);
        reg.counter(&format!("{prefix}.late"), self.late);
        reg.counter(&format!("{prefix}.wasted"), self.wasted);
        reg.counter(&format!("{prefix}.bytes_prefetched"), self.bytes_prefetched);
        reg.counter(&format!("{prefix}.bytes_wasted"), self.bytes_wasted);
        reg.gauge(&format!("{prefix}.hit_rate"), self.hit_rate());
        reg.gauge(&format!("{prefix}.waste_rate"), self.waste_rate());
    }
}

/// One issued-and-unresolved prefetch.
#[derive(Debug, Clone, Copy)]
struct Inflight {
    ready_at: Ns,
    bytes: u64,
}

/// Deadline-aware admission control + outcome accounting for background
/// transfers. One planner instance per consumer (the KV manager and the
/// expert rebalancer each own one); keys are consumer-chosen `u64`s
/// (block ids, lease ids).
#[derive(Debug)]
pub struct PrefetchPlanner {
    cfg: PrefetchConfig,
    stats: PrefetchStats,
    inflight: BTreeMap<u64, Inflight>,
    /// Per directed link: the horizon up to which the queue is *our own*
    /// prefetch traffic. Admission distinguishes "busy with demand"
    /// (always yield) from "busy with earlier prefetches of this same
    /// batch" (fine, as long as the whole queue still meets the
    /// deadline).
    issued_until: BTreeMap<(DeviceId, DeviceId), Ns>,
}

impl PrefetchPlanner {
    pub fn new(cfg: PrefetchConfig) -> Self {
        Self {
            cfg,
            stats: PrefetchStats::default(),
            inflight: BTreeMap::new(),
            issued_until: BTreeMap::new(),
        }
    }

    pub fn cfg(&self) -> &PrefetchConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &PrefetchStats {
        &self.stats
    }

    /// Issued prefetches not yet resolved as hit/late/wasted.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// Whether `key` has an issued, unresolved prefetch.
    pub fn is_inflight(&self, key: u64) -> bool {
        self.inflight.contains_key(&key)
    }

    /// Admission control for one background transfer of `bytes` over
    /// (src → dst), needed by `deadline`. `chunk` must match how the
    /// transfer will actually be issued: `Some(descriptor_bytes)` for a
    /// scattered [`crate::harvest::session::Transfer::chunked`] copy
    /// (which pays per-chunk overheads the contiguous estimate would
    /// undershoot — and an under-estimated prefetch could occupy the
    /// link past its deadline, delaying demand), `None` for a
    /// contiguous one. Returns `false` (counting a yield) when:
    ///
    /// * too many prefetches are already in flight,
    /// * the link is busy with traffic we did not issue — queued demand
    ///   transfers must never wait behind a prefetch, or
    /// * the transfer cannot complete `slack_ns` before the deadline
    ///   (issuing it would occupy the link past the moment demand
    ///   traffic may arrive).
    ///
    /// Contract: callers must pick `deadline` no later than the next
    /// instant demand traffic can appear on this link (the next decode
    /// step / layer boundary); completion-before-deadline is what makes
    /// "prefetch never delays demand" hold.
    pub fn admit(
        &mut self,
        topo: &Topology,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        chunk: Option<u64>,
        deadline: Ns,
    ) -> bool {
        self.stats.planned += 1;
        let now = topo.clock().now();
        if self.inflight.len() >= self.cfg.max_inflight {
            self.stats.yielded += 1;
            trace::instant(Subsystem::Prefetch, "yield_inflight_cap", now, &[("bytes", bytes)]);
            return false;
        }
        let own = self.issued_until.get(&(src, dst)).copied().unwrap_or(0);
        if topo.busy_until(src, dst) > now.max(own) {
            // Someone else's traffic is queued: yield to it.
            self.stats.yielded += 1;
            trace::instant(Subsystem::Prefetch, "yield_link_busy", now, &[("bytes", bytes)]);
            return false;
        }
        let done = match chunk {
            // The builder only scatters when the payload exceeds the
            // descriptor size; mirror that here.
            Some(c) if bytes > c => topo.earliest_completion_scattered(src, dst, bytes, c),
            _ => topo.earliest_completion(src, dst, bytes),
        };
        match done {
            Some(done) if done.saturating_add(self.cfg.slack_ns) <= deadline => {
                trace::instant(
                    Subsystem::Prefetch,
                    "plan",
                    now,
                    &[("bytes", bytes), ("deadline", deadline), ("eta", done)],
                );
                true
            }
            _ => {
                self.stats.yielded += 1;
                trace::instant(Subsystem::Prefetch, "yield_deadline", now, &[("bytes", bytes)]);
                false
            }
        }
    }

    /// A transfer admitted by [`PrefetchPlanner::admit`] was issued;
    /// `ready_at` is its completion time on the simulated link. Pair
    /// with [`PrefetchPlanner::mark_link_busy`] so later admits in the
    /// same batch can tell the queue apart from demand traffic.
    pub fn record_issued(&mut self, key: u64, bytes: u64, ready_at: Ns, deadline: Ns) {
        // `ready_at` may exceed the admission estimate (scattered copies
        // pay per-chunk overheads the contiguous estimate ignores); the
        // late-arrival accounting in `mark_used` absorbs the error.
        let _ = deadline;
        self.stats.issued += 1;
        self.stats.bytes_prefetched += bytes;
        trace::instant_now(
            Subsystem::Prefetch,
            "issued",
            &[("key", key), ("bytes", bytes), ("ready_at", ready_at)],
        );
        self.inflight.insert(key, Inflight { ready_at, bytes });
    }

    /// Extend the own-traffic horizon on (src → dst) to `until`. Called
    /// together with [`PrefetchPlanner::record_issued`] so later admits
    /// in the same batch recognize the queue as prefetch traffic rather
    /// than demand.
    pub fn mark_link_busy(&mut self, src: DeviceId, dst: DeviceId, until: Ns) {
        let e = self.issued_until.entry((src, dst)).or_insert(0);
        *e = (*e).max(until);
    }

    /// The prefetched object under `key` was consumed at `now`. Returns
    /// whether it arrived on time (`true` → hit, `false` → late).
    /// Unknown keys (never prefetched, or already resolved) count as
    /// on-time and touch no counters.
    pub fn mark_used(&mut self, key: u64, now: Ns) -> bool {
        let Some(fl) = self.inflight.remove(&key) else { return true };
        if fl.ready_at <= now {
            self.stats.hits += 1;
            trace::instant(Subsystem::Prefetch, "hit", now, &[("key", key)]);
            true
        } else {
            self.stats.late += 1;
            trace::instant(
                Subsystem::Prefetch,
                "late",
                now,
                &[("key", key), ("ready_at", fl.ready_at)],
            );
            false
        }
    }

    /// The prefetched object under `key` was invalidated before use
    /// (revocation, scheduler preemption, eviction). No-op for unknown
    /// keys.
    pub fn mark_canceled(&mut self, key: u64) {
        if let Some(fl) = self.inflight.remove(&key) {
            self.stats.wasted += 1;
            self.stats.bytes_wasted += fl.bytes;
            trace::instant_now(
                Subsystem::Prefetch,
                "wasted",
                &[("key", key), ("bytes", fl.bytes)],
            );
        }
    }

    /// A planned entry went stale between plan and submit (the lease it
    /// named was revoked, the block moved). Nothing was issued; nothing
    /// can be read — the entry is simply dropped.
    pub fn mark_stale_plan(&mut self) {
        self.stats.stale_plans += 1;
        trace::instant_now(Subsystem::Prefetch, "stale_plan", &[]);
    }

    /// Cancel every in-flight prefetch (e.g. the consumer is shutting
    /// down or the working set was invalidated wholesale).
    pub fn cancel_all(&mut self) {
        let keys: Vec<u64> = self.inflight.keys().copied().collect();
        for k in keys {
            self.mark_canceled(k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{NodeSpec, SimNode};

    const MIB: u64 = 1 << 20;

    fn node() -> SimNode {
        SimNode::new(NodeSpec::h100x2())
    }

    fn planner() -> PrefetchPlanner {
        PrefetchPlanner::new(PrefetchConfig::default())
    }

    #[test]
    fn admits_on_idle_link_with_room_to_deadline() {
        let node = node();
        let mut p = planner();
        let est = node
            .topo
            .earliest_completion(DeviceId::Gpu(1), DeviceId::Gpu(0), MIB)
            .unwrap();
        assert!(p.admit(&node.topo, DeviceId::Gpu(1), DeviceId::Gpu(0), MIB, None, est));
        assert!(
            !p.admit(&node.topo, DeviceId::Gpu(1), DeviceId::Gpu(0), MIB, None, est - 1),
            "one ns short of the completion estimate must yield"
        );
        assert_eq!(p.stats().planned, 2);
        assert_eq!(p.stats().yielded, 1);
    }

    #[test]
    fn yields_to_queued_demand_traffic() {
        let mut node = node();
        // demand transfer occupies the link
        let ev = node.copy(DeviceId::Gpu(1), DeviceId::Gpu(0), 64 * MIB, None);
        assert!(ev.end > node.clock.now());
        let mut p = planner();
        assert!(
            !p.admit(&node.topo, DeviceId::Gpu(1), DeviceId::Gpu(0), MIB, None, u64::MAX),
            "prefetch must never queue behind demand traffic"
        );
        assert_eq!(p.stats().yielded, 1);
        // the reverse link is untouched and admissible
        assert!(p.admit(&node.topo, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, None, u64::MAX));
    }

    #[test]
    fn own_batch_may_queue_behind_itself_until_deadline() {
        let mut node = node();
        let mut p = planner();
        let (src, dst) = (DeviceId::Gpu(1), DeviceId::Gpu(0));
        let deadline = 500_000; // 0.5 ms: room for a dozen-ish 4 MiB copies
        let mut issued = 0;
        for key in 0..64u64 {
            if !p.admit(&node.topo, src, dst, 4 * MIB, None, deadline) {
                break;
            }
            let ev = node.copy(src, dst, 4 * MIB, None);
            p.record_issued(key, 4 * MIB, ev.end, deadline);
            p.mark_link_busy(src, dst, ev.end);
            assert!(ev.end <= deadline, "admitted transfer violates deadline");
            issued += 1;
        }
        assert!(issued > 1, "a batch must be able to queue behind itself");
        assert!(
            p.stats().yielded > 0 || issued == 64,
            "eventually the deadline caps the batch"
        );
        // everything issued completes before the deadline: demand traffic
        // arriving at the deadline is not delayed.
        assert!(node.topo.busy_until(src, dst) <= deadline);
    }

    #[test]
    fn inflight_cap_yields() {
        let node = node();
        let mut p = PrefetchPlanner::new(PrefetchConfig { max_inflight: 1, ..Default::default() });
        assert!(p.admit(&node.topo, DeviceId::Gpu(1), DeviceId::Gpu(0), MIB, None, u64::MAX));
        p.record_issued(1, MIB, 100, u64::MAX);
        assert_eq!(p.in_flight(), 1);
        assert!(!p.admit(&node.topo, DeviceId::Gpu(1), DeviceId::Gpu(0), MIB, None, u64::MAX));
        p.mark_used(1, 200);
        assert!(p.admit(&node.topo, DeviceId::Gpu(1), DeviceId::Gpu(0), MIB, None, u64::MAX));
    }

    #[test]
    fn outcome_ledger_hits_late_waste() {
        let mut p = planner();
        p.record_issued(1, MIB, 1_000, 2_000);
        p.record_issued(2, MIB, 1_000, 2_000);
        p.record_issued(3, 2 * MIB, 1_000, 2_000);
        assert!(p.mark_used(1, 1_500), "arrived before use: hit");
        assert!(!p.mark_used(2, 500), "used before arrival: late");
        p.mark_canceled(3);
        p.mark_canceled(3); // double cancel is a no-op
        let s = p.stats();
        assert_eq!((s.hits, s.late, s.wasted), (1, 1, 1));
        assert_eq!(s.bytes_prefetched, 4 * MIB);
        assert_eq!(s.bytes_wasted, 2 * MIB);
        assert!((p.stats().hit_rate() - 1.0 / 3.0).abs() < 1e-9);
        assert!((p.stats().waste_rate() - 0.5).abs() < 1e-9);
        assert_eq!(p.in_flight(), 0);
    }

    #[test]
    fn unknown_keys_are_benign() {
        let mut p = planner();
        assert!(p.mark_used(99, 0), "unknown key counts as on-time, touches nothing");
        p.mark_canceled(99);
        assert_eq!(p.stats().hits + p.stats().late + p.stats().wasted, 0);
    }

    #[test]
    fn cancel_all_flushes_inflight() {
        let mut p = planner();
        p.record_issued(1, MIB, 10, 100);
        p.record_issued(2, MIB, 10, 100);
        p.cancel_all();
        assert_eq!(p.in_flight(), 0);
        assert_eq!(p.stats().wasted, 2);
        assert_eq!(p.stats().bytes_wasted, 2 * MIB);
    }
}
