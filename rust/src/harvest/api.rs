//! Harvest API surface types (§3.2), lease edition.
//!
//! The paper's raw surface (`harvest_alloc` / `harvest_free` /
//! `harvest_register_cb`) is reproduced as deprecated shims on
//! [`crate::harvest::HarvestRuntime`]; the supported surface is the
//! lease-based one in [`crate::harvest::session`]. The types here are
//! shared by both: identifiers, hints, durability modes, revocation
//! reasons and errors.

use crate::memsim::hbm::AllocId;
use crate::memsim::Ns;

/// Opaque, never-reused identifier of a harvest lease (née "handle").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LeaseId(pub u64);

/// Alias for [`LeaseId`], kept so pre-lease call sites keep compiling
/// during the migration.
#[deprecated(note = "renamed to `LeaseId` — a handle is now the RAII \
                     `harvest::session::Lease`; the bare id only names it")]
pub type HandleId = LeaseId;

/// What happens to the cached object when its peer allocation is revoked
/// (§3.1: consistency is an application choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Durability {
    /// An authoritative copy lives in host DRAM; revocation falls back to
    /// it (the MoE expert-weights mode).
    #[default]
    HostBacked,
    /// The object is lost on revocation and reconstructed later (the KV
    /// cache mode — recompute or drop).
    Lossy,
}

/// Placement hints passed to allocation calls (§3.2 "hint constraints").
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocHints {
    /// The compute GPU this cache entry serves (locality policies place
    /// close to it; it is never selected as the peer).
    pub compute_gpu: Option<usize>,
    /// Pin to an explicit peer.
    pub prefer_peer: Option<usize>,
    /// Client identity for fairness accounting.
    pub client: Option<u32>,
    /// Durability mode (recorded on the lease; the runtime never tracks
    /// dirty state either way).
    pub durability: Durability,
}

/// The (device, pointer, size) tuple the paper's API returns, plus
/// bookkeeping metadata. This is the *raw* placement record; the RAII
/// owner of it is [`crate::harvest::session::Lease`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HarvestHandle {
    pub id: LeaseId,
    /// Peer GPU index holding the bytes.
    pub peer: usize,
    /// The device "pointer" (simulated: allocation id + byte offset).
    pub alloc: AllocId,
    pub offset: u64,
    pub size: u64,
    pub durability: Durability,
    pub client: Option<u32>,
}

/// Why a peer allocation disappeared (§3.2: allocator pressure,
/// policy-driven eviction, or external reclamation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RevocationReason {
    /// Co-tenant memory demand grew past the harvestable budget.
    TenantPressure,
    /// The controller's own policy evicted it (e.g. rebalancing).
    PolicyEviction,
    /// A higher-priority workload reclaimed the MIG partition.
    ExternalReclaim,
    /// Runtime shutdown.
    Shutdown,
}

/// A completed revocation, as recorded in the runtime log (and delivered
/// to the deprecated push callbacks). The pull-model equivalent handed
/// to sessions is [`crate::harvest::events::RevocationEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Revocation {
    pub handle: HarvestHandle,
    pub reason: RevocationReason,
    /// Virtual time at which the free completed (after DMA drain).
    pub at: Ns,
}

/// Errors from the allocation and transfer paths.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HarvestError {
    /// No peer currently has a segment that fits under the policy. For
    /// vectored allocations `requested` is the total batch size.
    NoCapacity { requested: u64 },
    /// The hints pinned a peer that cannot serve the request.
    PeerUnavailable { peer: usize },
    /// Unknown, revoked, or already-released lease.
    StaleLease(LeaseId),
    /// Zero-byte request (vectored: any zero-byte element).
    ZeroSize,
}

impl std::fmt::Display for HarvestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HarvestError::NoCapacity { requested } => {
                write!(f, "no peer capacity for {requested} bytes")
            }
            HarvestError::PeerUnavailable { peer } => {
                write!(f, "pinned peer gpu{peer} unavailable")
            }
            HarvestError::StaleLease(id) => write!(f, "stale lease {id:?}"),
            HarvestError::ZeroSize => write!(f, "zero-size harvest allocation"),
        }
    }
}

impl std::error::Error for HarvestError {}
