//! Harvest API surface types (§3.2).

use crate::memsim::hbm::AllocId;
use crate::memsim::Ns;

/// Opaque, never-reused identifier of a harvest allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct HandleId(pub u64);

/// What happens to the cached object when its peer allocation is revoked
/// (§3.1: consistency is an application choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Durability {
    /// An authoritative copy lives in host DRAM; revocation falls back to
    /// it (the MoE expert-weights mode).
    #[default]
    HostBacked,
    /// The object is lost on revocation and reconstructed later (the KV
    /// cache mode — recompute or drop).
    Lossy,
}

/// Placement hints passed to `harvest_alloc` (§3.2 "hint constraints").
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocHints {
    /// The compute GPU this cache entry serves (locality policies place
    /// close to it; it is never selected as the peer).
    pub compute_gpu: Option<usize>,
    /// Pin to an explicit peer.
    pub prefer_peer: Option<usize>,
    /// Client identity for fairness accounting.
    pub client: Option<u32>,
    /// Durability mode (recorded on the handle; the runtime never tracks
    /// dirty state either way).
    pub durability: Durability,
}

/// The (device, pointer, size) tuple the paper's API returns, plus
/// bookkeeping metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HarvestHandle {
    pub id: HandleId,
    /// Peer GPU index holding the bytes.
    pub peer: usize,
    /// The device "pointer" (simulated: allocation id + byte offset).
    pub alloc: AllocId,
    pub offset: u64,
    pub size: u64,
    pub durability: Durability,
    pub client: Option<u32>,
}

/// Why a peer allocation disappeared (§3.2: allocator pressure,
/// policy-driven eviction, or external reclamation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RevocationReason {
    /// Co-tenant memory demand grew past the harvestable budget.
    TenantPressure,
    /// The controller's own policy evicted it (e.g. rebalancing).
    PolicyEviction,
    /// A higher-priority workload reclaimed the MIG partition.
    ExternalReclaim,
    /// Runtime shutdown.
    Shutdown,
}

/// A completed revocation, as delivered to callbacks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Revocation {
    pub handle: HarvestHandle,
    pub reason: RevocationReason,
    /// Virtual time at which the free completed (after DMA drain).
    pub at: Ns,
}

/// Errors from the allocation path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HarvestError {
    /// No peer currently has a segment that fits under the policy.
    NoCapacity { requested: u64 },
    /// The hints pinned a peer that cannot serve the request.
    PeerUnavailable { peer: usize },
    /// Unknown or already-freed handle.
    StaleHandle(HandleId),
    /// Zero-byte request.
    ZeroSize,
}

impl std::fmt::Display for HarvestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HarvestError::NoCapacity { requested } => {
                write!(f, "no peer capacity for {requested} bytes")
            }
            HarvestError::PeerUnavailable { peer } => {
                write!(f, "pinned peer gpu{peer} unavailable")
            }
            HarvestError::StaleHandle(id) => write!(f, "stale handle {id:?}"),
            HarvestError::ZeroSize => write!(f, "zero-size harvest_alloc"),
        }
    }
}

impl std::error::Error for HarvestError {}
