//! Harvest API surface types (§3.2), tiered-lease edition.
//!
//! The paper's raw surface (`harvest_alloc` / `harvest_free` /
//! `harvest_register_cb`) is reproduced as deprecated shims on
//! [`crate::harvest::HarvestRuntime`]; the supported surface is the
//! lease-based one in [`crate::harvest::session`]. The types here are
//! shared by both: identifiers, memory tiers, tier preferences, hints,
//! durability modes, revocation reasons and errors.
//!
//! # Memory tiers
//!
//! Harvest's core claim is that peer GPU memory is *one tier* in a cache
//! hierarchy whose slow alternative is PCIe host offload. [`MemoryTier`]
//! makes the hierarchy explicit, and [`TierPreference`] lets every
//! allocation say which slice of it is acceptable — one placement
//! decision instead of N ad-hoc consumer paths:
//!
//! ```
//! use harvest::harvest::{MemoryTier, TierPreference};
//!
//! // Fast → slow: local HBM, peer HBM over NVLink, CXL-attached memory,
//! // host DRAM over PCIe, NVMe SSD behind the host bridge.
//! assert!(MemoryTier::PeerHbm(1).speed_rank() < MemoryTier::CxlMem.speed_rank());
//! assert!(MemoryTier::CxlMem.speed_rank() < MemoryTier::Host.speed_rank());
//! assert!(MemoryTier::Host.speed_rank() < MemoryTier::Ssd.speed_rank());
//!
//! // `FastestAvailable` admits every harvest tier; the placement policy
//! // scores them under one cost model.
//! assert!(TierPreference::FastestAvailable.allows(MemoryTier::PeerHbm(0)));
//! assert!(TierPreference::FastestAvailable.allows(MemoryTier::Host));
//!
//! // `AtLeast(tier)` bounds the *slowest* acceptable tier (tier class,
//! // not a specific device): at least CXL-speed excludes host DRAM and
//! // the SSD cold tier.
//! let pref = TierPreference::AtLeast(MemoryTier::CxlMem);
//! assert!(pref.allows(MemoryTier::PeerHbm(2)));
//! assert!(pref.allows(MemoryTier::CxlMem));
//! assert!(!pref.allows(MemoryTier::Host));
//! assert!(!pref.allows(MemoryTier::Ssd));
//!
//! // `PEER_ONLY` is the pre-tier API's semantics (peer HBM or nothing).
//! assert!(TierPreference::PEER_ONLY.allows(MemoryTier::PeerHbm(3)));
//! assert!(!TierPreference::PEER_ONLY.allows(MemoryTier::Host));
//!
//! // `Pinned` names one exact tier — for peers, one exact device.
//! let pinned = TierPreference::Pinned(MemoryTier::PeerHbm(1));
//! assert!(pinned.allows(MemoryTier::PeerHbm(1)));
//! assert!(!pinned.allows(MemoryTier::PeerHbm(2)));
//! ```

use crate::memsim::hbm::AllocId;
use crate::memsim::{DeviceId, Ns};

/// Opaque, never-reused identifier of a harvest lease (née "handle").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LeaseId(pub u64);

/// Alias for [`LeaseId`], kept so pre-lease call sites keep compiling
/// during the migration.
#[deprecated(note = "renamed to `LeaseId` — a handle is now the RAII \
                     `harvest::session::Lease`; the bare id only names it")]
pub type HandleId = LeaseId;

/// One tier of the cache hierarchy, fastest first. Every lease is
/// resident on exactly one tier at a time;
/// [`crate::harvest::session::Transfer::migrate`] moves it between
/// tiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MemoryTier {
    /// The compute GPU's own HBM. Consumers manage this pool themselves
    /// (the KV local pool, pinned experts); the harvest runtime never
    /// allocates here — the variant exists so residency and preferences
    /// can name the whole hierarchy.
    LocalHbm,
    /// Spare HBM on peer GPU `.0`, reached over NVLink — the paper's
    /// contribution tier. Revocable under co-tenant pressure.
    PeerHbm(usize),
    /// CXL-attached memory expander (§8): lower setup latency than the
    /// host-paging PCIe path, an intermediate tier between peer HBM and
    /// host DRAM. Absent unless the node is built with a CXL arena.
    CxlMem,
    /// Host DRAM over PCIe — the slow tier the paper's baselines page
    /// against. Effectively never revoked.
    Host,
    /// NVMe SSD arena behind the host bridge — the cold-tier ladder's
    /// capacity rung (effectively unbounded bytes at block-device
    /// speed). Only the host reaches it directly; GPU↔SSD traffic
    /// stages through host DRAM. Absent unless the node is built with
    /// an SSD arena ([`crate::memsim::NodeSpec::with_ssd`]).
    Ssd,
}

impl MemoryTier {
    /// Position in the fast→slow hierarchy (0 = fastest). All peers
    /// share one rank: tier *class*, not device identity.
    pub fn speed_rank(&self) -> u8 {
        match self {
            MemoryTier::LocalHbm => 0,
            MemoryTier::PeerHbm(_) => 1,
            MemoryTier::CxlMem => 2,
            MemoryTier::Host => 3,
            MemoryTier::Ssd => 4,
        }
    }

    /// The simulated device holding this tier's bytes. Local HBM is not
    /// a harvest-addressable device (leases never live there).
    pub fn device(&self) -> DeviceId {
        match self {
            MemoryTier::PeerHbm(g) => DeviceId::Gpu(*g),
            MemoryTier::CxlMem => DeviceId::Cxl,
            MemoryTier::Host => DeviceId::Host,
            MemoryTier::Ssd => DeviceId::Ssd,
            MemoryTier::LocalHbm => {
                unreachable!("local HBM is not a harvest-addressable device")
            }
        }
    }

    /// The peer GPU index, when this tier is peer HBM.
    pub fn peer_gpu(&self) -> Option<usize> {
        match self {
            MemoryTier::PeerHbm(g) => Some(*g),
            _ => None,
        }
    }

    pub fn is_peer(&self) -> bool {
        matches!(self, MemoryTier::PeerHbm(_))
    }

    pub fn name(&self) -> &'static str {
        match self {
            MemoryTier::LocalHbm => "local-hbm",
            MemoryTier::PeerHbm(_) => "peer-hbm",
            MemoryTier::CxlMem => "cxl-mem",
            MemoryTier::Host => "host",
            MemoryTier::Ssd => "ssd",
        }
    }
}

impl std::fmt::Display for MemoryTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemoryTier::PeerHbm(g) => write!(f, "peer-hbm(gpu{g})"),
            other => write!(f, "{}", other.name()),
        }
    }
}

/// What slice of the tier hierarchy an allocation accepts. Passed to
/// [`crate::harvest::session::HarvestSession::alloc`] /
/// [`crate::harvest::session::HarvestSession::alloc_many`]; the
/// placement policy scores the admissible tiers under one cost model
/// ([`crate::harvest::policy::PlacementPolicy::place_tiered`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TierPreference {
    /// Any harvest tier; the cost model picks the cheapest (peer HBM on
    /// an idle fabric, host/CXL when peers are full or their links are
    /// saturated).
    #[default]
    FastestAvailable,
    /// Any tier at least as fast as the named tier *class* (the peer
    /// index inside `AtLeast(PeerHbm(_))` is ignored — any peer
    /// qualifies). `AtLeast(Host)` admits everything but the SSD cold
    /// tier; `AtLeast(Ssd)` admits everything.
    AtLeast(MemoryTier),
    /// Exactly this tier — and for `Pinned(PeerHbm(g))`, exactly that
    /// device. Fails with [`HarvestError::TierUnavailable`] rather than
    /// spilling elsewhere.
    Pinned(MemoryTier),
}

impl TierPreference {
    /// The pre-tier API's semantics: peer HBM or nothing. (The peer
    /// index in the `AtLeast` payload is ignored; any peer qualifies.)
    pub const PEER_ONLY: TierPreference = TierPreference::AtLeast(MemoryTier::PeerHbm(0));

    /// Whether an allocation under this preference may land on `tier`.
    /// Local HBM is never an allocation target.
    pub fn allows(&self, tier: MemoryTier) -> bool {
        if matches!(tier, MemoryTier::LocalHbm) {
            return false;
        }
        match *self {
            TierPreference::FastestAvailable => true,
            TierPreference::AtLeast(slowest) => tier.speed_rank() <= slowest.speed_rank(),
            TierPreference::Pinned(t) => match (t, tier) {
                (MemoryTier::PeerHbm(want), MemoryTier::PeerHbm(got)) => want == got,
                (want, got) => want == got,
            },
        }
    }
}

/// What happens to the cached object when its peer allocation is revoked
/// (§3.1: consistency is an application choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Durability {
    /// An authoritative copy lives in host DRAM; revocation falls back to
    /// it (the MoE expert-weights mode).
    #[default]
    HostBacked,
    /// The object is lost on revocation and reconstructed later (the KV
    /// cache mode — recompute or drop). Under
    /// [`crate::harvest::HarvestConfig::demote_to_host`] the controller
    /// demotes lossy leases to host DRAM instead of dropping them.
    Lossy,
}

/// Placement hints passed to allocation calls (§3.2 "hint constraints").
/// Tier selection itself is a [`TierPreference`] argument, not a hint —
/// pin a specific peer with `TierPreference::Pinned(MemoryTier::PeerHbm(g))`.
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocHints {
    /// The compute GPU this cache entry serves (locality policies place
    /// close to it; it is never selected as the peer, and tier fetch
    /// costs are estimated against it).
    pub compute_gpu: Option<usize>,
    /// Client identity for fairness accounting.
    pub client: Option<u32>,
    /// Durability mode (recorded on the lease; the runtime never tracks
    /// dirty state either way).
    pub durability: Durability,
}

/// The (device, pointer, size) tuple the paper's API returns, plus
/// bookkeeping metadata. This is the *raw* placement record; the RAII
/// owner of it is [`crate::harvest::session::Lease`]. `tier` is the
/// residency at the time the record was read — the lease's shared tier
/// cell stays current across migrations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HarvestHandle {
    pub id: LeaseId,
    /// Tier holding the bytes.
    pub tier: MemoryTier,
    /// The device "pointer" (simulated: allocation id + byte offset
    /// within the tier's arena).
    pub alloc: AllocId,
    pub offset: u64,
    pub size: u64,
    pub durability: Durability,
    pub client: Option<u32>,
}

impl HarvestHandle {
    /// The peer GPU index, when the record places the bytes in peer HBM.
    pub fn peer_gpu(&self) -> Option<usize> {
        self.tier.peer_gpu()
    }
}

/// Why a peer allocation disappeared (§3.2: allocator pressure,
/// policy-driven eviction, or external reclamation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RevocationReason {
    /// Co-tenant memory demand grew past the harvestable budget.
    TenantPressure,
    /// The controller's own policy evicted it (e.g. rebalancing).
    PolicyEviction,
    /// A higher-priority workload reclaimed the MIG partition.
    ExternalReclaim,
    /// Runtime shutdown.
    Shutdown,
}

/// A completed revocation, as recorded in the runtime log (and delivered
/// to the deprecated push callbacks). The pull-model equivalent handed
/// to sessions is [`crate::harvest::events::RevocationEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Revocation {
    pub handle: HarvestHandle,
    pub reason: RevocationReason,
    /// Virtual time at which the free completed (after DMA drain).
    pub at: Ns,
}

/// Errors from the allocation and transfer paths.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HarvestError {
    /// No admissible tier currently has a segment that fits under the
    /// policy. For vectored allocations `requested` is the total batch
    /// size.
    NoCapacity { requested: u64 },
    /// The preference pinned a tier that cannot serve the request.
    TierUnavailable { tier: MemoryTier },
    /// Unknown, revoked, or already-released lease.
    StaleLease(LeaseId),
    /// Zero-byte request (vectored: any zero-byte element).
    ZeroSize,
}

impl std::fmt::Display for HarvestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HarvestError::NoCapacity { requested } => {
                write!(f, "no tier capacity for {requested} bytes")
            }
            HarvestError::TierUnavailable { tier } => {
                write!(f, "pinned tier {tier} unavailable")
            }
            HarvestError::StaleLease(id) => write!(f, "stale lease {id:?}"),
            HarvestError::ZeroSize => write!(f, "zero-size harvest allocation"),
        }
    }
}

impl std::error::Error for HarvestError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_ranks_order_fast_to_slow() {
        assert!(MemoryTier::LocalHbm.speed_rank() < MemoryTier::PeerHbm(0).speed_rank());
        assert!(MemoryTier::PeerHbm(7).speed_rank() < MemoryTier::CxlMem.speed_rank());
        assert!(MemoryTier::CxlMem.speed_rank() < MemoryTier::Host.speed_rank());
        assert!(MemoryTier::Host.speed_rank() < MemoryTier::Ssd.speed_rank());
    }

    #[test]
    fn tier_devices() {
        assert_eq!(MemoryTier::PeerHbm(3).device(), DeviceId::Gpu(3));
        assert_eq!(MemoryTier::Host.device(), DeviceId::Host);
        assert_eq!(MemoryTier::CxlMem.device(), DeviceId::Cxl);
        assert_eq!(MemoryTier::Ssd.device(), DeviceId::Ssd);
        assert_eq!(MemoryTier::PeerHbm(2).peer_gpu(), Some(2));
        assert_eq!(MemoryTier::Host.peer_gpu(), None);
    }

    #[test]
    fn preference_admission() {
        use MemoryTier::*;
        use TierPreference::*;
        for t in [PeerHbm(0), PeerHbm(5), CxlMem, Host, Ssd] {
            assert!(FastestAvailable.allows(t), "{t}");
        }
        assert!(!FastestAvailable.allows(LocalHbm), "local pool is consumer-managed");
        assert!(AtLeast(Host).allows(Host));
        assert!(AtLeast(Host).allows(CxlMem));
        assert!(!AtLeast(Host).allows(Ssd), "the cold tier is opt-in");
        assert!(AtLeast(Ssd).allows(Host), "AtLeast(Ssd) admits everything");
        assert!(AtLeast(Ssd).allows(Ssd));
        assert!(AtLeast(CxlMem).allows(PeerHbm(1)));
        assert!(!AtLeast(CxlMem).allows(Host));
        assert!(Pinned(Ssd).allows(Ssd));
        assert!(!Pinned(Ssd).allows(Host));
        assert!(TierPreference::PEER_ONLY.allows(PeerHbm(9)), "index in AtLeast ignored");
        assert!(!TierPreference::PEER_ONLY.allows(CxlMem));
        assert!(Pinned(Host).allows(Host));
        assert!(!Pinned(Host).allows(CxlMem));
        assert!(Pinned(PeerHbm(1)).allows(PeerHbm(1)));
        assert!(!Pinned(PeerHbm(1)).allows(PeerHbm(2)), "pinned peer is device-exact");
        assert!(!Pinned(LocalHbm).allows(LocalHbm));
    }

    #[test]
    fn tier_display_names() {
        assert_eq!(MemoryTier::PeerHbm(2).to_string(), "peer-hbm(gpu2)");
        assert_eq!(MemoryTier::Host.to_string(), "host");
        assert_eq!(MemoryTier::CxlMem.to_string(), "cxl-mem");
        assert_eq!(MemoryTier::Ssd.to_string(), "ssd");
    }
}
